"""Figs. 7–8 — FL accuracy across schemes (proposed / W-O DT / OMA / ideal)
with 30% poisoners, on IID and non-IID splits of both dataset proxies.

Grid layout under the training sweep engine: the IID/non-IID axis rides
the per-seed DATA axis of ``sweep_training`` (two stacked splits sharing
one model/state), scheme stays a static compile key — so each figure is
ONE dispatch per scheme, not one per (split, scheme) cell.

Claims verified: ideal ≥ proposed ≥ {wo_dt, oma}; non-IID degrades accuracy;
all schemes use the reputation-based selection (fair comparison, §VI-C).
Final accuracies are read straight off the stacked ``(C, S, R)`` metrics
(mean over the config axis, then max of the last 5 rounds).  A batched
game-level precheck verifies the resource premise underlying the accuracy
gap — DT mapping saves client energy over the channel distribution
(K realizations, one vmapped Stackelberg solve per scheme)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.fl_round import stack_states, sweep_training
from repro.core.stackelberg import GameConfig

from .common import (fl_bench_config, fl_setup, mc_equilibrium_stats,
                     save_csv, stack_data)

ROUNDS = 16
SCHEMES = ("proposed", "wo_dt", "oma", "ideal")


def _mc_energy_precheck(k: int = 128, n: int = 5) -> bool:
    """Mean equilibrium energy over K draws, ONE batched XLA call per
    scheme: proposed (DT) < wo_dt, and proposed ≤ the OMA baseline (now
    batched too) — the resource premise behind the accuracy gap."""
    key = jax.random.PRNGKey(7)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    game = GameConfig()
    prop = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="proposed")
    wo = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="wo_dt")
    oma = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="oma")
    return (prop["mean_energy"] < wo["mean_energy"]
            and prop["mean_energy"] <= oma["mean_energy"] * 1.05)


def run():
    t0 = time.perf_counter()
    out = []
    mc_ok = _mc_energy_precheck()
    for dataset, fig in (("mnist", "fig7"), ("cifar", "fig8")):
        # S axis = (IID, non-IID) splits; the state/model is shared
        setups = [fl_setup(13, dataset, poison_ratio=0.3, iid=iid)
                  for iid in (True, False)]
        logits_fn = setups[0][2]
        states = stack_states([s for s, _, _ in setups])
        data = stack_data([d for _, d, _ in setups])
        acc = {}        # scheme -> (C=1, S=2, R) stacked val_acc
        for scheme in SCHEMES:
            fl = fl_bench_config(scheme=scheme)
            _, metrics = sweep_training(states, data, [fl], GameConfig(),
                                        logits_fn, ROUNDS)
            acc[scheme] = metrics["val_acc"]
        results = {(iid, s): [float(x) for x in acc[s][0, i]]
                   for s in SCHEMES for i, iid in enumerate((True, False))}
        rows = [[r] + [round(results[k][r], 4) for k in sorted(results)]
                for r in range(ROUNDS)]
        save_csv(f"{fig}_schemes_{dataset}",
                 "round," + ",".join(f"{'iid' if i else 'noniid'}_{s}"
                                     for i, s in sorted(results)),
                 rows)
        # final accuracy per (split, scheme) off the stacked (C, S, R) grid:
        # mean over the config axis, max of the last 5 rounds → [S]
        final = {s: jnp.max(jnp.mean(a, axis=0)[:, -5:], axis=-1)
                 for s, a in acc.items()}
        iid_ok = bool(final["ideal"][0] >= final["proposed"][0] - 0.05
                      and final["proposed"][0] >=
                      min(float(final["wo_dt"][0]),
                          float(final["oma"][0])) - 0.02)
        noniid_drop = bool(final["proposed"][1] <= final["proposed"][0] + 0.02)
        out.append((f"{fig}_schemes_{dataset}", 0.0,
                    f"ordering_ok={iid_ok};noniid_drop={noniid_drop};"
                    f"mc_dt_energy_saving={mc_ok};"
                    f"iid_proposed={float(final['proposed'][0]):.3f};"
                    f"iid_ideal={float(final['ideal'][0]):.3f};"
                    f"iid_wo_dt={float(final['wo_dt'][0]):.3f};"
                    f"iid_oma={float(final['oma'][0]):.3f}"))
    total_us = (time.perf_counter() - t0) * 1e6
    out = [(n, total_us / len(out), d) for n, _, d in out]
    return out
