"""Figs. 7–8 — FL accuracy across schemes (proposed / W-O DT / OMA / ideal)
with 30% poisoners, on IID and non-IID splits of both dataset proxies.

Claims verified: ideal ≥ proposed ≥ {wo_dt, oma}; non-IID degrades accuracy;
all schemes use the reputation-based selection (fair comparison, §VI-C).
A batched game-level precheck verifies the resource premise underlying the
accuracy gap — DT mapping saves client energy over the channel distribution
(K realizations, one vmapped Stackelberg solve per scheme)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import curve, fl_experiment, mc_equilibrium_stats, save_csv

ROUNDS = 16
SCHEMES = ("proposed", "wo_dt", "oma", "ideal")


def _mc_energy_precheck(k: int = 128, n: int = 5) -> bool:
    """Mean equilibrium energy over K draws, ONE batched XLA call per
    scheme: proposed (DT) < wo_dt, and proposed ≤ the OMA baseline (now
    batched too) — the resource premise behind the accuracy gap."""
    from repro.core.stackelberg import GameConfig
    key = jax.random.PRNGKey(7)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    game = GameConfig()
    prop = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="proposed")
    wo = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="wo_dt")
    oma = mc_equilibrium_stats(game, key, k, n, d, vmax, scheme="oma")
    return (prop["mean_energy"] < wo["mean_energy"]
            and prop["mean_energy"] <= oma["mean_energy"] * 1.05)


def run():
    t0 = time.perf_counter()
    out = []
    mc_ok = _mc_energy_precheck()
    for dataset, fig in (("mnist", "fig7"), ("cifar", "fig8")):
        results = {}
        for iid in (True, False):
            for scheme in SCHEMES:
                hist = fl_experiment(seed=13, dataset=dataset, scheme=scheme,
                                     poison_ratio=0.3, rounds=ROUNDS,
                                     iid=iid)
                results[(iid, scheme)] = curve(hist)
        rows = [[r] + [round(results[k][r], 4) for k in sorted(results)]
                for r in range(ROUNDS)]
        save_csv(f"{fig}_schemes_{dataset}",
                 "round," + ",".join(f"{'iid' if i else 'noniid'}_{s}"
                                     for i, s in sorted(results)),
                 rows)
        final = {k: max(v[-5:]) for k, v in results.items()}
        iid_ok = (final[(True, "ideal")] >= final[(True, "proposed")] - 0.05
                  and final[(True, "proposed")] >=
                  min(final[(True, "wo_dt")], final[(True, "oma")]) - 0.02)
        noniid_drop = final[(False, "proposed")] <= final[(True, "proposed")] + 0.02
        out.append((f"{fig}_schemes_{dataset}", 0.0,
                    f"ordering_ok={iid_ok};noniid_drop={noniid_drop};"
                    f"mc_dt_energy_saving={mc_ok};"
                    f"iid_proposed={final[(True,'proposed')]:.3f};"
                    f"iid_ideal={final[(True,'ideal')]:.3f};"
                    f"iid_wo_dt={final[(True,'wo_dt')]:.3f};"
                    f"iid_oma={final[(True,'oma')]:.3f}"))
    total_us = (time.perf_counter() - t0) * 1e6
    out = [(n, total_us / len(out), d) for n, _, d in out]
    return out
