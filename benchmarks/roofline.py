"""Roofline analysis (deliverable g): three terms per (arch × shape) from the
dry-run artifacts, with the trip-count-corrected HLO walker.

    compute term    = HLO_FLOPs(corrected, per device) / peak_FLOP/s
    memory term     = HLO_bytes(corrected, per device) / HBM_bw
    collective term = collective_bytes(per device)     / ICI link_bw

Per-device quantities from the SPMD module are equivalent to the spec's
global/(chips·bw) form.  MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(inference); the ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled
compute is "useful" (remat + attention quadratic + dispatch waste).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod] [--json out]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import zstandard as zstd

from repro.analysis.hlo_walk import HloCost
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "runs/dryrun")


def load_hlo_cost(arch: str, shape: str, mesh: str):
    path = os.path.join(RESULTS_DIR, "hlo", f"{arch}_{shape}_{mesh}.hlo.zst")
    if not os.path.exists(path):
        return None
    with open(path, "rb") as f:
        text = zstd.ZstdDecompressor().decompress(f.read()).decode()
    return HloCost(text).entry_cost()


def model_flops_per_device(meta: dict, n_chips: int) -> float:
    n_active = meta["params_active"]
    s, b = meta["seq_len"], meta["global_batch"]
    mode = meta["mode"]
    if mode == "train":
        total = 6.0 * n_active * s * b
    elif mode == "prefill":
        total = 2.0 * n_active * s * b
    else:  # decode: one token per sequence
        total = 2.0 * n_active * b
    return total / n_chips


def analyze_combo(result: dict) -> dict | None:
    if result.get("status") != "ok":
        return None
    arch, shape, mesh = result["arch"], result["shape"], result["mesh"]
    walk = load_hlo_cost(arch, shape, mesh)
    if walk is None:
        return None
    n_chips = result["n_chips"]
    flops = walk["flops"]
    hbm = walk["hbm_bytes"]
    coll = sum(walk["collectives"].values())
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm / HBM_BW
    t_coll = coll / ICI_BW_PER_LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(result["meta"], n_chips)
    mem = result["memory"]
    hbm_resident = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "n_chips": n_chips,
        "flops_per_dev": flops, "hbm_bytes_per_dev": hbm,
        "collective_bytes_per_dev": coll,
        "collectives_by_type": walk["collectives"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_compute_ratio": mf / flops if flops else 0.0,
        "resident_bytes_per_dev": hbm_resident,
        "step_time_bound_s": max(terms.values()),
        "raw_cost_analysis_flops": result["cost"]["flops"],
    }


def all_results(mesh: str = "pod"):
    out = []
    for fname in sorted(os.listdir(RESULTS_DIR)):
        if not fname.endswith(f"_{mesh}.json"):
            continue
        with open(os.path.join(RESULTS_DIR, fname)) as f:
            r = json.load(f)
        a = analyze_combo(r)
        if a:
            out.append(a)
        elif r.get("status") == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": mesh, "skipped": r["reason"]})
    return out


def table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful ratio | resident GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    order = {s: i for i, s in enumerate(SHAPES)}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_compute_ratio']:.2f} | "
            f"{r['resident_bytes_per_dev']/2**30:.1f} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--json", default="runs/roofline.json")
    args = ap.parse_args()
    rows = all_results(args.mesh)
    print(table(rows))
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"\nwrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
