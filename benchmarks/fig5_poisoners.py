"""Fig. 5 — FL accuracy vs #poisoners: proposed (AC+MS+PI reputation) vs
benchmark (AC+MS only, PI-blind).

Grid layout under the training sweep engine: the attacker-fraction axis
rides the per-seed DATA axis of ``sweep_training`` (the three poison
ratios are three stacked datasets sharing one model/state), while scheme
(selection weights + RONI on/off) stays a static key — so the whole
figure is ONE dispatch per (dataset, scheme), not one per cell.

Claims verified (on the synthetic proxies — DESIGN.md §6), read straight
off the stacked ``(C, S, R)`` metrics (mean over the config axis, then
max of the last 5 rounds):
  * 0% poisoners: proposed ≈ benchmark;
  * 30%/50% poisoners: proposed > benchmark (RONI-driven PI term excludes
    poisoned updates from selection and aggregation)."""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core.fl_round import stack_states, sweep_training
from repro.core.reputation import BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS
from repro.core.stackelberg import GameConfig

from .common import fl_bench_config, fl_setup, save_csv, stack_data

ROUNDS = 16
RATIOS = (0.0, 0.3, 0.5)
SCHEMES = (("proposed", PROPOSED_WEIGHTS, True),
           ("benchmark", BENCHMARK_WEIGHTS, False))


def run():
    t0 = time.perf_counter()
    acc = {}            # (dataset, scheme) -> (C=1, S=|ratios|, R) val_acc
    for dataset in ("mnist", "cifar"):
        setups = [fl_setup(7, dataset, poison_ratio=r) for r in RATIOS]
        logits_fn = setups[0][2]
        states = stack_states([s for s, _, _ in setups])
        data = stack_data([d for _, d, _ in setups])
        for scheme_name, w, roni in SCHEMES:
            fl = fl_bench_config(weights=w, use_roni=roni)
            _, metrics = sweep_training(states, data, [fl], GameConfig(),
                                        logits_fn, ROUNDS)
            acc[(dataset, scheme_name)] = metrics["val_acc"]
    results = {(d, r, s): [float(x) for x in acc[(d, s)][0, i]]
               for d, s in acc for i, r in enumerate(RATIOS)}
    rows = []
    for r in range(ROUNDS):
        row = [r]
        for k in sorted(results):
            row.append(round(results[k][r], 4))
        rows.append(row)
    hdr = "round," + ",".join(f"{d}_{int(p*100)}pct_{s}"
                              for d, p, s in sorted(results))
    save_csv("fig5_poisoners", hdr, rows)

    elapsed_us = (time.perf_counter() - t0) * 1e6
    checks = []
    # final accuracy per ratio, straight off the stacked (C, S, R) metrics:
    # mean over the config axis (size 1 here), max of the last 5 rounds → [S]
    final = {k: jnp.max(jnp.mean(a, axis=0)[:, -5:], axis=-1)
             for k, a in acc.items()}
    for dataset in ("mnist", "cifar"):
        prop, bench = final[(dataset, "proposed")], final[(dataset, "benchmark")]
        same0 = bool(jnp.abs(prop[0] - bench[0]) < 0.15)
        better30 = bool(prop[1] >= bench[1] - 0.02)
        better50 = bool(prop[2] >= bench[2] - 0.02)
        checks.append(f"{dataset}:0pct_close={same0};30pct_ge={better30};"
                      f"50pct_ge={better50}")
    return [("fig5_poisoners_sweep", elapsed_us, "|".join(checks))]
