"""Fig. 5 — FL accuracy vs #poisoners: proposed (AC+MS+PI reputation) vs
benchmark (AC+MS only, PI-blind).

Claims verified (on the synthetic proxies — DESIGN.md §6):
  * 0% poisoners: proposed ≈ benchmark;
  * 30%/50% poisoners: proposed > benchmark (RONI-driven PI term excludes
    poisoned updates from selection and aggregation)."""
from __future__ import annotations

import time

from repro.core.reputation import BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS

from .common import curve, fl_experiment, save_csv

ROUNDS = 16


def run():
    out_rows = []
    results = {}
    t0 = time.perf_counter()
    for dataset in ("mnist", "cifar"):
        for ratio in (0.0, 0.3, 0.5):
            for scheme_name, w, roni in (("proposed", PROPOSED_WEIGHTS, True),
                                         ("benchmark", BENCHMARK_WEIGHTS, False)):
                hist = fl_experiment(seed=7, dataset=dataset,
                                     poison_ratio=ratio, weights=w,
                                     use_roni=roni, rounds=ROUNDS)
                acc = curve(hist)
                results[(dataset, ratio, scheme_name)] = acc
    rows = []
    for r in range(ROUNDS):
        row = [r]
        for k in sorted(results):
            row.append(round(results[k][r], 4))
        rows.append(row)
    hdr = "round," + ",".join(f"{d}_{int(p*100)}pct_{s}"
                              for d, p, s in sorted(results))
    save_csv("fig5_poisoners", hdr, rows)

    elapsed_us = (time.perf_counter() - t0) * 1e6
    checks = []
    for dataset in ("mnist", "cifar"):
        final = {k: max(v[-5:]) for k, v in results.items() if k[0] == dataset}
        same0 = abs(final[(dataset, 0.0, "proposed")]
                    - final[(dataset, 0.0, "benchmark")]) < 0.15
        better30 = final[(dataset, 0.3, "proposed")] >= \
            final[(dataset, 0.3, "benchmark")] - 0.02
        better50 = final[(dataset, 0.5, "proposed")] >= \
            final[(dataset, 0.5, "benchmark")] - 0.02
        checks.append(f"{dataset}:0pct_close={same0};30pct_ge={better30};"
                      f"50pct_ge={better50}")
    return [("fig5_poisoners_sweep", elapsed_us, "|".join(checks))]
