"""FL training trajectory throughput — rounds/sec for the three tiers of
``fl_round`` at R = 50 rounds of the proposed scheme (RONI on):

  * host  — ``run_training_eager``: the legacy host-side round loop, one
    dispatch chain per stage per round, per-round ``float()``/``int()``
    metric syncs (measured on a subsample of rounds — it is the slow
    baseline);
  * scan  — ``run_training_scan``: the whole R-round trajectory as ONE
    jitted ``lax.scan`` dispatch (timed cold = compile + run, and warm);
  * vmap  — ``batched_training``: S = 8 seeds × R rounds in one dispatch
    (rounds/sec counts S·R rounds), seed axis device-sharded.

Plus the ``sweep`` section — the Fig. 5/6/7/8 grid workload: C = 6 config
points (lr / ε / t_max vary numerically) × S = 4 seeds × R = 20 rounds as
ONE ``sweep_training`` dispatch, measured against the two per-cell loops it
replaces: the per-cell HOST loop (``run_training_eager`` per cell — the
pre-scan figure path, subsampled because it is the slow baseline; the ≥4x
acceptance target) and the per-cell scan loop (``run_training_scan`` per
cell — the pre-sweep figure path, also the parity reference ≤ 1e-5).  A
fig6-style ε-grid re-dispatch proves numeric knobs stay traced operands
(zero retraces).

Also records the recompile accounting (``TRACE_COUNTS['run_round']`` must
grow by 1 per tier) and the S-seed parity check (vmap row s == sequential
scan of seed s, ≤ 1e-5 rel — the acceptance criterion).

Writes ``BENCH_training.json`` (repo root) so later PRs can track the
trajectory-throughput trend; ``scripts/check_bench.py`` gates the compiled
tiers (scan/vmap rounds/sec) at −20% vs the committed baseline.

Scaling
-------
The ``scaling`` section measures the vmap tier (``batched_training``,
S=8 seeds on the 1D draw mesh) and the sweep tier (``sweep_training``,
C=6 × S=4 on the 2D (cfg, draw) mesh) at R=10 rounds across 1, 2 and 4
forced host devices, each in its own worker subprocess
(``--scaling-worker D``).  Both tiers are efficiency-gated at ≥70% by
``scripts/check_bench.py`` and carry sharded-vs-``run_training_scan``
cell parity (≤1e-5).  On this 1-core container the quotient measures
sharding-overhead retention, not wall-clock speedup — see
``benchmarks/common.py``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from .common import emit_scaling_rows, scaling_section

ROUNDS = 50
SEEDS = 8
HOST_ROUNDS = 10          # host-loop rounds actually timed (slow baseline)
M, CAP, HIDDEN, NSEL = 12, 64, 32, 4
SWEEP_C, SWEEP_S, SWEEP_R = 6, 4, 20   # the figure-grid sweep workload
SWEEP_HOST_ROUNDS = 6     # per-cell host-loop rounds timed (extrapolated)
SCALING_R = 10            # rounds per scaling-tier trajectory
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_training.json")


def _rate(elapsed_s: float, rounds: int) -> float:
    return rounds / max(elapsed_s, 1e-12)


def _setup(seed: int):
    from repro.core.channel import sample_positions
    from repro.core.digital_twin import DTConfig, sample_v_max
    from repro.core.fl_round import FLState
    from repro.core.reputation import init_reputation
    from repro.data.federated import make_federated_data
    from repro.data.synthetic import SYNTHETIC_MNIST
    from repro.models.classifier import make_classifier
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=M, cap=CAP,
                               poison_ratio=0.25)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784,
                                        hidden=HIDDEN)
    state = FLState(params=params, rep=init_reputation(M),
                    v_max=sample_v_max(ks[2], M, DTConfig()),
                    distances=sample_positions(ks[3], M), key=ks[4])
    return state, data, logits_fn


def _sweep_section(per_seed, data, logits_fn):
    """The Fig. 5/6/7/8 workload: a C×S grid of whole training runs as one
    dispatch vs the two per-cell loops it replaces.  Returns the ``sweep``
    sub-document of BENCH_training.json."""
    import dataclasses
    from repro.core.fl_round import (FLConfig, run_training_eager,
                                     run_training_scan, stack_states,
                                     sweep_training)
    from repro.core.stackelberg import (GameConfig, TRACE_COUNTS,
                                        sharding_layout)
    from repro.sharding import game_mesh
    fls = [FLConfig(n_selected=NSEL, local_steps=10, server_steps=10,
                    lr=lr, epsilon=eps)
           for lr, eps in ((0.1, 0.0), (0.08, 0.1), (0.12, 0.2),
                           (0.1, 0.3), (0.06, 0.0), (0.1, 0.45))]
    games = [dataclasses.replace(GameConfig(), t_max=t)
             for t in (8.0, 9.0, 10.0, 11.0, 12.0, 10.5)]
    states = stack_states([s for s, _, _ in per_seed[:SWEEP_S]])
    grid_rounds = SWEEP_C * SWEEP_S * SWEEP_R

    # per-cell HOST loop (the pre-scan figure path): one cell, subsampled —
    # at ~1 round/sec the full grid would dominate the whole bench
    run_training_eager(per_seed[0][0], data, fls[0], games[0], logits_fn, 1)
    t0 = time.perf_counter()
    run_training_eager(per_seed[0][0], data, fls[0], games[0], logits_fn,
                       SWEEP_HOST_ROUNDS)
    percell_host_rps = _rate(time.perf_counter() - t0, SWEEP_HOST_ROUNDS)

    # per-cell scan loop (the pre-sweep figure path) — warm, and the
    # parity reference for the swept grid
    refs = {}
    run_training_scan(per_seed[0][0], data, fls[0], games[0], logits_fn,
                      SWEEP_R)                       # compile once
    t0 = time.perf_counter()
    for c in range(SWEEP_C):
        for s in range(SWEEP_S):
            _, out = run_training_scan(per_seed[s][0], data, fls[c],
                                       games[c], logits_fn, SWEEP_R)
            refs[(c, s)] = out["val_acc"]
    jax.block_until_ready(refs[(SWEEP_C - 1, SWEEP_S - 1)])
    percell_scan_rps = _rate(time.perf_counter() - t0, grid_rounds)

    # the sweep: C×S×R in ONE dispatch, round body traced once
    before = TRACE_COUNTS["run_round"]
    t0 = time.perf_counter()
    _, sw = sweep_training(states, data, fls, games, logits_fn, SWEEP_R)
    jax.block_until_ready(sw["val_acc"])
    sweep_cold_s = time.perf_counter() - t0
    sweep_traces = TRACE_COUNTS["run_round"] - before
    assert sweep_traces == 1, f"sweep traced run_round {sweep_traces}x"
    sweep_rps = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        _, sw = sweep_training(states, data, fls, games, logits_fn, SWEEP_R)
        jax.block_until_ready(sw["val_acc"])
        sweep_rps = max(sweep_rps, _rate(time.perf_counter() - t0,
                                         grid_rounds))

    # parity: sweep cell (c, s) == the per-cell scan of configs c, seed s
    sweep_rel = 0.0
    for (c, s), ref in refs.items():
        sweep_rel = max(sweep_rel, float(jnp.max(
            jnp.abs(sw["val_acc"][c, s] - ref)
            / jnp.maximum(jnp.abs(ref), 1e-12))))

    # fig6-style ε grid: same shapes, new numeric knob values — the
    # re-dispatch must not retrace the round body
    before = TRACE_COUNTS["run_round"]
    eps_fls = [dataclasses.replace(fls[0], epsilon=e)
               for e in (0.0, 0.15, 0.3, 0.45, 0.6, 0.75)]
    _, _ = sweep_training(states, data, eps_fls, games[0], logits_fn,
                          SWEEP_R)
    eps_retraces = TRACE_COUNTS["run_round"] - before
    assert eps_retraces == 0, "ε grid retraced the round body"

    return {
        "grid_c": SWEEP_C,
        "grid_s": SWEEP_S,
        "grid_rounds": SWEEP_R,
        "percell_host_rounds_per_sec": round(percell_host_rps, 2),
        "percell_host_measured_rounds": SWEEP_HOST_ROUNDS,
        "percell_scan_rounds_per_sec": round(percell_scan_rps, 2),
        "sweep_cold_wall_s": round(sweep_cold_s, 3),
        "sweep_rounds_per_sec": round(sweep_rps, 2),
        "speedup_sweep_vs_percell_host": round(sweep_rps / percell_host_rps,
                                               2),
        "speedup_sweep_vs_percell_scan": round(sweep_rps / percell_scan_rps,
                                               2),
        "run_round_traces_sweep": int(sweep_traces),
        "eps_grid_retraces": int(eps_retraces),
        "grid_axis_shards": sharding_layout(SWEEP_C * SWEEP_S),
        "grid_shards": list(game_mesh.grid_layout(SWEEP_C, SWEEP_S)),
        "sweep_max_rel_vs_percell": sweep_rel,
        "sweep_matches_percell_1e5": bool(sweep_rel <= 1e-5),
    }


def scaling_workload():
    """One ``--scaling-worker`` pass at the current (forced) device count:
    warm rates for the vmap (S=8) and sweep (C=6 × S=4) tiers at R=10,
    plus sharded-vs-``run_training_scan`` cell parity (host numpy —
    sharded and single-device outputs live on different meshes)."""
    import dataclasses
    import numpy as np
    from repro.core.fl_round import (FLConfig, batched_training,
                                     run_training_scan, stack_states,
                                     sweep_training)
    from repro.core.stackelberg import GameConfig
    r = SCALING_R
    game = GameConfig()
    fl = FLConfig(n_selected=NSEL, local_steps=10, server_steps=10, lr=0.1)
    per_seed = [_setup(s) for s in range(SEEDS)]
    data, logits_fn = per_seed[0][1], per_seed[0][2]
    states = stack_states([s for s, _, _ in per_seed])
    rows = {}

    def ref_acc(state, flc, gc):
        _, ref = run_training_scan(state, data, flc, gc, logits_fn, r)
        return np.asarray(jax.device_get(ref["val_acc"]))

    _, bout = batched_training(states, data, fl, game, logits_fn, r)
    jax.block_until_ready(bout["val_acc"])
    warm_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        _, bout = batched_training(states, data, fl, game, logits_fn, r)
        jax.block_until_ready(bout["val_acc"])
        warm_s = min(warm_s, time.perf_counter() - t0)
    acc = np.asarray(jax.device_get(bout["val_acc"]))
    rel = 0.0
    for s in (0, SEEDS - 1):
        ref = ref_acc(per_seed[s][0], fl, game)
        rel = max(rel, float(np.max(np.abs(acc[s] - ref)
                                    / np.maximum(np.abs(ref), 1e-12))))
    rows["vmap"] = {
        "workload": f"batched_training S={SEEDS} R={r}",
        "rate": _rate(warm_s, SEEDS * r),
        "parity_max_rel": rel,
    }

    fls = [dataclasses.replace(fl, lr=lr, epsilon=eps)
           for lr, eps in ((0.1, 0.0), (0.08, 0.1), (0.12, 0.2),
                           (0.1, 0.3), (0.06, 0.0), (0.1, 0.45))]
    games = [dataclasses.replace(game, t_max=t)
             for t in (8.0, 9.0, 10.0, 11.0, 12.0, 10.5)]
    states4 = stack_states([s for s, _, _ in per_seed[:SWEEP_S]])
    _, sw = sweep_training(states4, data, fls, games, logits_fn, r)
    jax.block_until_ready(sw["val_acc"])
    warm_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        _, sw = sweep_training(states4, data, fls, games, logits_fn, r)
        jax.block_until_ready(sw["val_acc"])
        warm_s = min(warm_s, time.perf_counter() - t0)
    acc = np.asarray(jax.device_get(sw["val_acc"]))
    rel = 0.0
    for c, s in ((0, 0), (SWEEP_C - 1, SWEEP_S - 1)):
        ref = ref_acc(per_seed[s][0], fls[c], games[c])
        rel = max(rel, float(np.max(np.abs(acc[c, s] - ref)
                                    / np.maximum(np.abs(ref), 1e-12))))
    rows["sweep"] = {
        "workload": f"sweep_training C={SWEEP_C} S={SWEEP_S} R={r}",
        "rate": _rate(warm_s, SWEEP_C * SWEEP_S * r),
        "parity_max_rel": rel,
    }
    return rows


def run():
    from repro.core.fl_round import (FLConfig, batched_training,
                                     run_training_eager, run_training_scan,
                                     stack_states)
    from repro.core.stackelberg import (GameConfig, TRACE_COUNTS,
                                        sharding_layout)
    t_start = time.perf_counter()
    game = GameConfig()
    fl = FLConfig(n_selected=NSEL, local_steps=10, server_steps=10, lr=0.1)
    state, data, logits_fn = _setup(0)

    # host tier: warm the per-stage jit caches with one round, then time a
    # subsample — at ~10 dispatch chains/round the full R=50 would dominate
    # the bench without changing the rate.
    run_training_eager(state, data, fl, game, logits_fn, 1)
    t0 = time.perf_counter()
    run_training_eager(state, data, fl, game, logits_fn, HOST_ROUNDS)
    host_rps = _rate(time.perf_counter() - t0, HOST_ROUNDS)

    # scan tier: one lax.scan dispatch for all R rounds
    before = TRACE_COUNTS["run_round"]
    t0 = time.perf_counter()
    out_state, out = run_training_scan(state, data, fl, game, logits_fn,
                                       ROUNDS)
    jax.block_until_ready(out["val_acc"])
    scan_cold_s = time.perf_counter() - t0
    scan_traces = TRACE_COUNTS["run_round"] - before
    scan_rps = 0.0                       # warm: best of 3 (scheduler noise)
    for _ in range(3):
        t0 = time.perf_counter()
        _, out = run_training_scan(state, data, fl, game, logits_fn, ROUNDS)
        jax.block_until_ready(out["val_acc"])
        scan_rps = max(scan_rps, _rate(time.perf_counter() - t0, ROUNDS))
    assert bool(jnp.all(jnp.isfinite(out["val_acc"]))), "non-finite history"
    assert scan_traces == 1, f"scan traced run_round {scan_traces}x"

    # vmap tier: S seeds × R rounds in one dispatch
    per_seed = [_setup(s) for s in range(SEEDS)]
    states = stack_states([s for s, _, _ in per_seed])
    before = TRACE_COUNTS["run_round"]
    t0 = time.perf_counter()
    _, bout = batched_training(states, data, fl, game, logits_fn, ROUNDS)
    jax.block_until_ready(bout["val_acc"])
    vmap_cold_s = time.perf_counter() - t0
    vmap_traces = TRACE_COUNTS["run_round"] - before
    vmap_rps = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        _, bout = batched_training(states, data, fl, game, logits_fn, ROUNDS)
        jax.block_until_ready(bout["val_acc"])
        vmap_rps = max(vmap_rps,
                       _rate(time.perf_counter() - t0, SEEDS * ROUNDS))
    assert vmap_traces == 1, f"vmap traced run_round {vmap_traces}x"

    # acceptance parity: vmap row s == sequential scan of seed s
    vmap_rel = 0.0
    for s in range(SEEDS):
        _, ref = run_training_scan(per_seed[s][0], data, fl, game,
                                   logits_fn, ROUNDS)
        vmap_rel = max(vmap_rel, float(jnp.max(
            jnp.abs(bout["val_acc"][s] - ref["val_acc"]) /
            jnp.maximum(jnp.abs(ref["val_acc"]), 1e-12))))

    sweep = _sweep_section(per_seed, data, logits_fn)
    scaling = scaling_section("benchmarks.training_throughput",
                              gate_tiers=("vmap", "sweep"))

    doc = {
        "bench": "fl_training_trajectory_throughput",
        "rounds": ROUNDS,
        "seeds": SEEDS,
        "n_clients_pool": M,
        "n_selected": NSEL,
        "scheme": fl.scheme,
        "use_roni": fl.use_roni,
        "host_rounds_per_sec": round(host_rps, 2),
        "host_measured_rounds": HOST_ROUNDS,
        "scan_cold_wall_s": round(scan_cold_s, 3),
        "scan_rounds_per_sec": round(scan_rps, 2),
        "vmap_cold_wall_s": round(vmap_cold_s, 3),
        "vmap_rounds_per_sec": round(vmap_rps, 2),
        "speedup_scan_vs_host": round(scan_rps / host_rps, 2),
        "speedup_vmap_vs_host": round(vmap_rps / host_rps, 2),
        "run_round_traces_scan": int(scan_traces),
        "run_round_traces_vmap": int(vmap_traces),
        "seed_axis_shards": sharding_layout(SEEDS),
        "devices": len(jax.devices()),
        "vmap_max_rel_vs_sequential": vmap_rel,
        "vmap_matches_sequential_1e5": bool(vmap_rel <= 1e-5),
        "sweep": sweep,
        "scaling": scaling,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)

    elapsed_us = (time.perf_counter() - t_start) * 1e6
    return [("training_throughput", elapsed_us,
             f"R={ROUNDS};host_rps={doc['host_rounds_per_sec']};"
             f"scan_rps={doc['scan_rounds_per_sec']};"
             f"vmap_rps={doc['vmap_rounds_per_sec']};"
             f"scan_speedup={doc['speedup_scan_vs_host']}x;"
             f"target_5x_met={doc['speedup_scan_vs_host'] >= 5};"
             f"run_round_traces={scan_traces};"
             f"vmap_matches_seq={doc['vmap_matches_sequential_1e5']};"
             f"sweep_rps={sweep['sweep_rounds_per_sec']};"
             f"sweep_vs_percell_host="
             f"{sweep['speedup_sweep_vs_percell_host']}x;"
             f"sweep_target_4x_met="
             f"{sweep['speedup_sweep_vs_percell_host'] >= 4};"
             f"sweep_matches_percell={sweep['sweep_matches_percell_1e5']};"
             f"scaling_eff_vmap="
             f"{scaling['tiers']['vmap']['efficiency_at_max']:.2f};"
             f"scaling_eff_sweep="
             f"{scaling['tiers']['sweep']['efficiency_at_max']:.2f}")]


if __name__ == "__main__":
    if "--scaling-worker" in sys.argv:
        emit_scaling_rows(scaling_workload())
    else:
        for row in run():
            print(row)
