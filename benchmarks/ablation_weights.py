"""Ablation: reputation-weight vector ξ = (AC, MS, PI) under 30% poisoners.

Covers the paper's design space and its prior-work baselines:
  (0,1,0) = pure age-of-update selection ([18])
  (1,0,0) = pure data-quantity/AC selection
  (0,0,1) = pure interaction-history selection
  (0.5,0.5,0) = the paper's PI-blind benchmark
  (0.3,0.5,0.2) = the paper's proposed weights

Claim probed: the PI term (with RONI) is what defends against poisoning —
ξ-vectors with PI > 0 should dominate PI-blind ones."""
from __future__ import annotations

import time

from .common import curve, fl_experiment, save_csv

ROUNDS = 16
WEIGHT_SETS = {
    "proposed_0.3_0.5_0.2": (0.3, 0.5, 0.2),
    "benchmark_0.5_0.5_0.0": (0.5, 0.5, 0.0),
    "aou_only_0_1_0": (0.0, 1.0, 0.0),
    "ac_only_1_0_0": (1.0, 0.0, 0.0),
    "pi_only_0_0_1": (0.0, 0.0, 1.0),
}


def run():
    t0 = time.perf_counter()
    results = {}
    for name, w in WEIGHT_SETS.items():
        use_roni = w[2] > 0 or name.startswith("proposed")
        accs = []
        for seed in (7, 23):
            hist = fl_experiment(seed=seed, dataset="mnist",
                                 poison_ratio=0.3, weights=w,
                                 use_roni=use_roni, rounds=ROUNDS)
            accs.append(curve(hist))
        results[name] = [sum(col) / len(col) for col in zip(*accs)]
    rows = [[r] + [round(results[k][r], 4) for k in WEIGHT_SETS]
            for r in range(ROUNDS)]
    save_csv("ablation_weights", "round," + ",".join(WEIGHT_SETS), rows)
    final = {k: max(v[-4:]) for k, v in results.items()}
    pi_sets = [final["proposed_0.3_0.5_0.2"], final["pi_only_0_0_1"]]
    blind = [final["benchmark_0.5_0.5_0.0"], final["ac_only_1_0_0"],
             final["aou_only_0_1_0"]]
    derived = ";".join(f"{k}={v:.3f}" for k, v in final.items())
    derived += f";pi_term_helps={max(pi_sets) >= max(blind) - 0.02}"
    return [("ablation_reputation_weights", (time.perf_counter() - t0) * 1e6,
             derived)]
