"""Streaming allocation service latency/throughput bench → BENCH_serve.json.

Drives ``repro.launch.alloc_serve.AllocationService`` with a MIXED-N
ARRIVAL TRACE modelled on the dynamic-membership serving story (clients
join/drop every round, so cell sizes vary request to request):

  * ``TRACE_LEN`` requests, client counts drawn log-uniform-ish over the
    bucket range — 50% small cells (N ≤ 8), 30% medium (9–16), 20% large
    (17–64), matching the "many small cells, few big ones" shape of
    cellular deployments; seeded (default 0) so the trace is reproducible;
  * every request carries its own channel draw and a jittered ``t_max``
    (heterogeneous physics riding one bucket executable);
  * buckets 8/16/64, ``max_batch`` 8, double-buffered dispatch depth 2;
  * the service is warmed first (every bucket compiled), so the measured
    stream is the steady state a deployment runs in — the zero-retrace
    property is asserted, not assumed.

``BENCH_serve.json`` fields:

  * ``trace``               — {len, seed, buckets, max_batch, mix} of the
                              arrival trace (documented above);
  * ``warmup_s``            — one-time compile cost of the bucket set;
  * ``wall_s``              — submit-first → drain-complete wall seconds;
  * ``requests_per_sec``    — TRACE_LEN / wall_s, the sustained service
                              throughput (GATED by scripts/check_bench.py
                              at -20% vs the committed baseline);
  * ``latency_ms``          — {p50, p99, mean, max} per-request latency
                              (submit → result on host; recorded for the
                              ROADMAP but NOT gated — wall-clock
                              percentiles are too noisy on shared hosts);
  * ``retraces_after_warm`` — must be 0 (bucket executables are hit warm);
  * ``parity_max_rel``      — max relative |padded − exact-N| over p/q/f/
                              energy/t_total on a subsample of the trace
                              (the ≤1e-5 serving contract, re-checked in
                              the bench so the committed JSON carries the
                              measured number).

Resilience sections (ISSUE 9)
-----------------------------
  * ``overload``  — a same-bucket burst at 2x the arrival pressure the
    steady-state trace exerts (256 back-to-back submits into a bounded
    ``max_queue=32`` SLA-mode service; 25% of requests carry priority 2
    + a 1 s deadline).  Records sustained requests/sec over ALL emitted
    rows (every row is exactly-once — ok, shed, timeout or rejected),
    the status mix, and high-priority completion p99.
  * ``chaos``     — replays the ``full_chaos`` scenario from
    ``repro.launch.serve_chaos`` (burst + NaN channel rows + malformed
    requests + one stall + one transient dispatch failure + one
    poisoned batch) and records the audited ``ChaosReport`` accounting.

Both feed the top-level ``claims`` booleans gated by check_bench
(``*_no_lost_requests``, the high-priority p99 bound, no NaN ever
leaking through a ``status="ok"`` row) and the ``overload_rps`` /
``chaos_rps`` rates (tolerance-declared at ±35% — these paths sleep on
purpose, so they are noisier than the steady-state rate).

Run:  PYTHONPATH=src python benchmarks/serve_latency.py
      PYTHONPATH=src python -m benchmarks.serve_latency --devices 4

Scaling
-------
The ``scaling`` section replays a shorter mixed-N trace at 1, 2 and 4
forced host devices (``--scaling-worker D`` subprocesses): the service
shards its fixed ``[B, n_bucket]`` dispatch batch over the draw mesh, so
the section records the sustained request rate and padded-vs-exact
parity per device count.  Serving is latency-bound by the host round
trip, not device compute, so this tier is NOT efficiency-gated — the
rates document overhead, the parity and zero-retrace fields are the
contract.  On a 1-core container the rates measure sharding overhead
only — see ``benchmarks/common.py``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

import jax.numpy as jnp

try:
    from .common import emit_scaling_rows, scaling_section, timed  # noqa: F401
except ImportError:  # run as a bare script: benchmarks/ is sys.path[0]
    from common import emit_scaling_rows, scaling_section, timed  # noqa: F401

from repro.core.fl_round import allocate_batched
from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest
from repro.launch.serve_chaos import SCENARIOS, run_chaos

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

TRACE_LEN = 200
TRACE_SEED = 0
BUCKETS = (8, 16, 64)
MAX_BATCH = 8
D_BITS, V_MAX, EPS = 200.0, 0.5, 0.05
PARITY_EVERY = 25          # re-solve every k-th request exactly


SCALING_TRACE_LEN = 64     # shorter trace replayed per scaling worker

OVERLOAD_REQS = 256        # one-bucket burst, ~2x the steady-state pressure
OVERLOAD_MAX_QUEUE = 32
OVERLOAD_HI_FRAC = 0.25    # fraction at priority 2 with a 1 s deadline
OVERLOAD_HI_DEADLINE_S = 1.0
HI_P99_BOUND_MS = 500.0    # claims-gated bound on hi-priority completion p99


def make_trace(rng, length: int = TRACE_LEN):
    """The mixed-N arrival trace: (n, h2, t_max) per request."""
    reqs = []
    for _ in range(length):
        u = rng.random()
        if u < 0.5:
            n = int(rng.integers(1, 9))          # small cells
        elif u < 0.8:
            n = int(rng.integers(9, 17))         # medium
        else:
            n = int(rng.integers(17, 65))        # large
        h2 = rng.uniform(0.2, 2.0, n).astype(np.float32)
        t_max = float(rng.uniform(0.8, 1.5))     # heterogeneous physics
        reqs.append((n, h2, t_max))
    return reqs


def exact_solve(h2, t_max):
    order = np.argsort(-h2, kind="stable")
    n = h2.shape[0]
    out = allocate_batched("proposed", GameConfig(t_max=t_max),
                           jnp.asarray(h2[order])[None, :],
                           jnp.full((1, n), D_BITS, jnp.float32),
                           jnp.full((1, n), V_MAX, jnp.float32),
                           epsilon=EPS)
    inv = np.empty_like(order)
    inv[order] = np.arange(n)
    return {"p": np.asarray(out.p)[0][inv], "q": np.asarray(out.q)[0][inv],
            "f": np.asarray(out.f)[0][inv],
            "energy": float(out.energy[0]), "t_total": float(out.t_total[0])}


def scaling_workload():
    """One ``--scaling-worker`` pass at the current (forced) device count:
    warm sustained rate over a short mixed-N trace, zero-retrace assert,
    and padded-vs-exact parity on a subsample."""
    rng = np.random.default_rng(TRACE_SEED + 1)
    trace = make_trace(rng, SCALING_TRACE_LEN)
    # degraded_retry off: the steady-state sections measure the PR-8
    # baseline path bit-identically (the trace's jittered t_max makes a
    # few large cells infeasible, and the default-on ladder would
    # re-dispatch them under the un-warmed oma scheme); the resilience
    # layer is measured by the overload/chaos sections instead
    svc = AllocationService(buckets=BUCKETS, max_batch=MAX_BATCH,
                            max_inflight=2, degraded_retry=False)
    svc.warmup(schemes=("proposed",))
    before = TRACE_COUNTS["serve_allocation"]
    t0 = time.perf_counter()
    for n, h2, t_max in trace:
        svc.submit(AllocRequest(h2=h2, d=D_BITS, v_max=V_MAX,
                                cfg=GameConfig(t_max=t_max), epsilon=EPS))
    results = sorted(svc.drain(), key=lambda r: r.rid)
    wall_s = time.perf_counter() - t0
    retraces = TRACE_COUNTS["serve_allocation"] - before
    assert retraces == 0, f"scaling stream retraced {retraces}x"
    parity = 0.0
    for rid in range(0, SCALING_TRACE_LEN, 8):
        _, h2, t_max = trace[rid]
        ref = exact_solve(h2, t_max)
        got = results[rid]
        for f in ("p", "q", "f"):
            a, b = np.asarray(getattr(got, f), np.float64), ref[f]
            parity = max(parity, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-12))))
    return {"serve": {
        "workload": f"mixed-N stream len={SCALING_TRACE_LEN} "
                    f"max_batch={MAX_BATCH} shards={svc.shards}",
        "rate": SCALING_TRACE_LEN / max(wall_s, 1e-12),
        "parity_max_rel": parity,
        "retraces_after_warm": int(retraces),
    }}


def overload_section():
    """Burst overload against a bounded-queue SLA service: 256 same-
    bucket requests submitted back-to-back (≈2x the pressure of the
    paced steady-state trace), 25% at priority 2 with a 1 s deadline.
    Every row must come back exactly once; high priority must keep a
    bounded completion p99 while low priority is allowed to shed."""
    rng = np.random.default_rng(TRACE_SEED + 2)
    svc = AllocationService(buckets=(BUCKETS[0],), max_batch=MAX_BATCH,
                            max_inflight=2, max_queue=OVERLOAD_MAX_QUEUE)
    svc.warmup(schemes=("proposed",))
    t0 = time.perf_counter()
    rids = []
    for _ in range(OVERLOAD_REQS):
        n = int(rng.integers(1, BUCKETS[0] + 1))
        hi = rng.random() < OVERLOAD_HI_FRAC
        rids.append(svc.submit(AllocRequest(
            h2=rng.uniform(0.2, 2.0, n).astype(np.float32),
            d=D_BITS, v_max=V_MAX, epsilon=EPS,
            priority=2 if hi else 0,
            deadline_s=OVERLOAD_HI_DEADLINE_S if hi else None)))
    results = svc.drain()
    wall_s = time.perf_counter() - t0
    statuses = {}
    for r in results:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    hi_done = [r.latency_s * 1e3 for r in results
               if r.priority == 2 and r.status in ("ok", "infeasible",
                                                   "timeout")]
    hi_p99 = float(np.percentile(np.asarray(hi_done), 99)) if hi_done \
        else float("nan")
    no_lost = (sorted(r.rid for r in results) == sorted(rids)
               and len(results) == len(rids))
    return {
        "requests": OVERLOAD_REQS,
        "max_queue": OVERLOAD_MAX_QUEUE,
        "hi_frac": OVERLOAD_HI_FRAC,
        "hi_deadline_s": OVERLOAD_HI_DEADLINE_S,
        "wall_s": round(wall_s, 4),
        "requests_per_sec": round(OVERLOAD_REQS / wall_s, 1),
        "statuses": statuses,
        "hi_completed": len(hi_done),
        "hi_p99_ms": round(hi_p99, 3),
        "hi_p99_bound_ms": HI_P99_BOUND_MS,
        "shed": int(svc.stats["shed"]),
        "admission_rejected": int(svc.stats["admission_rejected"]),
    }, no_lost, bool(hi_done) and hi_p99 <= HI_P99_BOUND_MS


def chaos_section():
    """The ``full_chaos`` scenario as a measured bench row.  One
    throwaway run first warms the scenario's executables (its service
    shape differs from the steady-state trace's) so the timed run and
    its injected ordinals land on steady-state dispatches."""
    run_chaos(SCENARIOS["full_chaos"])          # compile-cache warm
    t0 = time.perf_counter()
    rep = run_chaos(SCENARIOS["full_chaos"])
    wall_s = time.perf_counter() - t0
    return {
        "scenario": rep.scenario,
        "submitted": rep.submitted,
        "malformed_raised": rep.malformed_raised,
        "wall_s": round(wall_s, 4),
        "requests_per_sec": round(rep.submitted / wall_s, 1),
        "statuses": rep.status_counts,
        "injection": rep.injection,
        "hi_p99_ms": round(rep.hi_p99_ms(), 3),
        "lost": len(rep.lost_rids),
        "duplicates": len(rep.duplicate_rids),
        "nan_leaked_ok": rep.nan_leaked_ok,
    }, rep.exactly_once, rep.nan_leaked_ok == 0


def main():
    rng = np.random.default_rng(TRACE_SEED)
    trace = make_trace(rng)

    svc = AllocationService(buckets=BUCKETS, max_batch=MAX_BATCH,
                            max_inflight=2, degraded_retry=False)
    warmup_s = svc.warmup(schemes=("proposed",))
    traces_before = TRACE_COUNTS["serve_allocation"]

    t0 = time.perf_counter()
    for n, h2, t_max in trace:
        svc.submit(AllocRequest(h2=h2, d=D_BITS, v_max=V_MAX,
                                cfg=GameConfig(t_max=t_max), epsilon=EPS))
    results = sorted(svc.drain(), key=lambda r: r.rid)
    wall_s = time.perf_counter() - t0

    retraces = TRACE_COUNTS["serve_allocation"] - traces_before
    assert retraces == 0, f"warm stream retraced {retraces}x"
    assert len(results) == TRACE_LEN

    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    parity = 0.0
    for rid in range(0, TRACE_LEN, PARITY_EVERY):
        n, h2, t_max = trace[rid]
        ref = exact_solve(h2, t_max)
        got = results[rid]
        for f in ("p", "q", "f"):
            a, b = np.asarray(getattr(got, f), np.float64), ref[f]
            parity = max(parity, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-12))))
        for f in ("energy", "t_total"):
            parity = max(parity, abs(getattr(got, f) - ref[f]) /
                         max(abs(ref[f]), 1e-12))
    assert parity <= 1e-5, f"padded-bucket parity broke: {parity}"

    overload, ov_no_lost, ov_p99_ok = overload_section()
    chaos, ch_no_lost, ch_no_nan = chaos_section()

    doc = {
        "bench": "serve_latency",
        "trace": {"len": TRACE_LEN, "seed": TRACE_SEED,
                  "buckets": list(BUCKETS), "max_batch": MAX_BATCH,
                  "mix": "50% N in [1,8], 30% in [9,16], 20% in [17,64]"},
        "warmup_s": round(warmup_s, 3),
        "wall_s": round(wall_s, 4),
        "requests_per_sec": round(TRACE_LEN / wall_s, 1),
        "latency_ms": {"p50": round(float(np.percentile(lat_ms, 50)), 3),
                       "p99": round(float(np.percentile(lat_ms, 99)), 3),
                       "mean": round(float(lat_ms.mean()), 3),
                       "max": round(float(lat_ms.max()), 3)},
        "retraces_after_warm": int(retraces),
        "parity_max_rel": parity,
        "dispatches": int(svc.stats["dispatches"]),
        "padded_slots": int(svc.stats["padded_slots"]),
        "batch_shards": int(svc.shards),
        "batch_width": int(svc.batch_width),
        "overload": overload,
        "chaos": chaos,
        "claims": {
            "overload_no_lost_requests": ov_no_lost,
            "overload_hi_priority_p99_bounded": ov_p99_ok,
            "chaos_no_lost_requests": ch_no_lost,
            "chaos_no_nan_leak": ch_no_nan,
        },
        # these paths sleep on purpose (injected stalls, backoff) — 35%
        # noise window instead of the default 20%
        "tolerances": {"overload_rps": 0.35, "chaos_rps": 0.35},
        "scaling": scaling_section("benchmarks.serve_latency",
                                   gate_tiers=()),
    }
    out = os.path.join(REPO_ROOT, "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(json.dumps(doc, indent=2))
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    if "--scaling-worker" in sys.argv:
        emit_scaling_rows(scaling_workload())
    else:
        main()
