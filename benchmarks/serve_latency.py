"""Streaming allocation service latency/throughput bench → BENCH_serve.json.

Drives ``repro.launch.alloc_serve.AllocationService`` with a MIXED-N
ARRIVAL TRACE modelled on the dynamic-membership serving story (clients
join/drop every round, so cell sizes vary request to request):

  * ``TRACE_LEN`` requests, client counts drawn log-uniform-ish over the
    bucket range — 50% small cells (N ≤ 8), 30% medium (9–16), 20% large
    (17–64), matching the "many small cells, few big ones" shape of
    cellular deployments; seeded (default 0) so the trace is reproducible;
  * every request carries its own channel draw and a jittered ``t_max``
    (heterogeneous physics riding one bucket executable);
  * buckets 8/16/64, ``max_batch`` 8, double-buffered dispatch depth 2;
  * the service is warmed first (every bucket compiled), so the measured
    stream is the steady state a deployment runs in — the zero-retrace
    property is asserted, not assumed.

``BENCH_serve.json`` fields:

  * ``trace``               — {len, seed, buckets, max_batch, mix} of the
                              arrival trace (documented above);
  * ``warmup_s``            — one-time compile cost of the bucket set;
  * ``wall_s``              — submit-first → drain-complete wall seconds;
  * ``requests_per_sec``    — TRACE_LEN / wall_s, the sustained service
                              throughput (GATED by scripts/check_bench.py
                              at -20% vs the committed baseline);
  * ``latency_ms``          — {p50, p99, mean, max} per-request latency
                              (submit → result on host; recorded for the
                              ROADMAP but NOT gated — wall-clock
                              percentiles are too noisy on shared hosts);
  * ``retraces_after_warm`` — must be 0 (bucket executables are hit warm);
  * ``parity_max_rel``      — max relative |padded − exact-N| over p/q/f/
                              energy/t_total on a subsample of the trace
                              (the ≤1e-5 serving contract, re-checked in
                              the bench so the committed JSON carries the
                              measured number).

Run:  PYTHONPATH=src python benchmarks/serve_latency.py
      PYTHONPATH=src python -m benchmarks.serve_latency --devices 4

Scaling
-------
The ``scaling`` section replays a shorter mixed-N trace at 1, 2 and 4
forced host devices (``--scaling-worker D`` subprocesses): the service
shards its fixed ``[B, n_bucket]`` dispatch batch over the draw mesh, so
the section records the sustained request rate and padded-vs-exact
parity per device count.  Serving is latency-bound by the host round
trip, not device compute, so this tier is NOT efficiency-gated — the
rates document overhead, the parity and zero-retrace fields are the
contract.  On a 1-core container the rates measure sharding overhead
only — see ``benchmarks/common.py``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

import jax.numpy as jnp

try:
    from .common import emit_scaling_rows, scaling_section, timed  # noqa: F401
except ImportError:  # run as a bare script: benchmarks/ is sys.path[0]
    from common import emit_scaling_rows, scaling_section, timed  # noqa: F401

from repro.core.fl_round import allocate_batched
from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

TRACE_LEN = 200
TRACE_SEED = 0
BUCKETS = (8, 16, 64)
MAX_BATCH = 8
D_BITS, V_MAX, EPS = 200.0, 0.5, 0.05
PARITY_EVERY = 25          # re-solve every k-th request exactly


SCALING_TRACE_LEN = 64     # shorter trace replayed per scaling worker


def make_trace(rng, length: int = TRACE_LEN):
    """The mixed-N arrival trace: (n, h2, t_max) per request."""
    reqs = []
    for _ in range(length):
        u = rng.random()
        if u < 0.5:
            n = int(rng.integers(1, 9))          # small cells
        elif u < 0.8:
            n = int(rng.integers(9, 17))         # medium
        else:
            n = int(rng.integers(17, 65))        # large
        h2 = rng.uniform(0.2, 2.0, n).astype(np.float32)
        t_max = float(rng.uniform(0.8, 1.5))     # heterogeneous physics
        reqs.append((n, h2, t_max))
    return reqs


def exact_solve(h2, t_max):
    order = np.argsort(-h2, kind="stable")
    n = h2.shape[0]
    out = allocate_batched("proposed", GameConfig(t_max=t_max),
                           jnp.asarray(h2[order])[None, :],
                           jnp.full((1, n), D_BITS, jnp.float32),
                           jnp.full((1, n), V_MAX, jnp.float32),
                           epsilon=EPS)
    inv = np.empty_like(order)
    inv[order] = np.arange(n)
    return {"p": np.asarray(out.p)[0][inv], "q": np.asarray(out.q)[0][inv],
            "f": np.asarray(out.f)[0][inv],
            "energy": float(out.energy[0]), "t_total": float(out.t_total[0])}


def scaling_workload():
    """One ``--scaling-worker`` pass at the current (forced) device count:
    warm sustained rate over a short mixed-N trace, zero-retrace assert,
    and padded-vs-exact parity on a subsample."""
    rng = np.random.default_rng(TRACE_SEED + 1)
    trace = make_trace(rng, SCALING_TRACE_LEN)
    svc = AllocationService(buckets=BUCKETS, max_batch=MAX_BATCH,
                            max_inflight=2)
    svc.warmup(schemes=("proposed",))
    before = TRACE_COUNTS["serve_allocation"]
    t0 = time.perf_counter()
    for n, h2, t_max in trace:
        svc.submit(AllocRequest(h2=h2, d=D_BITS, v_max=V_MAX,
                                cfg=GameConfig(t_max=t_max), epsilon=EPS))
    results = sorted(svc.drain(), key=lambda r: r.rid)
    wall_s = time.perf_counter() - t0
    retraces = TRACE_COUNTS["serve_allocation"] - before
    assert retraces == 0, f"scaling stream retraced {retraces}x"
    parity = 0.0
    for rid in range(0, SCALING_TRACE_LEN, 8):
        _, h2, t_max = trace[rid]
        ref = exact_solve(h2, t_max)
        got = results[rid]
        for f in ("p", "q", "f"):
            a, b = np.asarray(getattr(got, f), np.float64), ref[f]
            parity = max(parity, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-12))))
    return {"serve": {
        "workload": f"mixed-N stream len={SCALING_TRACE_LEN} "
                    f"max_batch={MAX_BATCH} shards={svc.shards}",
        "rate": SCALING_TRACE_LEN / max(wall_s, 1e-12),
        "parity_max_rel": parity,
        "retraces_after_warm": int(retraces),
    }}


def main():
    rng = np.random.default_rng(TRACE_SEED)
    trace = make_trace(rng)

    svc = AllocationService(buckets=BUCKETS, max_batch=MAX_BATCH,
                            max_inflight=2)
    warmup_s = svc.warmup(schemes=("proposed",))
    traces_before = TRACE_COUNTS["serve_allocation"]

    t0 = time.perf_counter()
    for n, h2, t_max in trace:
        svc.submit(AllocRequest(h2=h2, d=D_BITS, v_max=V_MAX,
                                cfg=GameConfig(t_max=t_max), epsilon=EPS))
    results = sorted(svc.drain(), key=lambda r: r.rid)
    wall_s = time.perf_counter() - t0

    retraces = TRACE_COUNTS["serve_allocation"] - traces_before
    assert retraces == 0, f"warm stream retraced {retraces}x"
    assert len(results) == TRACE_LEN

    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    parity = 0.0
    for rid in range(0, TRACE_LEN, PARITY_EVERY):
        n, h2, t_max = trace[rid]
        ref = exact_solve(h2, t_max)
        got = results[rid]
        for f in ("p", "q", "f"):
            a, b = np.asarray(getattr(got, f), np.float64), ref[f]
            parity = max(parity, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-12))))
        for f in ("energy", "t_total"):
            parity = max(parity, abs(getattr(got, f) - ref[f]) /
                         max(abs(ref[f]), 1e-12))
    assert parity <= 1e-5, f"padded-bucket parity broke: {parity}"

    doc = {
        "bench": "serve_latency",
        "trace": {"len": TRACE_LEN, "seed": TRACE_SEED,
                  "buckets": list(BUCKETS), "max_batch": MAX_BATCH,
                  "mix": "50% N in [1,8], 30% in [9,16], 20% in [17,64]"},
        "warmup_s": round(warmup_s, 3),
        "wall_s": round(wall_s, 4),
        "requests_per_sec": round(TRACE_LEN / wall_s, 1),
        "latency_ms": {"p50": round(float(np.percentile(lat_ms, 50)), 3),
                       "p99": round(float(np.percentile(lat_ms, 99)), 3),
                       "mean": round(float(lat_ms.mean()), 3),
                       "max": round(float(lat_ms.max()), 3)},
        "retraces_after_warm": int(retraces),
        "parity_max_rel": parity,
        "dispatches": int(svc.stats["dispatches"]),
        "padded_slots": int(svc.stats["padded_slots"]),
        "batch_shards": int(svc.shards),
        "batch_width": int(svc.batch_width),
        "scaling": scaling_section("benchmarks.serve_latency",
                                   gate_tiers=()),
    }
    out = os.path.join(REPO_ROOT, "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(json.dumps(doc, indent=2))
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    if "--scaling-worker" in sys.argv:
        emit_scaling_rows(scaling_workload())
    else:
        main()
