"""Stackelberg equilibrium engine throughput — solves/sec for the three
execution paths at K ∈ {1, 64, 1024} independent 5-client realizations:

  * legacy — ``equilibrium_eager``: host-side Python loop, per-iteration
    ``float()``/``bool()`` device syncs, one instance at a time;
  * jit    — ``equilibrium``: the whole Alg.-2 alternation as one XLA
    program, still dispatched per instance;
  * vmap   — ``batched_equilibrium``: all K realizations in ONE XLA call.

Writes ``BENCH_equilibrium.json`` (repo root) so later PRs can track the
throughput trajectory; the legacy path is measured on a subsample at large
K (it is the slow baseline — running it 1024× would dominate the bench).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from .common import mc_channel_draws

N_CLIENTS = 5
K_VALUES = (1, 64, 1024)
LEGACY_CAP = 16          # legacy instances actually timed at large K
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_equilibrium.json")


def _inputs(k: int):
    key = jax.random.PRNGKey(1234)
    h2 = mc_channel_draws(key, k, N_CLIENTS)
    d = 100.0 + 200.0 * jax.random.uniform(jax.random.fold_in(key, 1),
                                           (k, N_CLIENTS))
    vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 2),
                                          (k, N_CLIENTS))
    return h2, d, vmax


def _rate(elapsed_s: float, solves: int) -> float:
    return solves / max(elapsed_s, 1e-12)


def run():
    from repro.core.stackelberg import (GameConfig, batched_equilibrium,
                                        equilibrium, equilibrium_eager)
    cfg = GameConfig()
    t_start = time.perf_counter()
    results = []
    for k in K_VALUES:
        h2, d, vmax = _inputs(k)

        # legacy eager loop (subsampled at large K — it is the baseline)
        k_legacy = min(k, LEGACY_CAP)
        equilibrium_eager(cfg, h2[0], d[0], vmax[0])        # warm caches
        t0 = time.perf_counter()
        for i in range(k_legacy):
            equilibrium_eager(cfg, h2[i], d[i], vmax[i])
        legacy_sps = _rate(time.perf_counter() - t0, k_legacy)

        # jitted engine, dispatched per instance
        k_jit = min(k, 64)
        jax.block_until_ready(equilibrium(cfg, h2[0], d[0], vmax[0]).energy)
        t0 = time.perf_counter()
        for i in range(k_jit):
            out = equilibrium(cfg, h2[i], d[i], vmax[i])
        jax.block_until_ready(out.energy)
        jit_sps = _rate(time.perf_counter() - t0, k_jit)

        # vmapped engine: one XLA call for all K
        out = batched_equilibrium(cfg, h2, d, vmax)
        jax.block_until_ready(out.energy)                   # compile + warm
        t0 = time.perf_counter()
        out = batched_equilibrium(cfg, h2, d, vmax)
        jax.block_until_ready(out.energy)
        vmap_sps = _rate(time.perf_counter() - t0, k)
        assert bool(jnp.all(jnp.isfinite(out.energy))), "non-finite energies"

        results.append({
            "K": k,
            "n_clients": N_CLIENTS,
            "legacy_solves_per_sec": round(legacy_sps, 2),
            "legacy_measured_on": k_legacy,
            "jit_solves_per_sec": round(jit_sps, 2),
            "jit_measured_on": k_jit,
            "vmap_solves_per_sec": round(vmap_sps, 2),
            "speedup_jit_vs_legacy": round(jit_sps / legacy_sps, 2),
            "speedup_vmap_vs_legacy": round(vmap_sps / legacy_sps, 2),
        })

    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "stackelberg_equilibrium_throughput",
                   "results": results}, f, indent=2)

    elapsed_us = (time.perf_counter() - t_start) * 1e6
    big = results[-1]
    return [("equilibrium_throughput", elapsed_us,
             f"K={big['K']};legacy_sps={big['legacy_solves_per_sec']};"
             f"jit_sps={big['jit_solves_per_sec']};"
             f"vmap_sps={big['vmap_solves_per_sec']};"
             f"vmap_speedup={big['speedup_vmap_vs_legacy']}x;"
             f"target_20x_met={big['speedup_vmap_vs_legacy'] >= 20}")]


if __name__ == "__main__":
    for row in run():
        print(row)
