"""Stackelberg equilibrium engine throughput — solves/sec for the three
execution paths at K ∈ {1, 64, 1024} independent 5-client realizations:

  * legacy — ``equilibrium_eager``: host-side Python loop, per-iteration
    ``float()``/``bool()`` device syncs, one instance at a time;
  * jit    — ``equilibrium``: the whole Alg.-2 alternation as one XLA
    program, still dispatched per instance;
  * vmap   — ``batched_equilibrium``: all K realizations in ONE XLA call;

plus an ``n_scaling`` section in two parts: the historical small-N rows
(N ∈ {5, 10, 20, 40, 64}) profiling the reverse ``lax.scan`` in
``successive_power`` (interference suffix-sum + per-client Dinkelbach
chain, inherently sequential in N), and large-N head-to-head rows
(N ∈ {64, 128, 256, 512, 1024}) comparing that sequential chain against
the blocked Jacobi fixed-point engine (``sic_mode="blocked"``,
``repro.core.sic``) and the Pallas suffix-kernel interpret path — the
data behind the ROADMAP's sequential-vs-blocked crossover claim;

plus a ``sweep`` section timing the fig9-style config grid (10 points ×
K=256 draws):

  * static — the PR-1 design re-created locally: physics floats as STATIC
    jit args, so every grid point pays a fresh XLA compile (timed cold —
    that compile tax was the real cost of a sweep);
  * sweep  — ``sweep_equilibrium``: physics as traced ``GamePhysics`` rows,
    the whole grid in one dispatch of one executable (timed cold = compile
    + run, and warm), with the recompile counts and device layout recorded.

Writes ``BENCH_equilibrium.json`` (repo root) so later PRs can track the
throughput trajectory (``scripts/check_bench.py`` gates on it); the legacy
path is measured on a subsample at large K (it is the slow baseline —
running it 1024× would dominate the bench).

Scaling
-------
The ``scaling`` section measures the vmap tier (``batched_equilibrium``,
K=8192 Monte-Carlo draws on the 1D draw mesh) and the sweep tier
(``sweep_equilibrium``, C=10 × K=2048 on the 2D (cfg, draw) mesh) at 1, 2
and 4 forced host devices, each in its own worker subprocess
(``--scaling-worker D``).  Both tiers are efficiency-gated at ≥70% by
``scripts/check_bench.py`` and carry sharded-vs-per-instance parity
(≤1e-5).  On this 1-core container the quotient measures sharding-overhead
retention, not wall-clock speedup — see ``benchmarks/common.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp

from .common import (emit_scaling_rows, mc_channel_draws, scaling_section)

N_CLIENTS = 5
K_VALUES = (1, 64, 1024)
LEGACY_CAP = 16          # legacy instances actually timed at large K
SWEEP_K = 256            # draws per config point in the sweep section
N_SCALING = (5, 10, 20, 40, 64)   # client counts for the N-scaling profile
N_SCALING_K = 48   # draws per point — NOT one of K_VALUES, so the (N=5, K)
                   # shape is a fresh compile key and compile_wall_s is a
                   # real measurement (K=64 was pre-warmed by the K sweep)
# large-N rows: sequential reverse-scan vs blocked Jacobi sweeps (ISSUE 5);
# K shrinks with N to keep the sequential baseline's wall time sane
N_SCALING_LARGE = ((64, 48), (128, 32), (256, 16), (512, 8), (1024, 8))
N_INTERPRET = (64, 128)  # Pallas-interpret validation path timed only at
                         # small N (the interpreter emulates the kernel
                         # op-by-op — a correctness tier, not a perf tier)
SWEEP_TMAX = (4.0, 6.0, 8.0, 10.0, 12.0)
SWEEP_MBITS = (0.5e6, 2.0e6)     # × SWEEP_TMAX → the 10-point fig9 grid
SCALING_VMAP_K = 8192            # draws in the scaling vmap tier
SCALING_SWEEP = (10, 2048)       # (C, K) of the scaling sweep tier
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_equilibrium.json")


def _inputs(k: int):
    key = jax.random.PRNGKey(1234)
    h2 = mc_channel_draws(key, k, N_CLIENTS)
    d = 100.0 + 200.0 * jax.random.uniform(jax.random.fold_in(key, 1),
                                           (k, N_CLIENTS))
    vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 2),
                                          (k, N_CLIENTS))
    return h2, d, vmax


def _rate(elapsed_s: float, solves: int) -> float:
    return solves / max(elapsed_s, 1e-12)


def _sweep_section():
    """Time the 10-point fig9 grid × K=256: per-config static-jit re-creation
    (one compile per point, the PR-1 design) vs the traced-config sweep
    engine (one compile for the whole grid)."""
    from repro.core.stackelberg import (GameConfig, TRACE_COUNTS, _solve,
                                        sharding_layout, sweep_equilibrium)
    from repro.sharding import game_mesh
    base = GameConfig()
    configs = [dataclasses.replace(base, t_max=tm, model_bits=mb)
               for mb in SWEEP_MBITS for tm in SWEEP_TMAX]
    h2 = mc_channel_draws(jax.random.PRNGKey(77), SWEEP_K, N_CLIENTS)
    d = jnp.full((N_CLIENTS,), 200.0)
    vmax = jnp.full((N_CLIENTS,), 0.5)
    n_solves = len(configs) * SWEEP_K

    # PR-1 design, re-created: the hashable GameConfig is the jit cache key,
    # so every distinct physics point compiles its own executable.
    @partial(jax.jit, static_argnames=("cfg", "max_iter"))
    def per_config_static(cfg, h2_b, d_b, vm_b, tol, max_iter=20):
        one = lambda h, dd, vm: _solve(cfg, h, dd, vm, 0.0, max_iter, tol,
                                       cfg.dinkelbach_inner)
        return jax.vmap(one)(h2_b, d_b, vm_b)

    d_b = jnp.broadcast_to(d, (SWEEP_K, N_CLIENTS))
    vm_b = jnp.broadcast_to(vmax, (SWEEP_K, N_CLIENTS))
    tol = jnp.asarray(1e-6, h2.dtype)
    t0 = time.perf_counter()
    for cfg in configs:           # cold: 10 compiles — the real sweep cost
        out = per_config_static(cfg, h2, d_b, vm_b, tol)
    jax.block_until_ready(out.energy)
    t_static = time.perf_counter() - t0

    before = TRACE_COUNTS["sweep_equilibrium"]
    t0 = time.perf_counter()
    out = sweep_equilibrium(configs, h2, d, vmax)
    jax.block_until_ready(out.energy)
    t_sweep_cold = time.perf_counter() - t0
    # warm path: best of 5 — this feeds a gated solves/sec metric and a
    # single ~10 ms sample would make the -20% gate flaky by construction
    t_sweep_warm = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        out = sweep_equilibrium(configs, h2, d, vmax)
        jax.block_until_ready(out.energy)
        t_sweep_warm = min(t_sweep_warm, time.perf_counter() - t0)
    assert bool(jnp.all(jnp.isfinite(out.energy))), "non-finite sweep energy"
    recompiles = TRACE_COUNTS["sweep_equilibrium"] - before

    return {
        "config_points": len(configs),
        "K": SWEEP_K,
        "n_clients": N_CLIENTS,
        "grid": "t_max x model_bits (fig9-style)",
        "static_jit_wall_s": round(t_static, 3),
        "static_jit_solves_per_sec": round(_rate(t_static, n_solves), 2),
        "sweep_cold_wall_s": round(t_sweep_cold, 3),
        "sweep_warm_wall_s": round(t_sweep_warm, 3),
        "sweep_solves_per_sec": round(_rate(t_sweep_warm, n_solves), 2),
        "speedup_sweep_cold_vs_static": round(t_static / t_sweep_cold, 2),
        "speedup_sweep_warm_vs_static": round(t_static / t_sweep_warm, 2),
        "sweep_recompiles": int(recompiles),
        "devices": len(jax.devices()),
        "k_axis_shards": sharding_layout(SWEEP_K),
        "grid_shards": list(game_mesh.grid_layout(len(configs), SWEEP_K)),
    }


def _n_scaling_section():
    """Profile ``batched_equilibrium`` at K=64 across client counts N —
    paper uses N=5, but larger cells stress the reverse ``lax.scan`` in
    ``successive_power`` whose carry (the SIC interference prefix-sum)
    serializes the per-client Dinkelbach solves.  ``client_solves_per_sec``
    (= K·N / wall) is the normalized rate: if the prefix-sum chain
    dominates, it degrades with N instead of holding flat, which is the
    signal for moving it into a Pallas kernel (ROADMAP open item)."""
    from repro.core.stackelberg import GameConfig, batched_equilibrium
    cfg = GameConfig()
    rows = []
    for n in N_SCALING:
        key = jax.random.PRNGKey(4000 + n)
        h2 = mc_channel_draws(key, N_SCALING_K, n)
        d = 100.0 + 200.0 * jax.random.uniform(jax.random.fold_in(key, 1),
                                               (N_SCALING_K, n))
        vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 2),
                                              (N_SCALING_K, n))
        t0 = time.perf_counter()
        out = batched_equilibrium(cfg, h2, d, vmax)
        jax.block_until_ready(out.energy)
        cold_s = time.perf_counter() - t0
        warm_s = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            out = batched_equilibrium(cfg, h2, d, vmax)
            jax.block_until_ready(out.energy)
            warm_s = min(warm_s, time.perf_counter() - t0)
        assert bool(jnp.all(jnp.isfinite(out.energy))), f"N={n}"
        rows.append({
            "N": n,
            "K": N_SCALING_K,
            "compile_wall_s": round(cold_s - warm_s, 3),
            "warm_wall_s": round(warm_s, 4),
            "solves_per_sec": round(_rate(warm_s, N_SCALING_K), 2),
            "client_solves_per_sec": round(_rate(warm_s, N_SCALING_K * n), 2),
            "us_per_client_per_solve": round(warm_s / (N_SCALING_K * n) * 1e6,
                                             3),
        })
    return rows


def _time_batched(cfg, h2, d, vmax, reps: int = 3):
    """(cold_s, warm_s) for one ``batched_equilibrium`` workload."""
    from repro.core.stackelberg import batched_equilibrium
    t0 = time.perf_counter()
    out = batched_equilibrium(cfg, h2, d, vmax)
    jax.block_until_ready(out.energy)
    cold_s = time.perf_counter() - t0
    warm_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = batched_equilibrium(cfg, h2, d, vmax)
        jax.block_until_ready(out.energy)
        warm_s = min(warm_s, time.perf_counter() - t0)
    assert bool(jnp.all(jnp.isfinite(out.energy)))
    return cold_s, warm_s, out


def _n_scaling_large_section():
    """Head-to-head at N ∈ {64 … 1024}: the sequential reverse-scan SIC
    chain vs the blocked Jacobi fixed-point engine (``sic_mode="blocked"``,
    same fixed point — parity asserted here too), plus the Pallas
    suffix-kernel interpret path at small N as a validation tier.

    Two workloads per row: the K-draw Monte-Carlo batch (throughput — the
    vmapped sequential scan amortizes its N serial steps across the K
    lanes, so it holds on longer here) and the single-instance K=1 solve
    (latency — nothing amortizes the serial chain, the regime where the
    blocked engine wins on this container).  This is the measurement
    behind the ROADMAP's crossover discussion; ``scripts/check_bench.py``
    gates the blocked rates at −20%."""
    from repro.core.stackelberg import GameConfig
    cfg_seq = GameConfig()
    cfg_blk = dataclasses.replace(cfg_seq, sic_mode="blocked")
    rows = []
    for n, k in N_SCALING_LARGE:
        key = jax.random.PRNGKey(9000 + n)
        h2 = mc_channel_draws(key, k, n)
        d = 100.0 + 200.0 * jax.random.uniform(jax.random.fold_in(key, 1),
                                               (k, n))
        vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 2),
                                              (k, n))
        _, seq_s, seq_out = _time_batched(cfg_seq, h2, d, vmax)
        blk_cold, blk_s, blk_out = _time_batched(cfg_blk, h2, d, vmax)
        rel = float(jnp.max(jnp.abs(blk_out.energy - seq_out.energy)
                            / jnp.maximum(jnp.abs(seq_out.energy), 1e-12)))
        # ≤1e-5 parity holds at the successive_power level (test_sic.py);
        # the full Alg-2 alternation's energy-change stopping rule can
        # amplify ~1e-7 solver residue into a DIFFERENT valid stopping
        # iterate on infeasible draws (both paths keep their best-iterate
        # safeguard), so the equilibrium-level drift bound is looser
        assert rel < 1e-3, f"blocked/sequential energy drift {rel} at N={n}"
        # single-instance latency: K=1 slices of the same draws
        _, seq1_s, _ = _time_batched(cfg_seq, h2[:1], d[:1], vmax[:1],
                                     reps=5)
        _, blk1_s, _ = _time_batched(cfg_blk, h2[:1], d[:1], vmax[:1],
                                     reps=5)
        row = {
            "N": n,
            "K": k,
            "seq_solves_per_sec": round(_rate(seq_s, k), 2),
            "blocked_solves_per_sec": round(_rate(blk_s, k), 2),
            "blocked_compile_wall_s": round(blk_cold - blk_s, 3),
            "speedup_blocked_vs_seq": round(seq_s / blk_s, 2),
            "seq_k1_latency_ms": round(seq1_s * 1e3, 3),
            "blocked_k1_latency_ms": round(blk1_s * 1e3, 3),
            "speedup_blocked_vs_seq_k1": round(seq1_s / blk1_s, 2),
            "energy_rel_err": float(f"{rel:.2e}"),
        }
        if n in N_INTERPRET:
            cfg_int = dataclasses.replace(cfg_seq,
                                          sic_mode="blocked_interpret")
            _, int_s, _ = _time_batched(cfg_int, h2, d, vmax, reps=1)
            row["blocked_interpret_solves_per_sec"] = round(_rate(int_s, k),
                                                            2)
        rows.append(row)
    return rows


def scaling_workload():
    """One ``--scaling-worker`` pass at the current (forced) device count:
    warm rates for the vmap and sweep tiers plus sharded-vs-per-instance
    parity on sampled draws (host numpy — sharded and single-device
    outputs live on different meshes and cannot mix in one jnp op)."""
    import numpy as np
    from repro.core.stackelberg import (GameConfig, equilibrium,
                                        sweep_equilibrium)
    cfg = GameConfig()
    rows = {}

    k = SCALING_VMAP_K
    h2, d, vmax = _inputs(k)
    # reps=5: the warm dispatch is ~8 ms, so the best-of needs more draws
    # than the default 3 for a stable efficiency quotient on 1 core
    _, warm_s, out = _time_batched(cfg, h2, d, vmax, reps=5)
    en = np.asarray(jax.device_get(out.energy))
    rel = 0.0
    for i in np.linspace(0, k - 1, 4).astype(int):
        ref = float(equilibrium(cfg, h2[i], d[i], vmax[i]).energy)
        rel = max(rel, abs(float(en[i]) - ref) / max(abs(ref), 1e-12))
    rows["vmap"] = {
        "workload": f"batched_equilibrium K={k} N={N_CLIENTS}",
        "rate": _rate(warm_s, k),
        "parity_max_rel": float(rel),
    }

    c, ks = SCALING_SWEEP
    configs = [dataclasses.replace(cfg, t_max=tm, model_bits=mb)
               for mb in SWEEP_MBITS for tm in SWEEP_TMAX][:c]
    h2s = mc_channel_draws(jax.random.PRNGKey(5150), ks, N_CLIENTS)
    d1 = jnp.full((N_CLIENTS,), 200.0)
    vm1 = jnp.full((N_CLIENTS,), 0.5)
    out = sweep_equilibrium(configs, h2s, d1, vm1)
    jax.block_until_ready(out.energy)
    warm_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = sweep_equilibrium(configs, h2s, d1, vm1)
        jax.block_until_ready(out.energy)
        warm_s = min(warm_s, time.perf_counter() - t0)
    en = np.asarray(jax.device_get(out.energy))
    rel = 0.0
    for ci, ki in ((0, 0), (c // 2, ks // 2), (c - 1, ks - 1)):
        ref = float(equilibrium(configs[ci], h2s[ki], d1, vm1).energy)
        rel = max(rel, abs(float(en[ci, ki]) - ref) / max(abs(ref), 1e-12))
    rows["sweep"] = {
        "workload": f"sweep_equilibrium C={c} K={ks} N={N_CLIENTS}",
        "rate": _rate(warm_s, c * ks),
        "parity_max_rel": float(rel),
    }
    return rows


def run():
    from repro.core.stackelberg import (GameConfig, batched_equilibrium,
                                        equilibrium, equilibrium_eager)
    cfg = GameConfig()
    t_start = time.perf_counter()
    results = []
    for k in K_VALUES:
        h2, d, vmax = _inputs(k)

        # All three paths take the best of REPS timed passes: a single
        # pass on a shared box is dominated by scheduler noise, and mixing
        # methodologies (best-of-N vs one-shot) would skew the tracked
        # speedup ratios that scripts/check_bench.py gates on.
        REPS = 3

        # legacy eager loop (subsampled at large K — it is the baseline)
        k_legacy = min(k, LEGACY_CAP)
        equilibrium_eager(cfg, h2[0], d[0], vmax[0])        # warm caches
        legacy_sps = 0.0
        for _ in range(REPS):
            t0 = time.perf_counter()
            for i in range(k_legacy):
                equilibrium_eager(cfg, h2[i], d[i], vmax[i])
            legacy_sps = max(legacy_sps,
                             _rate(time.perf_counter() - t0, k_legacy))

        # jitted engine, dispatched per instance
        k_jit = min(k, 64)
        jax.block_until_ready(equilibrium(cfg, h2[0], d[0], vmax[0]).energy)
        jit_sps = 0.0
        for _ in range(REPS):
            t0 = time.perf_counter()
            for i in range(k_jit):
                out = equilibrium(cfg, h2[i], d[i], vmax[i])
            jax.block_until_ready(out.energy)
            jit_sps = max(jit_sps, _rate(time.perf_counter() - t0, k_jit))

        # vmapped engine: one XLA call for all K.  Best-of-5 repetitions:
        # a single rep is dominated by scheduler noise at small K on a
        # shared box (the gate in scripts/check_bench.py needs a stable
        # number, not one lucky/unlucky dispatch).
        out = batched_equilibrium(cfg, h2, d, vmax)
        jax.block_until_ready(out.energy)                   # compile + warm
        vmap_sps = 0.0
        for _ in range(5):
            t0 = time.perf_counter()
            out = batched_equilibrium(cfg, h2, d, vmax)
            jax.block_until_ready(out.energy)
            vmap_sps = max(vmap_sps, _rate(time.perf_counter() - t0, k))
        assert bool(jnp.all(jnp.isfinite(out.energy))), "non-finite energies"

        results.append({
            "K": k,
            "n_clients": N_CLIENTS,
            "legacy_solves_per_sec": round(legacy_sps, 2),
            "legacy_measured_on": k_legacy,
            "jit_solves_per_sec": round(jit_sps, 2),
            "jit_measured_on": k_jit,
            "vmap_solves_per_sec": round(vmap_sps, 2),
            "speedup_jit_vs_legacy": round(jit_sps / legacy_sps, 2),
            "speedup_vmap_vs_legacy": round(vmap_sps / legacy_sps, 2),
        })

    sweep = _sweep_section()
    # one n_scaling section: the historical small-N sequential profile rows
    # followed by the large-N sequential-vs-blocked head-to-head rows
    n_scaling = _n_scaling_section() + _n_scaling_large_section()
    # noise at the 0.15 cap: the warm K=8192 vmap dispatch is ~8 ms, so
    # best-of-5 timings still swing ~±0.1 efficiency on this 1-core box
    # (measured 0.58–0.85 across quiet back-to-back worker runs)
    scaling = scaling_section("benchmarks.equilibrium_throughput",
                              gate_tiers=("vmap", "sweep"),
                              efficiency_noise=0.15)

    with open(BENCH_JSON, "w") as f:
        json.dump({"bench": "stackelberg_equilibrium_throughput",
                   "results": results, "sweep": sweep,
                   "n_scaling": n_scaling, "scaling": scaling}, f, indent=2)

    elapsed_us = (time.perf_counter() - t_start) * 1e6
    big = results[-1]
    big_n = n_scaling[-1]     # the N=1024 sequential-vs-blocked row
    return [("equilibrium_throughput", elapsed_us,
             f"K={big['K']};legacy_sps={big['legacy_solves_per_sec']};"
             f"jit_sps={big['jit_solves_per_sec']};"
             f"vmap_sps={big['vmap_solves_per_sec']};"
             f"vmap_speedup={big['speedup_vmap_vs_legacy']}x;"
             f"target_20x_met={big['speedup_vmap_vs_legacy'] >= 20};"
             f"sweep_recompiles={sweep['sweep_recompiles']};"
             f"sweep_vs_static={sweep['speedup_sweep_cold_vs_static']}x;"
             f"sweep_5x_met={sweep['speedup_sweep_cold_vs_static'] >= 5};"
             f"blocked_n{big_n['N']}_sps={big_n['blocked_solves_per_sec']};"
             f"blocked_vs_seq_n{big_n['N']}="
             f"{big_n['speedup_blocked_vs_seq']}x;"
             f"blocked_vs_seq_n{big_n['N']}_k1="
             f"{big_n['speedup_blocked_vs_seq_k1']}x;"
             f"scaling_eff_vmap="
             f"{scaling['tiers']['vmap']['efficiency_at_max']:.2f};"
             f"scaling_eff_sweep="
             f"{scaling['tiers']['sweep']['efficiency_at_max']:.2f}")]


if __name__ == "__main__":
    if "--scaling-worker" in sys.argv:
        emit_scaling_rows(scaling_workload())
    else:
        for row in run():
            print(row)
