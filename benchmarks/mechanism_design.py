"""Learned mechanism design vs the paper's hand-picked knobs (ISSUE 10
tentpole bench).

Two stages, one doc (``BENCH_mechanism.json``):

  tune — AdamW on ``core.mechanism``'s objective, END-TO-END through the
  solved Stackelberg equilibria via the IFT ``custom_vjp``
  (``core.implicit``).  Tuning starts AT the paper's hand-picked point
  (ξ = (0.3, 0.5, 0.2), ε = 10, RONI threshold = 0.02) so the objective
  delta is attributable to learning; the whole run is ONE jitted step
  re-dispatched (``TRACE_COUNTS['mechanism_step'] == 1``).

  evaluate — the learned knobs routed through the REAL training engine:
  ``to_fl_ops`` → ``sweep_training(..., ops_override=...)`` with the
  learned and hand-picked points riding the config axis of ONE dispatch,
  on a 30%-poisoned federation (the mechanism's own threat model).

Writes ``BENCH_mechanism.json`` with:
  * ``grad_steps_per_sec`` — throughput of the jitted
    value_and_grad-through-the-game step, gated by
    ``scripts/check_bench.py`` at the declared −35% tolerance (container
    wall-clock noise, CHANGES.md PR 4);
  * ``claims`` — booleans the gate FAILS on when false:
      - the learned knobs beat the hand-picked objective (the tentpole
        headline: gradient descent through the game finds a better
        mechanism than the paper's constants);
      - every gradient leaf of the first step is finite (no NaN
        cotangents through the IFT);
      - the tuning run compiled exactly once;
      - the learned mechanism's defended accuracy on the real engine
        stays within 5 pts of the hand-picked mechanism's (learning the
        proxy objective must not wreck the actual trajectory);
      - learned rewards pay honest clients more than attackers
        (incentive separation).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.fl_round import FLConfig, stack_states, sweep_training
from repro.core.mechanism import (MechanismStatics, init_params,
                                  mechanism_step, params_to_knobs,
                                  synthetic_context, to_fl_ops,
                                  tune_mechanism)
from repro.core.stackelberg import GameConfig, TRACE_COUNTS
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.optim.adamw import init_opt_state

from .common import fl_setup, save_csv

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_mechanism.json")

M, K_DRAWS = 20, 4
TUNE_STEPS = 60
EVAL_ROUNDS = 12
EVAL_SEEDS = (7, 8)
POISON = 0.3
STATICS = MechanismStatics(n_selected=5)


def _final_acc(val_acc):
    """[C, S, R] → [C]: mean over seeds of the max of the last 5 rounds."""
    return jnp.mean(jnp.max(val_acc[:, :, -5:], axis=-1), axis=-1)


def run():
    t0 = time.perf_counter()
    ctx = synthetic_context(jax.random.PRNGKey(0), m=M, k_draws=K_DRAWS)
    params = init_params(M)

    # --- tune: grads through the game, throughput of the jitted step ----
    before = TRACE_COUNTS["mechanism_step"]
    opt = init_opt_state(params, STATICS.adamw)
    p1, o1, j0, grads = mechanism_step(params, opt, ctx, STATICS)  # compile
    jax.block_until_ready(j0)
    grads_finite = all(bool(jnp.all(jnp.isfinite(leaf)))
                       for leaf in jax.tree_util.tree_leaves(grads))
    t_grad = time.perf_counter()
    n_timed = 10
    pp, oo = p1, o1
    for _ in range(n_timed):
        pp, oo, j, _ = mechanism_step(pp, oo, ctx, STATICS)
    jax.block_until_ready(j)
    grad_steps_per_sec = n_timed / (time.perf_counter() - t_grad)

    tuned, hist = tune_mechanism(params, ctx, STATICS, steps=TUNE_STEPS)
    traces = TRACE_COUNTS["mechanism_step"] - before
    knobs = {k: (v.tolist() if hasattr(v, "tolist") else float(v))
             for k, v in hist["knobs"].items()}
    j_hand, j_learn = hist["objective"][0], hist["objective"][-1]

    # --- evaluate through the REAL engine: learned vs hand-picked knobs
    # ride the config axis of ONE sweep dispatch (ops_override leaves
    # carry the [C=2] axis)
    states = stack_states([fl_setup(s, m=M, cap=128,
                                    poison_ratio=POISON)[0]
                           for s in EVAL_SEEDS])
    logits_fn = fl_setup(EVAL_SEEDS[0], m=M, cap=128)[2]
    data = make_federated_data(jax.random.PRNGKey(1234), SYNTHETIC_MNIST,
                               m=M, cap=128, poison_ratio=POISON)
    base = FLConfig(n_selected=5, local_steps=20, server_steps=20, lr=0.1)
    hand_ops = to_fl_ops(init_params(M))
    learn_ops = to_fl_ops(tuned)
    ops_c = {k: jnp.stack([hand_ops[k], learn_ops[k]]) for k in hand_ops}
    _, met = sweep_training(states, data, [base, base],
                            [GameConfig(), GameConfig()], logits_fn,
                            EVAL_ROUNDS, ops_override=ops_c)
    acc = _final_acc(met["val_acc"])            # [C=2]
    energy = jnp.mean(met["energy"], axis=(1, 2))
    acc_hand, acc_learn = float(acc[0]), float(acc[1])
    elapsed = time.perf_counter() - t0

    r = jnp.asarray(hist["knobs"]["rewards"])
    n_bad = int(round(0.25 * M))
    claims = {
        "learned_beats_handpicked_objective": bool(j_learn > j_hand),
        "ift_gradients_finite": grads_finite,
        "tuning_single_trace": bool(traces == 1),
        "engine_accuracy_within_5pts":
            bool(acc_learn >= acc_hand - 0.05),
        # the learned ε collapses toward 0 (the hand-picked ε=10 wrecks
        # DT aggregation) — the engine gain is ~45 pts, gate it
        "learned_improves_engine_accuracy": bool(acc_learn > acc_hand),
        "rewards_separate_honest_from_attackers":
            bool(float(jnp.mean(r[: M - n_bad]))
                 > float(jnp.mean(r[M - n_bad:]))),
        # recorded margins (context, not gated):
        "objective_handpicked": round(j_hand, 4),
        "objective_learned": round(j_learn, 4),
        "engine_acc_handpicked": round(acc_hand, 4),
        "engine_acc_learned": round(acc_learn, 4),
        "engine_energy_handpicked_J": round(float(energy[0]), 4),
        "engine_energy_learned_J": round(float(energy[1]), 4),
    }

    doc = {
        "bench": "mechanism_design",
        "setup": {"m": M, "k_draws": K_DRAWS, "tune_steps": TUNE_STEPS,
                  "eval_rounds": EVAL_ROUNDS, "eval_seeds": len(EVAL_SEEDS),
                  "poison_ratio": POISON,
                  "n_selected": STATICS.n_selected},
        "mechanism_step_traces": traces,
        "grad_steps_per_sec": round(grad_steps_per_sec, 2),
        "tolerances": {"grad_steps_per_sec": 0.35},
        "learned_knobs": knobs,
        "objective_trace": [round(x, 4) for x in hist["objective"]],
        "elapsed_s": round(elapsed, 2),
        "claims": claims,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    save_csv("mechanism_design", "step,objective",
             list(enumerate(round(x, 5) for x in hist["objective"])))

    checks = ";".join(f"{k}={v}" for k, v in claims.items()
                      if isinstance(v, bool))
    return [("mechanism_design", elapsed * 1e6,
             f"grad_steps_per_sec={grad_steps_per_sec:.2f}|traces={traces}|"
             f"{checks}")]
