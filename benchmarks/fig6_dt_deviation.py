"""Fig. 6 — FL accuracy vs DT mapping deviation ε.

The ε grid is the canonical config-axis sweep: per dataset, all |ε|
deviation points share one state/dataset and differ only in the traced
``FLConfig.epsilon`` knob, so the WHOLE figure is one ``sweep_training``
dispatch per dataset (C = |ε| configs × S = 1 seed × R rounds, round body
traced once) instead of a host loop over per-cell training runs.

Claims verified: accuracy degrades as ε grows; the harder (CIFAR-proxy)
dataset is more sensitive to deviation than the MNIST proxy.  The final
accuracies are read straight off the stacked ``(C, S, R)`` metrics (mean
over the seed axis, then max of the last 5 rounds).  A batched game-level
precheck additionally verifies the resource-side mechanism: ε inflates the
DT-mapped data size D̂ = v·D + ε, so the server must commit a strictly
larger total frequency share Σα to keep the equal-finish-time schedule of
Theorem 1 (Eq. 26; the finish times themselves stay pinned at t_total in
the slack regime, so Σα is the observable)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.fl_round import stack_states, sweep_training
from repro.core.stackelberg import GameConfig

from .common import fl_bench_config, fl_setup, save_csv

ROUNDS = 16
EPSILONS = (0.0, 0.3, 0.6)


def _mc_dt_server_shares(epsilons, k: int = 128, n: int = 5):
    """Mean total DT frequency share Σα over K realizations, for ALL
    deviation points at once: ε rides the sweep engine's config axis, so
    the whole precheck is ONE XLA dispatch (|ε| configs × K draws)."""
    from repro.core.stackelberg import sweep_equilibrium
    from .common import mc_channel_draws
    key = jax.random.PRNGKey(42)
    h2 = mc_channel_draws(key, k, n)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    cfg = GameConfig()
    alloc = sweep_equilibrium([cfg] * len(epsilons), h2, d, vmax,
                              epsilon=jnp.asarray(epsilons))
    share = jnp.mean(jnp.sum(alloc.alpha, axis=-1), axis=-1)   # [C]
    return [float(s) for s in share]


def run():
    t0 = time.perf_counter()
    acc = {}            # dataset -> (C=|eps|, S=1, R) stacked val_acc
    for dataset in ("mnist", "cifar"):
        state, data, logits_fn = fl_setup(11, dataset)
        fls = [fl_bench_config(epsilon=e) for e in EPSILONS]
        _, metrics = sweep_training(stack_states([state]), data, fls,
                                    GameConfig(), logits_fn, ROUNDS)
        acc[dataset] = metrics["val_acc"]
    results = {(d, e): [float(x) for x in acc[d][i, 0]]
               for d in acc for i, e in enumerate(EPSILONS)}
    rows = [[r] + [round(results[k][r], 4) for k in sorted(results)]
            for r in range(ROUNDS)]
    save_csv("fig6_dt_deviation",
             "round," + ",".join(f"{d}_eps{e}" for d, e in sorted(results)),
             rows)
    elapsed_us = (time.perf_counter() - t0) * 1e6
    checks = []
    # final accuracy per ε point, straight off the stacked (C, S, R) grid:
    # mean over the seed axis, then best of the last 5 rounds → [C]
    final = {d: jnp.max(jnp.mean(a, axis=1)[:, -5:], axis=-1)
             for d, a in acc.items()}
    for dataset in ("mnist", "cifar"):
        mono = bool(final[dataset][0] >= final[dataset][-1] - 0.03)
        checks.append(f"{dataset}:eps0_ge_eps0.6={mono}")
    gap_m = float(final["mnist"][0] - final["mnist"][-1])
    gap_c = float(final["cifar"][0] - final["cifar"][-1])
    checks.append(f"cifar_more_sensitive={gap_c >= gap_m - 0.05}")
    shares = _mc_dt_server_shares(EPSILONS)
    checks.append(f"mc_dt_server_share_monotone_in_eps="
                  f"{all(a < b for a, b in zip(shares, shares[1:]))}")
    return [("fig6_dt_deviation_sweep", elapsed_us, "|".join(checks))]
