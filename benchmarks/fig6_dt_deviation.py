"""Fig. 6 — FL accuracy vs DT mapping deviation ε.

Claims verified: accuracy degrades as ε grows; the harder (CIFAR-proxy)
dataset is more sensitive to deviation than the MNIST proxy.  A batched
game-level precheck additionally verifies the resource-side mechanism:
ε inflates the DT-mapped data size D̂ = v·D + ε, so the server must commit
a strictly larger total frequency share Σα to keep the equal-finish-time
schedule of Theorem 1 (Eq. 26; the finish times themselves stay pinned at
t_total in the slack regime, so Σα is the observable)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import curve, fl_experiment, save_csv

ROUNDS = 16
EPSILONS = (0.0, 0.3, 0.6)


def _mc_dt_server_shares(epsilons, k: int = 128, n: int = 5):
    """Mean total DT frequency share Σα over K realizations, for ALL
    deviation points at once: ε rides the sweep engine's config axis, so
    the whole precheck is ONE XLA dispatch (|ε| configs × K draws)."""
    from repro.core.stackelberg import GameConfig, sweep_equilibrium
    from .common import mc_channel_draws
    key = jax.random.PRNGKey(42)
    h2 = mc_channel_draws(key, k, n)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    cfg = GameConfig()
    alloc = sweep_equilibrium([cfg] * len(epsilons), h2, d, vmax,
                              epsilon=jnp.asarray(epsilons))
    share = jnp.mean(jnp.sum(alloc.alpha, axis=-1), axis=-1)   # [C]
    return [float(s) for s in share]


def run():
    t0 = time.perf_counter()
    results = {}
    for dataset in ("mnist", "cifar"):
        for eps in EPSILONS:
            hist = fl_experiment(seed=11, dataset=dataset, epsilon=eps,
                                 rounds=ROUNDS)
            results[(dataset, eps)] = curve(hist)
    rows = [[r] + [round(results[k][r], 4) for k in sorted(results)]
            for r in range(ROUNDS)]
    save_csv("fig6_dt_deviation",
             "round," + ",".join(f"{d}_eps{e}" for d, e in sorted(results)),
             rows)
    elapsed_us = (time.perf_counter() - t0) * 1e6
    checks = []
    for dataset in ("mnist", "cifar"):
        final = {e: max(results[(dataset, e)][-5:]) for e in EPSILONS}
        mono = final[0.0] >= final[0.6] - 0.03
        checks.append(f"{dataset}:eps0_ge_eps0.6={mono}")
    gap_m = max(results[("mnist", 0.0)][-5:]) - max(results[("mnist", 0.6)][-5:])
    gap_c = max(results[("cifar", 0.0)][-5:]) - max(results[("cifar", 0.6)][-5:])
    checks.append(f"cifar_more_sensitive={gap_c >= gap_m - 0.05}")
    shares = _mc_dt_server_shares(EPSILONS)
    checks.append(f"mc_dt_server_share_monotone_in_eps="
                  f"{all(a < b for a, b in zip(shares, shares[1:]))}")
    return [("fig6_dt_deviation_sweep", elapsed_us, "|".join(checks))]
