"""Benchmark harness — one module per paper table/figure + kernel
micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig4,fig9,kernels
    PYTHONPATH=src python -m benchmarks.run --only equilibrium   # fast mode:
        # just the batched Stackelberg engine throughput (~seconds), writes
        # BENCH_equilibrium.json for trajectory tracking
    PYTHONPATH=src python -m benchmarks.run --only training      # fast mode:
        # trajectory + config-grid sweep tiers, writes BENCH_training.json
    PYTHONPATH=src python -m benchmarks.run --only fig5          # one figure
        # (fig5 / fig6 / fig78 each run + gate individually the same way)
    PYTHONPATH=src python -m benchmarks.run --devices 4          # re-exec
        # with 4 forced host devices (see benchmarks/common.py) before any
        # suite loads jax — every suite then runs sharded

Unknown ``--only`` names are an error (they used to silently run nothing).
The summary (stdout + ``runs/bench/summary.csv``) ends with ``#``-comment
rows recording the device count and per-suite wall-clock seconds.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from . import common  # noqa: F401  applies --devices/REPRO_FORCE_DEVICES
                      # (re-exec) before any suite initializes jax

SUITES = ("fig4", "fig5", "fig6", "fig78", "fig9", "ablation", "kernels",
          "equilibrium", "training", "robustness", "mechanism")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(SUITES),
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--devices", type=int, default=None,
                    help="forced host device count (consumed pre-jax by "
                         "benchmarks.common; listed here for --help)")
    args = ap.parse_args()
    wanted = set(filter(None, args.only.split(",")))
    unknown = wanted - set(SUITES)
    if unknown:
        ap.error(f"unknown suite(s) {','.join(sorted(unknown))}; "
                 f"valid: {','.join(SUITES)}")
    if not wanted:
        ap.error(f"--only selected no suites; valid: {','.join(SUITES)}")

    print("name,us_per_call,derived")
    rows = []
    suite_walls = []
    for suite in SUITES:
        if suite not in wanted:
            continue
        t_suite = time.perf_counter()
        try:
            if suite == "fig4":
                from . import fig4_dinkelbach as mod
            elif suite == "fig5":
                from . import fig5_poisoners as mod
            elif suite == "fig6":
                from . import fig6_dt_deviation as mod
            elif suite == "fig78":
                from . import fig78_schemes as mod
            elif suite == "fig9":
                from . import fig9_total_cost as mod
            elif suite == "ablation":
                from . import ablation_weights as mod
            elif suite == "equilibrium":
                from . import equilibrium_throughput as mod
            elif suite == "training":
                from . import training_throughput as mod
            elif suite == "robustness":
                from . import robustness_grid as mod
            elif suite == "mechanism":
                from . import mechanism_design as mod
            else:
                from . import kernels_microbench as mod
            for name, us, derived in mod.run():
                line = f"{name},{us:.1f},{derived}"
                print(line, flush=True)
                rows.append(line)
        except Exception:  # noqa: BLE001
            print(f"{suite},NaN,ERROR", flush=True)
            traceback.print_exc()
        suite_walls.append((suite, time.perf_counter() - t_suite))

    import jax
    footer = [f"# devices,{len(jax.devices())}"]
    footer += [f"# suite_wall_s,{suite},{wall:.1f}"
               for suite, wall in suite_walls]
    for line in footer:
        print(line, flush=True)
    os.makedirs("runs/bench", exist_ok=True)
    with open("runs/bench/summary.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(rows) + "\n")
        f.write("\n".join(footer) + "\n")


if __name__ == "__main__":
    main()
