"""Fig. 4 — convergence of Algorithm 1 (Dinkelbach power optimization).

Claim verified: q converges to the optimum within a handful of iterations;
q values are ordered by decoding position (first-decoded client has the
smallest q, since it sees the most interference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import save_csv, timed


def run():
    from repro.core.channel import (noise_power, sample_channel_gains,
                                    sample_positions)
    from repro.core.dinkelbach import dinkelbach_power, successive_power

    key = jax.random.PRNGKey(42)
    n = 5
    h2 = jnp.sort(sample_channel_gains(
        jax.random.fold_in(key, 1), sample_positions(key, n)))[::-1]
    sigma2 = noise_power()

    # successive optimization to get each client's interference level
    p_star, q_star = successive_power(h2, 1e6, 5.0, 1e6, sigma2, 0.01, 0.1)
    intf = jnp.flip(jnp.cumsum(jnp.flip(p_star * h2))) - p_star * h2

    rows, traces = [], []
    for i in range(n):
        f_eff = float(h2[i] / (intf[i] + sigma2))
        p, q, it, trace = dinkelbach_power(1e6, 5.0, f_eff, 1e6, 0.01, 0.1,
                                           return_trace=True)
        traces.append(trace)
        rows.append((i + 1, float(p), float(q), it))
    max_len = max(len(t) for t in traces)
    csv_rows = []
    for j in range(max_len):
        csv_rows.append([j] + [t[j] if j < len(t) else t[-1] for t in traces])
    save_csv("fig4_dinkelbach",
             "iteration," + ",".join(f"client_{i+1}_q" for i in range(n)),
             csv_rows)

    _, us = timed(lambda: successive_power(h2, 1e6, 5.0, 1e6, sigma2,
                                           0.01, 0.1)[0].block_until_ready(),
                  iters=5)
    iters_used = max(r[3] for r in rows)
    # claim check (paper: first-decoded client has the smallest q). This is
    # an interference-dominated-regime property — verify it with comparable
    # gains; with heavy pathloss spread the gain term dominates instead
    # (EXPERIMENTS.md §Paper-validation).
    h2_eq = jnp.full((n,), float(jnp.mean(h2)))
    _, q_eq = successive_power(h2_eq, 1e6, 5.0, 1e6, sigma2, 0.01, 0.1)
    order_eq = bool(jnp.all(q_eq[:-1] <= q_eq[-1] + 1e-6))
    return [("fig4_dinkelbach_successive_power", us,
             f"max_iters={iters_used};q_first_smallest_equal_gain={order_eq}")]
