"""Attack-vs-defense robustness grid (ISSUE 7 tentpole bench).

The scenario library of ``repro.core.faults`` published as a grid:

  attacks  (6) : clean · static · adaptive (reputation-gated) · duty
                 (on–off bursts) · sybil (one hoard across 5 colluding
                 IDs) · storm (outages + compute slowdowns on top of
                 static poisoning)
  defenses (3) : defended  — PROPOSED selection weights + RONI
                 rep_only  — PROPOSED weights, RONI off (PI term blind)
                 none      — BENCHMARK weights (PI-less) + RONI off
  seeds    (2) : independent model/state initializations

Dispatch layout — the zero-retrace contract: attacks ride the CONFIG
axis of ``sweep_training`` (per-attack ``FaultConfig`` as [C]-stacked
traced operands, per-attack datasets on ``data_axis="config"``), and
``use_roni`` is the only static key that splits the grid — so the 36
trajectories run as exactly TWO sweep dispatches (RONI-on: C=6; RONI-off:
C=12, rep_only and none share the executable because selection weights
are traced operands).  ``TRACE_COUNTS['run_round']`` is asserted == 2
over the whole grid.

Writes ``BENCH_robustness.json`` (repo root) with:
  * ``grid_rounds_per_sec`` — gated by ``scripts/check_bench.py`` at the
    declared per-metric tolerance (−35%: this container's wall-clock
    noise is recorded at ±30%, CHANGES.md PR 4);
  * ``claims`` — booleans the gate FAILS on when false:
      - defended final accuracy stays within 5 pts of the defended clean
        run under the adaptive attacker;
      - the undefended scheme degrades MORE than the defended one under
        the same adaptive attacker;
      - same pair for the static attacker;
      - the storm scenario's masked mid-round dropouts keep every
        trajectory finite (graceful degradation, not a crash).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.faults import (FaultConfig, adaptive_attacker,
                               duty_cycle_attacker, straggler_storm)
from repro.core.fl_round import FLConfig, stack_states, sweep_training
from repro.core.reputation import BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS
from repro.core.stackelberg import GameConfig, TRACE_COUNTS
from repro.data.federated import make_federated_data, make_sybil_data
from repro.data.synthetic import SYNTHETIC_MNIST

from .common import fl_setup, save_csv, stack_data

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_robustness.json")

ROUNDS = 16
SEEDS = (7, 8)
M, CAP = 20, 128
POISON = 0.3
SYBIL_POOL = 5

#: attack name -> (FaultConfig behavioral gates, dataset poison ratio)
ATTACKS = (
    ("clean", FaultConfig(), 0.0),
    ("static", FaultConfig(), POISON),
    ("adaptive", adaptive_attacker(rep_gate=0.85), POISON),
    ("duty", duty_cycle_attacker(period=4, on=2), POISON),
    ("sybil", FaultConfig(), "sybil"),
    ("storm", straggler_storm(), POISON),
)
DEFENSES = (
    ("defended", PROPOSED_WEIGHTS, True),
    ("rep_only", PROPOSED_WEIGHTS, False),
    ("none", BENCHMARK_WEIGHTS, False),
)


def _fl(weights, use_roni) -> FLConfig:
    return FLConfig(n_selected=5, local_steps=20, server_steps=20, lr=0.1,
                    roni_threshold=0.02, weights=weights, use_roni=use_roni)


def _attack_datasets():
    """One dataset per attack profile, all from ONE data key so the grid
    cells differ only in the planted attackers (clean/sybil/poisoned
    variants of the same draw)."""
    key = jax.random.PRNGKey(1234)
    k_data, k_sybil = jax.random.split(key)
    per_attack = []
    for name, _, poison in ATTACKS:
        if poison == "sybil":
            clean = make_federated_data(k_data, SYNTHETIC_MNIST, m=M,
                                        cap=CAP, poison_ratio=0.0)
            per_attack.append(make_sybil_data(k_sybil, clean, SYBIL_POOL))
        else:
            per_attack.append(make_federated_data(
                k_data, SYNTHETIC_MNIST, m=M, cap=CAP, poison_ratio=poison))
    return stack_data(per_attack)


def _final_acc(val_acc):
    """[C, S, R] → [C]: mean over seeds of the max of the last 5 rounds
    (the fig5 headline statistic)."""
    return jnp.mean(jnp.max(val_acc[:, :, -5:], axis=-1), axis=-1)


def run():
    t0 = time.perf_counter()
    states = stack_states([fl_setup(s, m=M, cap=CAP)[0] for s in SEEDS])
    logits_fn = fl_setup(SEEDS[0], m=M, cap=CAP)[2]
    data = _attack_datasets()                   # [C=6] config-axis datasets
    game = GameConfig()
    attack_fcs = [fc for _, fc, _ in ATTACKS]
    n_attacks = len(ATTACKS)

    before = TRACE_COUNTS["run_round"]
    acc = {}                                    # defense -> [C, S, R]
    # RONI-on sweep: the defended scheme, C = 6 attacks
    _, m_def = sweep_training(states, data, [_fl(PROPOSED_WEIGHTS, True)],
                              game, logits_fn, ROUNDS, faults=attack_fcs,
                              data_axis="config")
    acc["defended"] = m_def["val_acc"]
    # RONI-off sweep: rep_only + none share one executable (weights are
    # traced operands) — C = 12 = 6 attacks × 2 weight settings
    fls_off = ([_fl(PROPOSED_WEIGHTS, False)] * n_attacks
               + [_fl(BENCHMARK_WEIGHTS, False)] * n_attacks)
    data_off = jax.tree_util.tree_map(
        lambda x: jnp.concatenate([x, x]), data)
    _, m_off = sweep_training(states, data_off, fls_off, game, logits_fn,
                              ROUNDS, faults=attack_fcs + attack_fcs,
                              data_axis="config")
    acc["rep_only"] = m_off["val_acc"][:n_attacks]
    acc["none"] = m_off["val_acc"][n_attacks:]
    traces = TRACE_COUNTS["run_round"] - before
    assert traces == 2, f"attack grid retraced: {traces} != 2"
    elapsed = time.perf_counter() - t0

    n_cells = n_attacks * len(DEFENSES) * len(SEEDS)
    grid_rounds_per_sec = n_cells * ROUNDS / elapsed
    storm_idx = n_attacks - 1
    dropped = int(jnp.sum(m_def["n_dropped"][storm_idx]))

    final = {d: _final_acc(a) for d, a in acc.items()}  # defense -> [C]
    by_attack = {name: {d: round(float(final[d][i]), 4) for d, _, _
                        in DEFENSES}
                 for i, (name, _, _) in enumerate(ATTACKS)}

    def drop(defense, attack_i):
        """Accuracy lost vs the same defense's clean run (pts)."""
        return float(final[defense][0] - final[defense][attack_i])

    adaptive_i = 2
    static_i = 1
    claims = {
        "defended_within_5pts_of_clean_adaptive":
            bool(drop("defended", adaptive_i) <= 0.05),
        "no_defense_degrades_more_adaptive":
            bool(drop("none", adaptive_i) > drop("defended", adaptive_i)),
        "defended_within_5pts_of_clean_static":
            bool(drop("defended", static_i) <= 0.05),
        "no_defense_degrades_more_static":
            bool(drop("none", static_i) > drop("defended", static_i)),
        "storm_trajectories_all_finite":
            bool(jnp.all(jnp.isfinite(acc["defended"][storm_idx]))
                 and jnp.all(jnp.isfinite(acc["none"][storm_idx]))),
        # recorded margins (context, not gated):
        "defended_drop_adaptive_pts": round(drop("defended", adaptive_i), 4),
        "none_drop_adaptive_pts": round(drop("none", adaptive_i), 4),
        "defended_drop_static_pts": round(drop("defended", static_i), 4),
        "none_drop_static_pts": round(drop("none", static_i), 4),
        "storm_dropped_client_rounds": dropped,
    }

    doc = {
        "bench": "robustness_grid",
        "grid": {"attacks": [a for a, _, _ in ATTACKS],
                 "defenses": [d for d, _, _ in DEFENSES],
                 "seeds": len(SEEDS), "rounds": ROUNDS,
                 "m": M, "poison_ratio": POISON,
                 "sybil_pool": SYBIL_POOL},
        "dispatches": 2,
        "run_round_traces": traces,
        "elapsed_s": round(elapsed, 2),
        "grid_rounds_per_sec": round(grid_rounds_per_sec, 2),
        "tolerances": {"grid_rounds_per_sec": 0.35},
        "final_acc_by_attack": by_attack,
        "claims": claims,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")

    rows = [[name] + [by_attack[name][d] for d, _, _ in DEFENSES]
            for name, _, _ in ATTACKS]
    save_csv("robustness_grid",
             "attack," + ",".join(d for d, _, _ in DEFENSES), rows)

    checks = ";".join(f"{k}={v}" for k, v in claims.items()
                      if isinstance(v, bool))
    return [("robustness_grid", elapsed * 1e6,
             f"rounds_per_sec={grid_rounds_per_sec:.1f}|traces={traces}|"
             f"{checks}")]
