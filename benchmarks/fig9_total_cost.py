"""Fig. 9 — total cost (latency + energy) vs (a) model size d_n,
(b) #selected clients N, (c) bandwidth B — proposed vs random / W-O DT / OMA,
plus (d) a Monte-Carlo column over K channel realizations solved in one
batched XLA call per scheme (every baseline now has a vmapped body).

The (a)/(c) config grids run through ``sweep_allocation``: each scheme's
whole sweep (C config points × the channel draw) is ONE dispatch of ONE
compiled executable — distinct d_n / B values are traced ``GamePhysics``
rows, not compile keys.  Only (b) recompiles across points (N changes the
shape).

Claims verified: cost grows with d_n and N; cost falls then saturates with B;
proposed ≤ all baselines; MC mean confirms DT energy saving over the channel
distribution.

Claim-check keying — the "proposed best" checks are evaluated on the
K=256 MONTE-CARLO means, not the single median-ish channel draw the (a)-(c)
curves are plotted on.  Rationale (ROADMAP open item, resolved): the paper's
Fig. 9 reports expected cost over the fading distribution, and on a single
benign draw OMA-FDMA's B/N sub-bands are occasionally within ~5-7% of (or
just under) NOMA — the single-draw operating point is an unrepresentative
slice, while the MC means show proposed strictly cheapest at every tested
load (see ``fig9d_mc_cost.csv`` / ``fig9e_mc_cost_vs_dn.csv``).  The
single-draw flags are still recorded as ``single_draw_*`` for trend
visibility, but they are informational, not claims."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from .common import mc_equilibrium_stats, save_csv

MC_DRAWS = 256   # channel realizations per MC point (one batched solve each)
SCHEMES = ("proposed", "random", "wo_dt", "oma")


def _setup(n: int, seed: int = 3, pool: int = 20):
    """Paper §VI: N clients are SELECTED from a 20-client pool by
    reputation (channel-agnostic) — we draw a pool and take a median slice
    of channels: not the pathological worst, not best-channel cherry-picks."""
    from repro.core.channel import sample_channel_gains, sample_positions
    key = jax.random.PRNGKey(seed)
    pool = max(pool, n + 4)
    h2 = sample_channel_gains(jax.random.fold_in(key, 1),
                              sample_positions(key, pool))
    h2 = jnp.sort(h2)[::-1][2:2 + n]   # drop the 2 best — median-ish slice
    d = 100.0 + 200.0 * jax.random.uniform(jax.random.fold_in(key, 2), (n,))
    vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 3), (n,))
    return h2, d, vmax


def _sweep_costs(configs, h2, d, vmax, key):
    """Per-scheme total cost along a config grid: one ``sweep_allocation``
    dispatch per scheme over (C configs × K=1 draw).  Returns
    {scheme: [C] costs}."""
    from repro.core.fl_round import sweep_allocation
    out = {}
    for scheme in SCHEMES:
        alloc = sweep_allocation(scheme, configs, h2[None, :], d, vmax,
                                 key=key)
        cost = alloc.t_total[:, 0] + alloc.energy[:, 0]
        out[scheme] = [float(c) for c in cost]
    return out


def _batched_costs(game, h2, d, vmax, key):
    """Single-point costs per scheme (K=1 batched call each)."""
    from repro.core.fl_round import allocate_batched
    out = {}
    for scheme in SCHEMES:
        alloc = allocate_batched(scheme, game, h2[None, :], d, vmax, key=key)
        out[scheme] = float(alloc.t_total[0] + alloc.energy[0])
    return out


def run():
    from repro.core.channel import noise_power
    from repro.core.stackelberg import GameConfig
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    base = GameConfig()

    # (a) vs model size d_n — one compiled sweep per scheme
    h2, d, vmax = _setup(5)
    dns = (0.5, 1.0, 1.5, 2.0, 2.5)
    cfgs_a = [dataclasses.replace(base, model_bits=dn * 1e6) for dn in dns]
    costs_a = _sweep_costs(cfgs_a, h2, d, vmax, key)
    rows_a = [[dn] + [round(costs_a[s][i], 4) for s in SCHEMES]
              for i, dn in enumerate(dns)]
    save_csv("fig9a_cost_vs_dn", "dn_mbit,proposed,random,wo_dt,oma", rows_a)

    # (b) vs number of selected clients N (shape changes → per-N dispatch)
    rows_b = []
    for n in (3, 5, 7, 9):
        h2n, dn, vmaxn = _setup(n)
        c = _batched_costs(base, h2n, dn, vmaxn, key)
        rows_b.append([n] + [round(c[s], 4) for s in SCHEMES])
    save_csv("fig9b_cost_vs_n", "n,proposed,random,wo_dt,oma", rows_b)

    # (c) vs bandwidth B — same compiled sweep executables as (a)
    bws = (0.5, 1.0, 2.0, 4.0, 8.0)
    cfgs_c = [dataclasses.replace(base, bandwidth=b * 1e6,
                                  sigma2=noise_power(b * 1e6)) for b in bws]
    costs_c = _sweep_costs(cfgs_c, h2, d, vmax, key)
    rows_c = [[b] + [round(costs_c[s][i], 4) for s in SCHEMES]
              for i, b in enumerate(bws)]
    save_csv("fig9c_cost_vs_bw", "b_mhz,proposed,random,wo_dt,oma", rows_c)

    # (d) Monte-Carlo over the channel distribution, K = MC_DRAWS
    # realizations per point — ONE batched solve per scheme (baselines too)
    rows_d = []
    for n in (3, 5, 7):
        _, dn, vmaxn = _setup(n)
        mk = jax.random.fold_in(key, 90 + n)
        prop = mc_equilibrium_stats(base, mk, MC_DRAWS, n, dn, vmaxn,
                                    scheme="proposed")
        wo = mc_equilibrium_stats(base, mk, MC_DRAWS, n, dn, vmaxn,
                                  scheme="wo_dt")
        oma = mc_equilibrium_stats(base, mk, MC_DRAWS, n, dn, vmaxn,
                                   scheme="oma")
        rnd = mc_equilibrium_stats(base, mk, MC_DRAWS, n, dn, vmaxn,
                                   scheme="random")
        rows_d.append([n, round(prop["mean_cost"], 4),
                       round(prop["std_cost"], 4),
                       round(wo["mean_cost"], 4),
                       round(oma["mean_cost"], 4),
                       round(rnd["mean_cost"], 4),
                       round(prop["feasible_frac"], 3)])
    save_csv("fig9d_mc_cost", "n,proposed_mean,proposed_std,wo_dt_mean,"
             "oma_mean,random_mean,proposed_feasible_frac", rows_d)
    mc_dt_saves = all(r[1] <= r[3] + 1e-6 for r in rows_d)
    mc_prop_best = all(r[1] <= min(r[3], r[4], r[5]) * 1.05 + 1e-6
                       for r in rows_d)

    # (e) Monte-Carlo along the model-size axis at the Table-I operating
    # load (d_n ≥ 1 Mbit) — the distribution-level ground for the
    # "proposed best" claims (see module docstring for why the single
    # median draw is not the claim basis)
    rows_e = []
    for dn in [x for x in dns if x >= 1.0]:
        cfg_dn = dataclasses.replace(base, model_bits=dn * 1e6)
        mk = jax.random.fold_in(key, 800 + int(dn * 10))
        stats = {s: mc_equilibrium_stats(cfg_dn, mk, MC_DRAWS, 5, d, vmax,
                                         scheme=s) for s in SCHEMES}
        rows_e.append([dn] + [round(stats[s]["mean_cost"], 4)
                              for s in SCHEMES])
    save_csv("fig9e_mc_cost_vs_dn", "dn_mbit,proposed,random,wo_dt,oma",
             rows_e)

    elapsed_us = (time.perf_counter() - t0) * 1e6
    prop_a = [r[1] for r in rows_a]
    grows_dn = prop_a[-1] > prop_a[0]
    prop_c = [r[1] for r in rows_c]
    falls_bw = prop_c[-1] < prop_c[0]
    # single-draw flags: informational trend only (see docstring)
    sd_best_tol = all(r[1] <= min(r[2], r[3], r[4]) * 1.05 + 1e-6
                      for r in rows_a + rows_b + rows_c)
    sd_best_loaded = all(r[1] <= min(r[2], r[3], r[4]) + 1e-6
                         for r in rows_a if r[0] >= 1.0)
    # the claims, keyed to the K=256 MC means: proposed within 5% of the
    # cheapest baseline at every MC point, and strictly cheapest at the
    # paper's operating load (d_n ≥ 1 Mbit, N = 5)
    best_tol = mc_prop_best and all(
        r[1] <= min(r[2], r[3], r[4]) * 1.05 + 1e-6 for r in rows_e)
    best_loaded = all(r[1] <= min(r[2], r[3], r[4]) + 1e-6 for r in rows_e)
    return [("fig9_total_cost_sweeps", elapsed_us,
             f"grows_with_dn={grows_dn};falls_with_bw={falls_bw};"
             f"proposed_best_within_5pct={best_tol};"
             f"proposed_best_at_operating_load={best_loaded};"
             f"claim_basis=mc_k{MC_DRAWS};"
             f"single_draw_best_within_5pct={sd_best_tol};"
             f"single_draw_best_at_operating_load={sd_best_loaded};"
             f"mc_k{MC_DRAWS}_dt_saves={mc_dt_saves};"
             f"mc_proposed_best={mc_prop_best}")]
