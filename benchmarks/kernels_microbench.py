"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference paths.

Wall-times on CPU are NOT TPU projections — interpret mode executes the
kernel body in Python.  The derived column reports the allclose check and
the analytic FLOPs the kernel performs (used with §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import timed


def run():
    from repro.kernels.ref import ssd_scan_ref, swa_attention_ref
    from repro.kernels.ssd_scan import ssd_scan_pallas
    from repro.kernels.swa_attention import swa_attention_pallas
    from repro.models.ssm import ssd_chunked

    out = []
    key = jax.random.PRNGKey(0)

    # SSD: production-ish tile (bh=8, s=512, p=64, n=128)
    bh, s, p, n = 8, 512, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    b = jax.random.normal(ks[3], (bh, s, n))
    c = jax.random.normal(ks[4], (bh, s, n))
    ref, us_ref = timed(lambda: ssd_scan_ref(x, dt, a, b, c), iters=3)
    pal, us_pal = timed(lambda: ssd_scan_pallas(x, dt, a, b, c, chunk=128,
                                                interpret=True), iters=3)
    err = float(jnp.max(jnp.abs(pal - ref)))
    chunk_flops = 2 * bh * s * (128 * n + 128 * p + n * p) * 2
    out.append(("ssd_scan_pallas_interpret", us_pal,
                f"allclose_err={err:.1e};approx_flops={chunk_flops:.3g}"))
    out.append(("ssd_scan_jnp_ref", us_ref, "sequential_scan_oracle"))
    xm = x.reshape(bh, s, 1, p).repeat(1, 2)

    # jnp chunked model path (what SPMD uses)
    y_model, us_model = timed(
        lambda: ssd_chunked(x.reshape(bh, s, 1, p), dt.reshape(bh, s, 1),
                            a[:1], b.reshape(bh, s, 1, n),
                            c.reshape(bh, s, 1, n), 128), iters=3)
    out.append(("ssd_chunked_jnp_model_path", us_model, "spmd_path"))

    # SWA attention: 1k seq, window 256
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (4, 1024, 64)) * 0.5 for kk in ks)
    ref, us_ref = timed(lambda: swa_attention_ref(q, k, v, window=256), iters=3)
    pal, us_pal = timed(lambda: swa_attention_pallas(
        q, k, v, window=256, block=128, interpret=True), iters=3)
    err = float(jnp.max(jnp.abs(pal - ref)))
    out.append(("swa_attention_pallas_interpret", us_pal,
                f"allclose_err={err:.1e}"))
    out.append(("swa_attention_jnp_ref", us_ref, "full_matrix_oracle"))
    return out
