"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, run_training
from repro.core.reputation import init_reputation
from repro.core.stackelberg import GameConfig
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_CIFAR, SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

RESULTS_DIR = "runs/bench"


def timed(fn, *args, iters: int = 3, warmup: int = 1):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters * 1e6  # us


def fl_setup(seed: int, dataset: str = "mnist", poison_ratio: float = 0.0,
             iid: bool = True, m: int = 20, cap: int = 128):
    """The data/model/state triple of one figure-bench cell:
    ``(state, data, logits_fn)``, keyed exactly as ``fl_experiment`` keys
    them (same PRNG split order), so grid cells that share
    (seed, dataset) differ ONLY in the knob under sweep.

    Both proxies use the MLP head in the benchmark harness: the phenomena
    under test (selection/poisoning/DT-deviation dynamics) are
    distribution-level, and XLA-on-CPU convolutions are ~40 s/round —
    they would dominate the harness without informing the claims.  The
    CNN path stays in the library (models/classifier.py) and is covered
    by tests.  CIFAR-proxy difficulty comes from its lower class
    separation (DESIGN.md §6)."""
    spec = SYNTHETIC_MNIST if dataset == "mnist" else SYNTHETIC_CIFAR
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    lpc = 1 if dataset == "mnist" else 5
    data = make_federated_data(ks[0], spec, m=m, cap=cap, iid=iid,
                               labels_per_client=lpc,
                               poison_ratio=poison_ratio)
    params, logits_fn = make_classifier(
        "mlp", ks[1], in_dim=spec.dim, hidden=64 if dataset == "mnist" else 96)
    state = FLState(params=params, rep=init_reputation(m),
                    v_max=sample_v_max(ks[2], m, DTConfig()),
                    distances=sample_positions(ks[3], m), key=ks[4])
    return state, data, logits_fn


def fl_bench_config(scheme: str = "proposed", epsilon: float = 0.0,
                    weights=None, use_roni: bool = True,
                    n_selected: int = 5) -> FLConfig:
    """The figure-bench ``FLConfig`` (shared by the per-cell and swept
    paths, so the two stay numerically comparable)."""
    from repro.core.reputation import PROPOSED_WEIGHTS
    return FLConfig(n_selected=n_selected, local_steps=40, server_steps=40,
                    lr=0.1, epsilon=epsilon, scheme=scheme,
                    roni_threshold=0.02,
                    weights=weights or PROPOSED_WEIGHTS, use_roni=use_roni)


def stack_data(datasets):
    """Stack per-cell ``FedData`` (identical shapes) along a new leading
    axis — the per-seed data axis of ``batched_training``/``sweep_training``
    (fig5's poison-ratio axis, fig78's IID/non-IID axis)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *datasets)


def fl_experiment(seed: int, dataset: str = "mnist", scheme: str = "proposed",
                  poison_ratio: float = 0.0, epsilon: float = 0.0,
                  weights=None, rounds: int = 20, iid: bool = True,
                  m: int = 20, cap: int = 128, n_selected: int = 5,
                  use_roni: bool = True, game: GameConfig | None = None):
    """Run one FL training curve; returns history (list of per-round dicts)."""
    state, data, logits_fn = fl_setup(seed, dataset, poison_ratio=poison_ratio,
                                      iid=iid, m=m, cap=cap)
    fl = fl_bench_config(scheme=scheme, epsilon=epsilon, weights=weights,
                         use_roni=use_roni, n_selected=n_selected)
    state, hist = run_training(state, data, fl, game or GameConfig(),
                               logits_fn, rounds)
    return hist


def mc_channel_draws(key, k: int, n: int):
    """[K, N] channel power gains, each row sorted descending (SIC order) —
    the Monte-Carlo input of the batched Stackelberg engine."""
    from repro.core.channel import sample_sic_channel_batch
    return sample_sic_channel_batch(key, k, n)


def mc_equilibrium_stats(game: GameConfig, key, k: int, n: int, d, vmax,
                         scheme: str = "proposed", epsilon: float = 0.0):
    """Mean/std total cost over K channel realizations, solved in ONE
    batched XLA call — works for every scheme (proposed/ideal/wo_dt/oma/
    oma_tdma/random) now that the baselines have vmapped bodies."""
    from repro.core.fl_round import allocate_batched
    h2_batch = mc_channel_draws(key, k, n)
    alloc = allocate_batched(scheme, game, h2_batch,
                             jnp.broadcast_to(d, (k, n)),
                             jnp.broadcast_to(vmax, (k, n)),
                             epsilon=epsilon,
                             key=jax.random.fold_in(key, 1))
    cost = alloc.t_total + alloc.energy
    return {
        "mean_cost": float(jnp.mean(cost)),
        "std_cost": float(jnp.std(cost)),
        "mean_energy": float(jnp.mean(alloc.energy)),
        "mean_latency": float(jnp.mean(alloc.t_total)),
        "feasible_frac": float(jnp.mean(alloc.feasible.astype(jnp.float32))),
    }


def curve(hist, key="val_acc"):
    return [h[key] for h in hist]


def save_csv(name: str, header: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
