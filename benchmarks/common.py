"""Shared helpers for the paper-figure benchmarks.

Scaling
-------
Every bench entry point understands ``--devices N`` (or the
``REPRO_FORCE_DEVICES`` env var): before jax initializes, the process
re-execs itself with ``--xla_force_host_platform_device_count=N`` so the
whole run measures at N forced host devices — the multi-device-by-default
knob of ISSUE 8.  ``scaling_section`` additionally spawns per-device-count
worker subprocesses (``--scaling-worker D``) and assembles the ``scaling``
section of the BENCH JSONs: measured 1/2/4-device rates, the parallel
efficiency at the max device count, and sharded-vs-single-device parity.

Efficiency is normalized by ``min(devices, host_cores)``: on a multi-core
host it is true parallel efficiency; on a 1-core container (this CI box)
forced host devices time-slice one core, so the quotient measures
*sharding-overhead retention* (1.0 = the mesh machinery is free) — the
honest statement of what a CPU box can verify.  Real accelerator speedups
must come from accelerator runs; the gate guarantees the sharded program
is within 30% of the single-device program per unit of hardware, i.e.
scaling is overhead-limited by at most that much.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_DEVICES_APPLIED_ENV = "_REPRO_DEVICES_APPLIED"


def _force_devices() -> None:
    """Re-exec with ``--xla_force_host_platform_device_count=N`` when
    ``--devices N`` / ``REPRO_FORCE_DEVICES`` asks for forced host
    devices.  Must run BEFORE jax import (the flag binds at backend
    init); the marker env var breaks the re-exec loop, and module mode
    (``python -m benchmarks.x``) is preserved via ``__main__.__spec__``."""
    want = os.environ.get("REPRO_FORCE_DEVICES", "")
    argv = sys.argv
    if "--devices" in argv:
        i = argv.index("--devices")
        if i + 1 >= len(argv):
            raise SystemExit("--devices needs a value")
        want = argv[i + 1]
        del argv[i:i + 2]
    elif "--scaling-worker" in argv:
        # the worker arg IS the device count, so a hand-launched worker
        # forces its own devices; parent-spawned workers arrive with
        # XLA_FLAGS + the applied marker already set (no re-exec)
        want = argv[argv.index("--scaling-worker") + 1]
    if not want or os.environ.get(_DEVICES_APPLIED_ENV) == want:
        return
    flag = f"--xla_force_host_platform_device_count={int(want)}"
    keep = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    os.environ["XLA_FLAGS"] = " ".join(keep + [flag])
    os.environ[_DEVICES_APPLIED_ENV] = want
    os.environ["REPRO_FORCE_DEVICES"] = want
    spec = getattr(sys.modules.get("__main__"), "__spec__", None)
    if spec is not None and spec.name:
        cmd = [sys.executable, "-m", spec.name] + sys.argv[1:]
    else:
        cmd = [sys.executable] + sys.argv
    os.execv(sys.executable, cmd)


_force_devices()

import jax
import jax.numpy as jnp

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, run_training
from repro.core.reputation import init_reputation
from repro.core.stackelberg import GameConfig
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_CIFAR, SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

RESULTS_DIR = "runs/bench"


def timed(fn, *args, iters: int = 3, warmup: int = 1):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters * 1e6  # us


def fl_setup(seed: int, dataset: str = "mnist", poison_ratio: float = 0.0,
             iid: bool = True, m: int = 20, cap: int = 128):
    """The data/model/state triple of one figure-bench cell:
    ``(state, data, logits_fn)``, keyed exactly as ``fl_experiment`` keys
    them (same PRNG split order), so grid cells that share
    (seed, dataset) differ ONLY in the knob under sweep.

    Both proxies use the MLP head in the benchmark harness: the phenomena
    under test (selection/poisoning/DT-deviation dynamics) are
    distribution-level, and XLA-on-CPU convolutions are ~40 s/round —
    they would dominate the harness without informing the claims.  The
    CNN path stays in the library (models/classifier.py) and is covered
    by tests.  CIFAR-proxy difficulty comes from its lower class
    separation (DESIGN.md §6)."""
    spec = SYNTHETIC_MNIST if dataset == "mnist" else SYNTHETIC_CIFAR
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    lpc = 1 if dataset == "mnist" else 5
    data = make_federated_data(ks[0], spec, m=m, cap=cap, iid=iid,
                               labels_per_client=lpc,
                               poison_ratio=poison_ratio)
    params, logits_fn = make_classifier(
        "mlp", ks[1], in_dim=spec.dim, hidden=64 if dataset == "mnist" else 96)
    state = FLState(params=params, rep=init_reputation(m),
                    v_max=sample_v_max(ks[2], m, DTConfig()),
                    distances=sample_positions(ks[3], m), key=ks[4])
    return state, data, logits_fn


def fl_bench_config(scheme: str = "proposed", epsilon: float = 0.0,
                    weights=None, use_roni: bool = True,
                    n_selected: int = 5) -> FLConfig:
    """The figure-bench ``FLConfig`` (shared by the per-cell and swept
    paths, so the two stay numerically comparable)."""
    from repro.core.reputation import PROPOSED_WEIGHTS
    return FLConfig(n_selected=n_selected, local_steps=40, server_steps=40,
                    lr=0.1, epsilon=epsilon, scheme=scheme,
                    roni_threshold=0.02,
                    weights=weights or PROPOSED_WEIGHTS, use_roni=use_roni)


def stack_data(datasets):
    """Stack per-cell ``FedData`` (identical shapes) along a new leading
    axis — the per-seed data axis of ``batched_training``/``sweep_training``
    (fig5's poison-ratio axis, fig78's IID/non-IID axis)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *datasets)


def fl_experiment(seed: int, dataset: str = "mnist", scheme: str = "proposed",
                  poison_ratio: float = 0.0, epsilon: float = 0.0,
                  weights=None, rounds: int = 20, iid: bool = True,
                  m: int = 20, cap: int = 128, n_selected: int = 5,
                  use_roni: bool = True, game: GameConfig | None = None):
    """Run one FL training curve; returns history (list of per-round dicts)."""
    state, data, logits_fn = fl_setup(seed, dataset, poison_ratio=poison_ratio,
                                      iid=iid, m=m, cap=cap)
    fl = fl_bench_config(scheme=scheme, epsilon=epsilon, weights=weights,
                         use_roni=use_roni, n_selected=n_selected)
    state, hist = run_training(state, data, fl, game or GameConfig(),
                               logits_fn, rounds)
    return hist


def mc_channel_draws(key, k: int, n: int):
    """[K, N] channel power gains, each row sorted descending (SIC order) —
    the Monte-Carlo input of the batched Stackelberg engine."""
    from repro.core.channel import sample_sic_channel_batch
    return sample_sic_channel_batch(key, k, n)


def mc_equilibrium_stats(game: GameConfig, key, k: int, n: int, d, vmax,
                         scheme: str = "proposed", epsilon: float = 0.0):
    """Mean/std total cost over K channel realizations, solved in ONE
    batched XLA call — works for every scheme (proposed/ideal/wo_dt/oma/
    oma_tdma/random) now that the baselines have vmapped bodies."""
    from repro.core.fl_round import allocate_batched
    h2_batch = mc_channel_draws(key, k, n)
    alloc = allocate_batched(scheme, game, h2_batch,
                             jnp.broadcast_to(d, (k, n)),
                             jnp.broadcast_to(vmax, (k, n)),
                             epsilon=epsilon,
                             key=jax.random.fold_in(key, 1))
    cost = alloc.t_total + alloc.energy
    return {
        "mean_cost": float(jnp.mean(cost)),
        "std_cost": float(jnp.std(cost)),
        "mean_energy": float(jnp.mean(alloc.energy)),
        "mean_latency": float(jnp.mean(alloc.t_total)),
        "feasible_frac": float(jnp.mean(alloc.feasible.astype(jnp.float32))),
    }


def curve(hist, key="val_acc"):
    return [h[key] for h in hist]


def save_csv(name: str, header: str, rows):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


# ---------------------------------------------------------------------------
# scaling harness (1/2/4 forced host devices)
# ---------------------------------------------------------------------------
SCALING_DEVICES = (1, 2, 4)
SCALING_MARKER = "SCALING_ROWS "


def host_cores() -> int:
    return os.cpu_count() or 1


def run_scaling_workers(module: str, devices=SCALING_DEVICES,
                        timeout: int = 1200) -> dict:
    """Spawn ``python -m {module} --scaling-worker D`` once per device
    count, each child pinned to D forced host devices via XLA_FLAGS.
    The worker prints one ``SCALING_ROWS {json}`` line mapping tier name
    → {rate, parity_max_rel, ...}; returns {D: rows}."""
    out = {}
    for d in devices:
        env = dict(os.environ)
        for k in ("REPRO_FORCE_DEVICES", "REPRO_MESH_DEVICES"):
            env.pop(k, None)
        keep = [f for f in env.get("XLA_FLAGS", "").split()
                if not f.startswith("--xla_force_host_platform_device_count")]
        env["XLA_FLAGS"] = " ".join(
            keep + [f"--xla_force_host_platform_device_count={d}"])
        env[_DEVICES_APPLIED_ENV] = str(d)   # flags set directly: no re-exec
        proc = subprocess.run(
            [sys.executable, "-m", module, "--scaling-worker", str(d)],
            capture_output=True, text=True, timeout=timeout, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."))
        if proc.returncode != 0:
            raise RuntimeError(
                f"scaling worker {module} D={d} failed:\n"
                f"--- stdout ---\n{proc.stdout[-4000:]}\n"
                f"--- stderr ---\n{proc.stderr[-4000:]}")
        rows = None
        for line in proc.stdout.splitlines():
            if line.startswith(SCALING_MARKER):
                rows = json.loads(line[len(SCALING_MARKER):])
        if rows is None:
            raise RuntimeError(
                f"scaling worker {module} D={d} printed no "
                f"{SCALING_MARKER!r} line:\n{proc.stdout[-4000:]}")
        out[d] = rows
    return out


def scaling_section(module: str, gate_tiers, devices=SCALING_DEVICES,
                    min_efficiency: float = 0.70,
                    efficiency_noise: float = 0.10) -> dict:
    """Measure and assemble the ``scaling`` section of a BENCH JSON.

    ``efficiency_at_max = rate[Dmax] / (min(Dmax, host_cores) · rate[1])``
    — true parallel efficiency on a multi-core host, sharding-overhead
    retention on a 1-core container (see module docstring).  Only tiers
    in ``gate_tiers`` are held to ``min_efficiency`` by check_bench
    (serve latency, e.g., records rates but is not efficiency-gated);
    ``efficiency_noise`` is the declared run-to-run tolerance."""
    per_dev = run_scaling_workers(module, devices)
    dmax = max(devices)
    norm = min(dmax, host_cores())
    tiers = {}
    for name in per_dev[devices[0]]:
        rates = {str(d): per_dev[d][name]["rate"] for d in devices}
        parity = max(per_dev[d][name].get("parity_max_rel", 0.0)
                     for d in devices)
        tiers[name] = {
            "workload": per_dev[dmax][name].get("workload", name),
            "rates_per_s": rates,
            "efficiency_at_max": rates[str(dmax)] / (norm * rates["1"]),
            "parity_max_rel": parity,
            "parity_ok": parity <= 1e-5,
        }
    return {
        "devices_measured": list(devices),
        "host_cores": host_cores(),
        "normalizer": norm,
        "note": ("forced host devices on CPU; efficiency is normalized by "
                 "min(devices, host_cores) — sharding-overhead retention "
                 "on a 1-core box, true parallel efficiency on real "
                 "multi-core/accelerator hardware"),
        "efficiency_gate_tiers": list(gate_tiers),
        "min_efficiency": min_efficiency,
        "efficiency_noise": efficiency_noise,
        "tiers": tiers,
    }


def emit_scaling_rows(rows: dict) -> None:
    """Worker side of the protocol: print the tier rows for the parent."""
    print(SCALING_MARKER + json.dumps(rows), flush=True)
