"""Benchmark regression gate: fail if a tracked engine's measured
throughput regressed more than TOLERANCE vs the committed baseline
(``git show HEAD:<bench>.json``).

Tracked bench files and their gated metrics (higher is better):
  * ``BENCH_equilibrium.json``
      - ``results[].vmap_solves_per_sec``  — the K-axis Monte-Carlo path;
      - ``sweep.sweep_solves_per_sec``     — the config-grid sweep engine;
      - ``n_scaling[].blocked_solves_per_sec`` — the large-N blocked SIC
        engine rows (``sic_mode="blocked"``, one gate per N).
  * ``BENCH_training.json``
      - ``scan_rounds_per_sec``        — the scan-compiled FL trajectory;
      - ``vmap_rounds_per_sec``        — the seed-vmapped trajectory sweep;
      - ``sweep.sweep_rounds_per_sec`` — the C×S config-grid training
        sweep (the Fig. 5/6/7/8 workload as one dispatch).
  * ``BENCH_serve.json``
      - ``requests_per_sec``           — sustained throughput of the
        ragged-N streaming allocation service under the mixed-N arrival
        trace (``benchmarks/serve_latency.py``; p50/p99 latencies are
        recorded there but not gated — wall-clock percentiles on shared
        CI hosts are too noisy for a hard gate);
      - ``overload.requests_per_sec`` / ``chaos.requests_per_sec`` — the
        ISSUE-9 resilience sections (burst overload against the bounded
        SLA queue; the full_chaos fault-injection scenario), tolerance-
        declared at ±35% because both paths sleep on purpose.  Gating
        the rates doubles as a section-presence gate: once the baseline
        carries them, losing either section fails.  Their headline
        invariants ride the ``claims`` gate below — no lost requests
        under overload/chaos, high-priority p99 bounded, no NaN leaking
        through a ``status="ok"`` row.
  * ``BENCH_robustness.json``
      - ``grid_rounds_per_sec``        — the attack-vs-defense grid
        (``benchmarks/robustness_grid.py``) as sharded sweep dispatches;
      - plus the CLAIMS gate: every boolean under the file's ``claims``
        object must be true — a robustness headline (e.g. "the defended
        scheme stays within 5 pts of clean under the adaptive attacker")
        that stops holding fails the gate even if throughput is fine.
  * ``BENCH_mechanism.json``
      - ``grad_steps_per_sec``         — the jitted value_and_grad step
        through the solved Stackelberg equilibria (the IFT custom_vjp
        path, ``benchmarks/mechanism_design.py``), tolerance-declared at
        −35%;
      - plus the CLAIMS gate: learned knobs must beat the hand-picked
        objective, IFT gradients must be finite, the tuning run must
        compile once, and the learned mechanism's real-engine accuracy
        must stay within 5 pts of hand-picked.
    (The host-loop baseline tiers are recorded but not gated — they are
    the slow references, and their host-side dispatch overhead is the
    noisiest number in the file.)

Scaling gate: bench files may carry a ``scaling`` section (written by the
1/2/4-forced-host-device harness in ``benchmarks/common.py``).  When
present it is gated three ways: (1) every tier named in its
``efficiency_gate_tiers`` (the sweep/vmap tiers; serve records rates but
is latency-bound and not efficiency-gated) must hold
``efficiency_at_max ≥ min_efficiency − efficiency_noise`` (declared in
the section itself — default 70% minus the declared container-noise
margin, capped at 15 pts); (2) every tier's sharded-vs-single-device
``parity_max_rel`` must be ≤ 1e-5 (the multi-device numerics contract is
a hard gate, never noise-excused); (3) a bench whose committed baseline
has a ``scaling`` section but whose current file lost it FAILS — scaling
coverage must not silently disappear.

Tolerance: the default gate is a >20% drop.  A bench file may override
per metric via a top-level ``"tolerances": {"<label>": 0.35, ...}``
object (this container's timing noise is recorded at ±30% — see
CHANGES.md PR 4 note); the current file's override wins, then the
committed baseline's, then the default.  ``check(remeasure=..., k=...)``
takes a best-of-k re-measure hook: when a metric would fail, the hook is
asked for up to k−1 fresh measurements of that bench and the BEST value
per metric is gated — a transient scheduler stall on a shared host
should not fail a real gate.

Exit code 0 = pass (or nothing to compare: missing file, no git baseline,
or the baseline predates a metric).  Exit 1 = a gated metric regressed
past tolerance — or vanished from the current file while the baseline
tracks it (a bench that silently stops reporting a rate must not pass
the gate) — or a ``claims`` boolean is false — or the current file is
corrupt (a half-written JSON from a killed bench run FAILS that bench
explicitly; it must not exit 0 via the SKIP path).
Run directly or let ``scripts/dev_smoke.py`` invoke it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOLERANCE = 0.20          # default: >20% drop in a gated rate fails


def _equilibrium_metrics(doc) -> dict:
    out = {}
    for row in doc.get("results", []):
        val = row.get("vmap_solves_per_sec")
        if val is not None:          # keep 0.0: a collapsed rate must gate
            out[f"vmap_K{row.get('K')}"] = float(val)
    sweep = doc.get("sweep") or {}
    if sweep.get("sweep_solves_per_sec") is not None:
        out["sweep"] = float(sweep["sweep_solves_per_sec"])
    for row in doc.get("n_scaling", []):
        val = row.get("blocked_solves_per_sec")
        if val is not None:
            out[f"nscale_blocked_N{row.get('N')}"] = float(val)
    return out


def _training_metrics(doc) -> dict:
    out = {}
    for key, label in (("scan_rounds_per_sec", "scan"),
                       ("vmap_rounds_per_sec", "vmap")):
        if doc.get(key) is not None:
            out[label] = float(doc[key])
    sweep = doc.get("sweep") or {}
    if sweep.get("sweep_rounds_per_sec") is not None:
        out["sweep"] = float(sweep["sweep_rounds_per_sec"])
    return out


def _serve_metrics(doc) -> dict:
    out = {}
    if doc.get("requests_per_sec") is not None:
        out["requests_per_sec"] = float(doc["requests_per_sec"])
    # resilience sections (ISSUE 9): gating their rates also makes the
    # SECTIONS load-bearing — once the committed baseline has them, a
    # bench that stops reporting overload/chaos fails the missing-metric
    # rule instead of silently dropping coverage
    for section, label in (("overload", "overload_rps"),
                           ("chaos", "chaos_rps")):
        rate = (doc.get(section) or {}).get("requests_per_sec")
        if rate is not None:
            out[label] = float(rate)
    return out


def _robustness_metrics(doc) -> dict:
    out = {}
    if doc.get("grid_rounds_per_sec") is not None:
        out["grid_rounds_per_sec"] = float(doc["grid_rounds_per_sec"])
    return out


def _mechanism_metrics(doc) -> dict:
    out = {}
    if doc.get("grad_steps_per_sec") is not None:
        out["grad_steps_per_sec"] = float(doc["grad_steps_per_sec"])
    return out


BENCHES = (
    ("BENCH_equilibrium.json", _equilibrium_metrics),
    ("BENCH_training.json", _training_metrics),
    ("BENCH_serve.json", _serve_metrics),
    ("BENCH_robustness.json", _robustness_metrics),
    ("BENCH_mechanism.json", _mechanism_metrics),
)

# sentinel for "file exists but is unreadable" — distinct from None
# ("file absent", a legitimate SKIP): a corrupt bench must FAIL the gate
class _Corrupt:
    def __init__(self, reason: str):
        self.reason = reason


def _load_current(name: str):
    """Parse the working-tree bench file.  Absent → None (SKIP).  Present
    but unparseable (half-written JSON from a killed bench run, bad
    encoding, unreadable file) → ``_Corrupt`` so the caller fails that
    bench EXPLICITLY instead of crashing or skipping."""
    path = os.path.join(REPO_ROOT, name)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
        return _Corrupt(f"{type(e).__name__}: {e}")


def _load_committed(name: str):
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{name}"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        return None


def _tolerance_for(label: str, cur, ref) -> float:
    """Per-metric tolerance: the current file's ``tolerances`` object wins,
    then the committed baseline's, then the −20% default.  Values are
    fractional drops (0.35 = a 35% drop still passes)."""
    for doc in (cur, ref):
        tol = (doc.get("tolerances") or {}).get(label) if doc else None
        if tol is not None:
            return float(tol)
    return TOLERANCE


PARITY_LIMIT = 1e-5       # sharded == single-device numerics contract
NOISE_CAP = 0.15          # a declared efficiency_noise can't excuse more


def _check_scaling(cur, ref) -> tuple:
    """Gate the ``scaling`` section (see module docstring): efficiency of
    the declared gate tiers, sharded-vs-single-device parity of every
    tier, and loss of the section itself vs the committed baseline."""
    failures, lines = [], []
    sec = cur.get("scaling")
    if sec is None:
        if ref.get("scaling") is not None:
            lines.append("  scaling: section MISSING from current bench "
                         "(baseline has one) REGRESSED")
            failures.append("scaling")
        return failures, lines
    min_eff = float(sec.get("min_efficiency", 0.70))
    noise = min(float(sec.get("efficiency_noise", 0.0)), NOISE_CAP)
    gate_tiers = set(sec.get("efficiency_gate_tiers", ()))
    for tier, row in sorted((sec.get("tiers") or {}).items()):
        parity = row.get("parity_max_rel")
        if parity is None or float(parity) > PARITY_LIMIT:
            lines.append(f"  scaling.{tier}: parity_max_rel={parity} "
                         f"(limit {PARITY_LIMIT}) BROKEN")
            failures.append(f"scaling:{tier}:parity")
        if tier not in gate_tiers:
            continue
        eff = row.get("efficiency_at_max")
        floor = min_eff - noise
        if eff is None or float(eff) < floor:
            lines.append(f"  scaling.{tier}: efficiency_at_max={eff} "
                         f"< {min_eff:.0%} - {noise:.0%} noise REGRESSED")
            failures.append(f"scaling:{tier}:efficiency")
        else:
            lines.append(f"  scaling.{tier}: efficiency_at_max="
                         f"{float(eff):.2f} (floor {floor:.2f}) ok")
    return failures, lines


def _check_claims(cur) -> tuple:
    """Gate the bench file's own headline claims: every boolean under the
    top-level ``claims`` object must be true.  Non-boolean entries are
    recorded context (measured margins etc.), not gates."""
    failures, lines = [], []
    for label, val in sorted((cur.get("claims") or {}).items()):
        if not isinstance(val, bool):
            continue
        lines.append(f"  claim {label}: {'holds' if val else 'VIOLATED'}")
        if not val:
            failures.append(label)
    return failures, lines


def _check_one(name: str, metrics_fn, remeasure=None, k: int = 2):
    """Returns (failures, lines) for one bench file; skips when the file or
    its committed baseline is absent.

    ``remeasure`` (optional callable ``name -> fresh doc | None``) is the
    best-of-k hook: when a metric would fail, the bench is re-measured up
    to ``k - 1`` more times and the BEST value per metric is gated, so a
    one-off scheduler stall on a noisy shared host doesn't hard-fail."""
    cur, ref = _load_current(name), _load_committed(name)
    if isinstance(cur, _Corrupt):
        return ([f"{name}:corrupt"],
                [f"  CORRUPT bench file ({cur.reason}) FAILED"])
    if cur is None or ref is None:
        why = f"no {name}" if cur is None else \
              f"no committed baseline for {name} (git show failed)"
        return [], [f"  SKIP ({why})"]
    cur_m, ref_m = metrics_fn(cur), metrics_fn(ref)

    def failing_labels(m):
        bad = []
        for label, ref_val in ref_m.items():
            val = m.get(label)
            tol = _tolerance_for(label, cur, ref)
            if val is None or val / max(ref_val, 1e-9) < 1.0 - tol:
                bad.append(label)
        return bad

    remeasured = 0
    while remeasure is not None and failing_labels(cur_m) \
            and remeasured < k - 1:
        fresh = remeasure(name)
        remeasured += 1
        if fresh is None:
            break
        fresh_m = metrics_fn(fresh)
        cur_m = {label: max(v for v in (cur_m.get(label),
                                        fresh_m.get(label))
                            if v is not None)
                 for label in set(cur_m) | set(fresh_m)}

    failures, lines = [], []
    if remeasured:
        lines.append(f"  (re-measured {remeasured}x, best-of-"
                     f"{remeasured + 1} gated)")
    for label, ref_val in sorted(ref_m.items()):
        cur_val = cur_m.get(label)
        tol = _tolerance_for(label, cur, ref)
        if cur_val is None:
            # a gated metric the baseline tracks but the current file lost
            # IS a failure — silently un-gating it would let a broken bench
            # (or a total collapse written as a missing key) slip through
            lines.append(f"  {label}: MISSING from current bench (baseline "
                         f"{ref_val:.0f}/s) REGRESSED")
            failures.append(f"{name}:{label}")
            continue
        ratio = cur_val / max(ref_val, 1e-9)
        status = "ok" if ratio >= 1.0 - tol else "REGRESSED"
        lines.append(f"  {label}: {cur_val:.0f}/s vs baseline "
                     f"{ref_val:.0f}/s ({ratio:.2f}x, tol -{tol:.0%}) "
                     f"{status}")
        if status == "REGRESSED":
            failures.append(f"{name}:{label}")
    scaling_failures, scaling_lines = _check_scaling(cur, ref)
    lines.extend(scaling_lines)
    failures.extend(f"{name}:{c}" for c in scaling_failures)
    claim_failures, claim_lines = _check_claims(cur)
    lines.extend(claim_lines)
    failures.extend(f"{name}:claim:{c}" for c in claim_failures)
    return failures, lines


def check(verbose: bool = True, remeasure=None, k: int = 2) -> int:
    all_failures = []
    if verbose:
        print("check_bench: tracked rates vs committed baseline "
              f"(default tolerance -{TOLERANCE:.0%})")
    for name, metrics_fn in BENCHES:
        failures, lines = _check_one(name, metrics_fn,
                                     remeasure=remeasure, k=k)
        if verbose:
            print(f" {name}:")
            for line in lines:
                print(line)
        all_failures.extend(failures)
    if all_failures:
        print("check_bench: FAIL — regressed past tolerance, claim "
              f"violated, or corrupt: {', '.join(all_failures)}")
        return 1
    if verbose:
        print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(check())
