"""Benchmark regression gate: fail if a tracked engine's measured
throughput regressed more than TOLERANCE vs the committed baseline
(``git show HEAD:<bench>.json``).

Tracked bench files and their gated metrics (higher is better):
  * ``BENCH_equilibrium.json``
      - ``results[].vmap_solves_per_sec``  — the K-axis Monte-Carlo path;
      - ``sweep.sweep_solves_per_sec``     — the config-grid sweep engine;
      - ``n_scaling[].blocked_solves_per_sec`` — the large-N blocked SIC
        engine rows (``sic_mode="blocked"``, one gate per N).
  * ``BENCH_training.json``
      - ``scan_rounds_per_sec``        — the scan-compiled FL trajectory;
      - ``vmap_rounds_per_sec``        — the seed-vmapped trajectory sweep;
      - ``sweep.sweep_rounds_per_sec`` — the C×S config-grid training
        sweep (the Fig. 5/6/7/8 workload as one dispatch).
  * ``BENCH_serve.json``
      - ``requests_per_sec``           — sustained throughput of the
        ragged-N streaming allocation service under the mixed-N arrival
        trace (``benchmarks/serve_latency.py``; p50/p99 latencies are
        recorded there but not gated — wall-clock percentiles on shared
        CI hosts are too noisy for a hard gate).
    (The host-loop baseline tiers are recorded but not gated — they are
    the slow references, and their host-side dispatch overhead is the
    noisiest number in the file.)

Exit code 0 = pass (or nothing to compare: missing file, no git baseline,
or the baseline predates a metric).  Exit 1 = a gated metric regressed
>20% — or vanished from the current file while the baseline tracks it
(a bench that silently stops reporting a rate must not pass the gate) —
or the current file is corrupt (a half-written JSON from a killed bench
run FAILS that bench explicitly; it must not exit 0 via the SKIP path).
Run directly or let ``scripts/dev_smoke.py`` invoke it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOLERANCE = 0.20          # >20% drop in a gated rate fails the gate


def _equilibrium_metrics(doc) -> dict:
    out = {}
    for row in doc.get("results", []):
        val = row.get("vmap_solves_per_sec")
        if val is not None:          # keep 0.0: a collapsed rate must gate
            out[f"vmap_K{row.get('K')}"] = float(val)
    sweep = doc.get("sweep") or {}
    if sweep.get("sweep_solves_per_sec") is not None:
        out["sweep"] = float(sweep["sweep_solves_per_sec"])
    for row in doc.get("n_scaling", []):
        val = row.get("blocked_solves_per_sec")
        if val is not None:
            out[f"nscale_blocked_N{row.get('N')}"] = float(val)
    return out


def _training_metrics(doc) -> dict:
    out = {}
    for key, label in (("scan_rounds_per_sec", "scan"),
                       ("vmap_rounds_per_sec", "vmap")):
        if doc.get(key) is not None:
            out[label] = float(doc[key])
    sweep = doc.get("sweep") or {}
    if sweep.get("sweep_rounds_per_sec") is not None:
        out["sweep"] = float(sweep["sweep_rounds_per_sec"])
    return out


def _serve_metrics(doc) -> dict:
    out = {}
    if doc.get("requests_per_sec") is not None:
        out["requests_per_sec"] = float(doc["requests_per_sec"])
    return out


BENCHES = (
    ("BENCH_equilibrium.json", _equilibrium_metrics),
    ("BENCH_training.json", _training_metrics),
    ("BENCH_serve.json", _serve_metrics),
)

# sentinel for "file exists but is unreadable" — distinct from None
# ("file absent", a legitimate SKIP): a corrupt bench must FAIL the gate
class _Corrupt:
    def __init__(self, reason: str):
        self.reason = reason


def _load_current(name: str):
    """Parse the working-tree bench file.  Absent → None (SKIP).  Present
    but unparseable (half-written JSON from a killed bench run, bad
    encoding, unreadable file) → ``_Corrupt`` so the caller fails that
    bench EXPLICITLY instead of crashing or skipping."""
    path = os.path.join(REPO_ROOT, name)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
        return _Corrupt(f"{type(e).__name__}: {e}")


def _load_committed(name: str):
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{name}"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        return None


def _check_one(name: str, metrics_fn):
    """Returns (failures, lines) for one bench file; skips when the file or
    its committed baseline is absent."""
    cur, ref = _load_current(name), _load_committed(name)
    if isinstance(cur, _Corrupt):
        return ([f"{name}:corrupt"],
                [f"  CORRUPT bench file ({cur.reason}) FAILED"])
    if cur is None or ref is None:
        why = f"no {name}" if cur is None else \
              f"no committed baseline for {name} (git show failed)"
        return [], [f"  SKIP ({why})"]
    cur_m, ref_m = metrics_fn(cur), metrics_fn(ref)
    failures, lines = [], []
    for label, ref_val in sorted(ref_m.items()):
        cur_val = cur_m.get(label)
        if cur_val is None:
            # a gated metric the baseline tracks but the current file lost
            # IS a failure — silently un-gating it would let a broken bench
            # (or a total collapse written as a missing key) slip through
            lines.append(f"  {label}: MISSING from current bench (baseline "
                         f"{ref_val:.0f}/s) REGRESSED")
            failures.append(f"{name}:{label}")
            continue
        ratio = cur_val / max(ref_val, 1e-9)
        status = "ok" if ratio >= 1.0 - TOLERANCE else "REGRESSED"
        lines.append(f"  {label}: {cur_val:.0f}/s vs baseline "
                     f"{ref_val:.0f}/s ({ratio:.2f}x) {status}")
        if status == "REGRESSED":
            failures.append(f"{name}:{label}")
    return failures, lines


def check(verbose: bool = True) -> int:
    all_failures = []
    if verbose:
        print("check_bench: tracked rates vs committed baseline "
              f"(tolerance -{TOLERANCE:.0%})")
    for name, metrics_fn in BENCHES:
        failures, lines = _check_one(name, metrics_fn)
        if verbose:
            print(f" {name}:")
            for line in lines:
                print(line)
        all_failures.extend(failures)
    if all_failures:
        print(f"check_bench: FAIL — regressed >{TOLERANCE:.0%} or corrupt: "
              f"{', '.join(all_failures)}")
        return 1
    if verbose:
        print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(check())
