"""Benchmark regression gate: fail if the Stackelberg engine's measured
throughput in ``BENCH_equilibrium.json`` regressed more than TOLERANCE
vs the committed baseline (``git show HEAD:BENCH_equilibrium.json``).

Gated metrics (higher is better):
  * ``results[].vmap_solves_per_sec``  — the K-axis Monte-Carlo path;
  * ``sweep.sweep_solves_per_sec``     — the config-grid sweep engine.

Exit code 0 = pass (or nothing to compare: missing file, no git baseline,
or baseline predates a metric).  Exit 1 = a gated metric regressed >20%.
Run directly or let ``scripts/dev_smoke.py`` invoke it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_equilibrium.json")
TOLERANCE = 0.20          # >20% drop in solves/sec fails the gate


def _load_current():
    if not os.path.exists(BENCH_JSON):
        return None
    with open(BENCH_JSON) as f:
        return json.load(f)


def _load_committed():
    try:
        blob = subprocess.run(
            ["git", "show", "HEAD:BENCH_equilibrium.json"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        return None


def _gated_metrics(doc) -> dict:
    """{label: solves_per_sec} for every gated metric present in ``doc``."""
    out = {}
    for row in doc.get("results", []):
        val = row.get("vmap_solves_per_sec")
        if val:
            out[f"vmap_K{row.get('K')}"] = float(val)
    sweep = doc.get("sweep") or {}
    if sweep.get("sweep_solves_per_sec"):
        out["sweep"] = float(sweep["sweep_solves_per_sec"])
    return out


def check(verbose: bool = True) -> int:
    cur, ref = _load_current(), _load_committed()
    if cur is None or ref is None:
        if verbose:
            why = "no BENCH_equilibrium.json" if cur is None else \
                  "no committed baseline (git show failed)"
            print(f"check_bench: SKIP ({why})")
        return 0
    cur_m, ref_m = _gated_metrics(cur), _gated_metrics(ref)
    failures, lines = [], []
    for label, ref_val in sorted(ref_m.items()):
        cur_val = cur_m.get(label)
        if cur_val is None:
            lines.append(f"  {label}: dropped from bench (baseline "
                         f"{ref_val:.0f}/s) — not gated")
            continue
        ratio = cur_val / max(ref_val, 1e-9)
        status = "ok" if ratio >= 1.0 - TOLERANCE else "REGRESSED"
        lines.append(f"  {label}: {cur_val:.0f}/s vs baseline "
                     f"{ref_val:.0f}/s ({ratio:.2f}x) {status}")
        if status == "REGRESSED":
            failures.append(label)
    if verbose:
        print("check_bench: solves/sec vs committed baseline "
              f"(tolerance -{TOLERANCE:.0%})")
        for line in lines:
            print(line)
    if failures:
        print(f"check_bench: FAIL — regressed >{TOLERANCE:.0%}: "
              f"{', '.join(failures)}")
        return 1
    if verbose:
        print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(check())
