"""List the largest tensors in a dry-run's saved optimized HLO.

    PYTHONPATH=src python scripts/big_buffers.py nemotron-4-340b train_4k pod [min_mb]
"""
import re
import sys
from collections import Counter

import zstandard as zstd

DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
      "f32": 4, "s64": 8, "f64": 8}

arch, shape, mesh = sys.argv[1], sys.argv[2], sys.argv[3]
min_mb = float(sys.argv[4]) if len(sys.argv) > 4 else 256.0
path = f"runs/dryrun/hlo/{arch}_{shape}_{mesh}.hlo.zst"
text = zstd.ZstdDecompressor().decompress(open(path, "rb").read()).decode()

pat = re.compile(r"=\s*((?:\([^=]*?\))|(?:\w+\[[\d,]*\]))")
shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
op_re = re.compile(r"\]\}?[^=]*?\s([\w\-]+)\(")
big = Counter()
for line in text.splitlines():
    s = line.strip()
    m = pat.search(s)
    if not m:
        continue
    total = 0
    for sm in shape_re.finditer(m.group(1)):
        dt, dims = sm.group(1), sm.group(2)
        if dt not in DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DT[dt]
    if total >= min_mb * 2**20:
        op = op_re.search(s)
        meta = re.search(r'op_name="([^"]*)"', s)
        big[(m.group(1)[:70], op.group(1) if op else "?",
             (meta.group(1)[:60] if meta else ""))] += 1
for (shp, op, meta), cnt in big.most_common(30):
    print(f"{cnt:4d}x {op:22s} {shp:72s} {meta}")
