"""Dev smoke: tiny variants of each family, forward + loss + decode."""
import jax, jax.numpy as jnp
from repro.models import (ATTN, CROSS, MAMBA, MOE, SHARED_ATTN, BlockSpec,
                          ModelConfig, decode_step, init_caches, init_params,
                          loss_fn, prefill)

def run(name, cfg, batch):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    loss, m = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    # decode one token
    caches = init_caches(cfg, batch["tokens"].shape[0], 64)
    tok = batch["tokens"][:, :1]
    logits, caches = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))(params, tok, caches)
    assert logits.shape == (batch["tokens"].shape[0], cfg.padded_vocab_size)
    assert jnp.all(jnp.isfinite(logits)), name
    print(f"{name}: loss={float(loss):.4f} decode ok")

B, S, V = 2, 32, 128
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
base = dict(tokens=toks, targets=toks)

# dense w/ alternating local/global + softcap (gemma-like)
cfg = ModelConfig(name="tiny-dense", family="dense", num_layers=4, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=V,
                  pattern=(BlockSpec(ATTN, 8), BlockSpec(ATTN, 0)),
                  attn_softcap=50.0, logit_softcap=30.0)
run("dense", cfg, base)

# moe
cfg = ModelConfig(name="tiny-moe", family="moe", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, head_dim=16, d_ff=64, vocab_size=V,
                  pattern=(BlockSpec(MOE, 0),), num_experts=4, num_experts_per_tok=2)
run("moe", cfg, base)

# ssm
cfg = ModelConfig(name="tiny-ssm", family="ssm", num_layers=2, d_model=64,
                  num_heads=1, num_kv_heads=1, head_dim=16, d_ff=0, vocab_size=V,
                  pattern=(BlockSpec(MAMBA),), ssm_state=16, ssm_head_dim=16,
                  ssm_chunk=8)
run("ssm", cfg, base)

# hybrid (zamba2-like: 3 mamba + shared attn)
cfg = ModelConfig(name="tiny-hybrid", family="hybrid", num_layers=4, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=V,
                  pattern=(BlockSpec(MAMBA), BlockSpec(SHARED_ATTN, 0)),
                  ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
run("hybrid", cfg, base)

# audio enc-dec
cfg = ModelConfig(name="tiny-audio", family="audio", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=V,
                  pattern=(BlockSpec(CROSS, 0),), encoder_layers=2, encoder_ratio=4)
frames = jax.random.normal(jax.random.PRNGKey(2), (B, S // 4, 64))
run("audio", cfg, dict(base, frames=frames))

# vlm
P = 8
cfg = ModelConfig(name="tiny-vlm", family="vlm", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=V,
                  pattern=(BlockSpec(ATTN, 0),), num_patch_tokens=P)
patches = jax.random.normal(jax.random.PRNGKey(3), (B, P, 64))
run("vlm", cfg, dict(tokens=toks[:, :S - P], targets=toks[:, :S - P], patches=patches))

print("ALL FAMILIES OK")

# batched Stackelberg equilibrium engine (core FL hot path): K realizations
# in one vmapped XLA call — exercises the jit/vmap throughput path in smoke
import dataclasses
from repro.core.channel import sample_sic_channel_batch
from repro.core.fl_round import allocate_batched
from repro.core.stackelberg import (GameConfig, TRACE_COUNTS,
                                    batched_equilibrium, sweep_equilibrium)

K, N = 8, 5
h2b = sample_sic_channel_batch(jax.random.PRNGKey(7), K, N)
alloc = batched_equilibrium(GameConfig(), h2b, jnp.full((N,), 200.0),
                            jnp.full((N,), 0.5))
assert alloc.energy.shape == (K,) and bool(jnp.all(jnp.isfinite(alloc.energy)))
assert bool(jnp.all(jnp.isfinite(alloc.t_total)))
print(f"batched equilibrium OK: K={K} mean_energy={float(alloc.energy.mean()):.4f}")

# sweep engine: a 4-point config grid × K draws in one dispatch, one trace
cfgs = [dataclasses.replace(GameConfig(), t_max=t) for t in (6., 8., 10., 12.)]
before = TRACE_COUNTS["sweep_equilibrium"]
sw = sweep_equilibrium(cfgs, h2b, jnp.full((N,), 200.0), jnp.full((N,), 0.5))
assert sw.energy.shape == (len(cfgs), K)
assert TRACE_COUNTS["sweep_equilibrium"] - before == 1, "sweep retraced"
print(f"sweep equilibrium OK: {len(cfgs)} configs x K={K}, 1 trace")

# large-N blocked SIC engine: N=128 Jacobi fixed-point sweeps must land on
# the same equilibrium as the sequential reverse-scan chain (ISSUE 5)
h2_128 = sample_sic_channel_batch(jax.random.PRNGKey(21), 4, 128)
d_128, vm_128 = jnp.full((128,), 200.0), jnp.full((128,), 0.5)
a_seq = batched_equilibrium(GameConfig(), h2_128, d_128, vm_128)
a_blk = batched_equilibrium(GameConfig(sic_mode="blocked"), h2_128, d_128,
                            vm_128)
_rel = lambda a, b: float(jnp.max(jnp.abs(a - b) /
                                  jnp.maximum(jnp.abs(b), 1e-12)))
# equilibrium-LEVEL bound is 1e-3, not the solver-level 1e-5: the Alg-2
# energy-change stopping rule can pick a different valid best-iterate from
# ~1e-7 solver residue on infeasible draws (see equilibrium_throughput.py)
assert _rel(a_blk.energy, a_seq.energy) < 1e-3, "blocked energy drift"
assert _rel(a_blk.p, a_seq.p) < 1e-3, "blocked power drift"
print(f"blocked SIC OK: N=128 K=4, energy rel={_rel(a_blk.energy, a_seq.energy):.2e}")

# every scheme has a batched Monte-Carlo path now
for scheme in ("proposed", "wo_dt", "oma", "oma_tdma", "random"):
    a = allocate_batched(scheme, GameConfig(), h2b, jnp.full((N,), 200.0),
                         jnp.full((N,), 0.5), key=jax.random.PRNGKey(1))
    assert a.energy.shape == (K,) and bool(jnp.all(jnp.isfinite(a.energy))), scheme
print("allocate_batched OK for all schemes")

# scan-compiled FL trajectory: R rounds in one lax.scan dispatch, round
# body traced exactly once, stacked-metrics history
from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, run_training_scan
from repro.core.reputation import init_reputation
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

_ks = jax.random.split(jax.random.PRNGKey(11), 6)
_data = make_federated_data(_ks[0], SYNTHETIC_MNIST, m=10, cap=32)
_params, _logits_fn = make_classifier("mlp", _ks[1], in_dim=784, hidden=16)
_state = FLState(params=_params, rep=init_reputation(10),
                 v_max=sample_v_max(_ks[2], 10, DTConfig()),
                 distances=sample_positions(_ks[3], 10), key=_ks[4])
_before = TRACE_COUNTS["run_round"]
_fin, _hist = run_training_scan(_state, _data,
                                FLConfig(n_selected=3, local_steps=4,
                                         server_steps=4, lr=0.1),
                                GameConfig(), _logits_fn, rounds=3)
assert _hist["val_acc"].shape == (3,)
assert bool(jnp.all(jnp.isfinite(_hist["val_acc"])))
assert TRACE_COUNTS["run_round"] - _before == 1, "scan retraced run_round"
print(f"run_training_scan OK: R=3, 1 trace, "
      f"val_acc={float(_hist['val_acc'][-1]):.3f}")

# config-axis training sweep: C=2 configs (ε/lr/t_max vary) × S=2 seeds ×
# R=2 rounds in ONE dispatch — the Fig. 5/6/7/8 grid workload; the round
# body must trace exactly once for the whole grid
from repro.core.fl_round import stack_states, sweep_training

_state_b = dataclasses.replace(_state, key=jax.random.PRNGKey(99))
_states = stack_states([_state, _state_b])
_fls = [FLConfig(n_selected=3, local_steps=4, server_steps=4, lr=lr,
                 epsilon=eps) for lr, eps in ((0.1, 0.0), (0.08, 0.3))]
_games = [dataclasses.replace(GameConfig(), t_max=t) for t in (9.0, 11.0)]
_before = TRACE_COUNTS["run_round"]
_fin_g, _grid = sweep_training(_states, _data, _fls, _games, _logits_fn,
                               rounds=2)
assert _grid["val_acc"].shape == (2, 2, 2)
assert bool(jnp.all(jnp.isfinite(_grid["val_acc"])))
assert TRACE_COUNTS["run_round"] - _before == 1, "sweep retraced run_round"
print(f"sweep_training OK: C=2 x S=2 x R=2, 1 trace, "
      f"val_acc={float(_grid['val_acc'][0, 0, -1]):.3f}")

# ragged-N streaming allocation service: 4 mixed-N requests spanning two
# buckets — padded solves finite, results restored to request order, and
# EXACTLY one trace per touched bucket executable (ISSUE 6 smoke)
import numpy as np
from repro.launch.alloc_serve import AllocationService, AllocRequest

_svc = AllocationService(buckets=(8, 16), max_batch=2)
_before = TRACE_COUNTS["serve_allocation"]
_rng = np.random.default_rng(5)
_ns = (3, 7, 12, 5)                        # buckets: 8, 8, 16, 8
for _n in _ns:
    _svc.submit(AllocRequest(h2=_rng.uniform(0.2, 2.0, _n), d=200.0,
                             v_max=0.5, epsilon=0.05))
_res = sorted(_svc.drain(), key=lambda r: r.rid)
assert [r.n for r in _res] == list(_ns)
assert [r.bucket for r in _res] == [8, 8, 16, 8]
assert all(np.isfinite(r.energy) and np.all(np.isfinite(r.p)) for r in _res)
_touched = len({(r.bucket) for r in _res})
assert TRACE_COUNTS["serve_allocation"] - _before == _touched, \
    "alloc-serve traced more than once per bucket"
print(f"alloc serve OK: {len(_res)} mixed-N requests, "
      f"{_touched} buckets, 1 trace each")

# SLA-resilience smoke (ISSUE 9): a 30-request burst with ONE injected
# dispatch stall into a bounded-queue SLA service — the exactly-once
# invariant must hold (every submitted rid drains exactly once, with a
# status from the contract vocabulary, zero lost)
from repro.launch.serve_chaos import (ChaosScenario, assert_exactly_once,
                                      run_chaos)

_burst = ChaosScenario(name="smoke_burst_stall", n_requests=30,
                       stall_dispatches=(1,), stall_s=0.2,
                       hi_priority_frac=0.25,
                       service_kwargs={"max_queue": 16, "max_batch": 4,
                                       "buckets": (8,)})
_rep = run_chaos(_burst)
assert_exactly_once(_rep)
assert _rep.submitted == 30 and len(_rep.results) == 30
assert _rep.injection["injected_stalls"] == 1
print(f"serve resilience OK: 30-request burst + 1 stall, 0 lost, "
      f"statuses={_rep.status_counts}")

# fault-injection engine: a tiny attack-vs-defense grid — 2 scenarios
# (clean-gates vs adaptive attacker + straggler storm) × S=2 seeds in ONE
# sweep dispatch, zero mid-grid retraces (ISSUE 7 smoke).  Every fault
# knob is a traced operand: the two scenarios share the executable.
from repro.core.faults import FaultConfig

_scenarios = [FaultConfig(),                   # legacy static attacker
              FaultConfig(rep_gate=0.85, p_outage=0.2, p_slow=0.3,
                          compute_slowdown=2.0, channel_fade=0.5)]
_fls_f = [FLConfig(n_selected=3, local_steps=4, server_steps=4, lr=0.1)] * 2
_before = TRACE_COUNTS["run_round"]
_fin_f, _fgrid = sweep_training(_states, _data, _fls_f, GameConfig(),
                                _logits_fn, rounds=2, faults=_scenarios)
assert _fgrid["val_acc"].shape == (2, 2, 2)
assert bool(jnp.all(jnp.isfinite(_fgrid["val_acc"])))
assert _fgrid["n_dropped"].shape == (2, 2, 2)
assert TRACE_COUNTS["run_round"] - _before == 1, "fault grid retraced"
print(f"fault grid OK: 2 scenarios x S=2 x R=2, 1 trace, "
      f"dropped={int(jnp.sum(_fgrid['n_dropped']))}")

# multi-device smoke (ISSUE 8): 4 forced host devices, a C=3 × K=5 sweep
# on the 2D (cfg, draw) mesh — non-divisible axes pad + slice back, the
# grid still traces exactly ONCE, and cells match per-instance solves.
# Subprocess: the XLA device count is fixed at jax import.
import os, pathlib, subprocess, sys
_root = pathlib.Path(__file__).resolve().parents[1]
_MD_SMOKE = r"""
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import (GameConfig, TRACE_COUNTS, equilibrium,
                                    sweep_equilibrium)
assert len(jax.devices()) == 4, jax.devices()
h2 = sample_sic_channel_batch(jax.random.PRNGKey(7), 5, 5)
d = jnp.full((5,), 200.0); vm = jnp.full((5,), 0.5)
cfgs = [dataclasses.replace(GameConfig(), t_max=t) for t in (6., 9., 12.)]
before = TRACE_COUNTS["sweep_equilibrium"]
sw = sweep_equilibrium(cfgs, h2, d, vm)
assert TRACE_COUNTS["sweep_equilibrium"] - before == 1, "sweep retraced"
en = np.asarray(jax.device_get(sw.energy))
assert en.shape == (3, 5), en.shape
ref = float(equilibrium(cfgs[1], h2[2], d, vm).energy)
rel = abs(float(en[1, 2]) - ref) / max(abs(ref), 1e-12)
assert rel <= 1e-5, rel
print("MULTIDEVICE_SMOKE_OK")
"""
_env = dict(os.environ)
_env["PYTHONPATH"] = (str(_root / "src") + os.pathsep +
                      _env.get("PYTHONPATH", ""))
_env["XLA_FLAGS"] = " ".join(
    [f for f in _env.get("XLA_FLAGS", "").split()
     if not f.startswith("--xla_force_host_platform_device_count")]
    + ["--xla_force_host_platform_device_count=4"])
_proc = subprocess.run([sys.executable, "-c", _MD_SMOKE], env=_env,
                       capture_output=True, text=True, timeout=420)
assert _proc.returncode == 0, _proc.stderr[-2000:]
assert "MULTIDEVICE_SMOKE_OK" in _proc.stdout
print("multi-device sweep OK: 4 forced devices, C=3 x K=5, 1 trace")

# mechanism tuning smoke (ISSUE 10): 2 AdamW steps END-TO-END through the
# solved Stackelberg equilibria (IFT custom_vjp) — every gradient leaf
# finite, objective finite, and both steps share ONE executable
from repro.core.mechanism import (MechanismStatics, init_params,
                                  mechanism_step, synthetic_context)
from repro.optim.adamw import init_opt_state

_mctx = synthetic_context(jax.random.PRNGKey(0), m=12, k_draws=2)
_mp = init_params(12)
_mopt = init_opt_state(_mp, MechanismStatics().adamw)
_before = TRACE_COUNTS["mechanism_step"]
for _ in range(2):
    _mp, _mopt, _mj, _mg = mechanism_step(_mp, _mopt, _mctx,
                                          MechanismStatics())
    assert bool(jnp.isfinite(_mj)), "mechanism objective not finite"
    assert all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(_mg)), \
        "NaN gradient through the IFT custom_vjp"
assert TRACE_COUNTS["mechanism_step"] - _before == 1, "mechanism retraced"
print(f"mechanism tuning OK: 2 grad-through-the-game steps, 1 trace, "
      f"J={float(_mj):.4f}")

# benchmark regression gate (no-op when BENCH json / git baseline is absent)
subprocess.run([sys.executable, str(_root / "scripts" / "check_bench.py")],
               check=True)
