"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ref import ssd_scan_ref, swa_attention_ref
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.swa_attention import swa_attention_pallas
from repro.models.ssm import ssd_chunked


def _ssd_inputs(key, bh, s, p, n, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bh, s, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (bh,)) * 0.3)
    b = jax.random.normal(ks[3], (bh, s, n)).astype(dtype)
    c = jax.random.normal(ks[4], (bh, s, n)).astype(dtype)
    return x, dt, a, b, c


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (1, 32, 8, 16, 8),
    (2, 64, 16, 32, 16),
    (4, 128, 32, 32, 32),
    (2, 128, 64, 128, 64),   # production-like tile shapes
])
def test_ssd_kernel_shapes(bh, s, p, n, chunk):
    x, dt, a, b, c = _ssd_inputs(jax.random.PRNGKey(0), bh, s, p, n, jnp.float32)
    ref = ssd_scan_ref(x, dt, a, b, c)
    out = ssd_scan_pallas(x, dt, a, b, c, chunk=chunk, interpret=True)
    assert out.shape == ref.shape
    jnp.allclose(out, ref)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-4


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 8e-2)])
def test_ssd_kernel_dtypes(dtype, tol):
    x, dt, a, b, c = _ssd_inputs(jax.random.PRNGKey(1), 2, 64, 16, 32, dtype)
    ref = ssd_scan_ref(x, dt, a, b, c).astype(jnp.float32)
    out = ssd_scan_pallas(x, dt, a, b, c, chunk=16,
                          interpret=True).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_ssd_kernel_matches_model_chunked_path():
    """The model's jnp SSD path and the kernel agree (same algorithm)."""
    key = jax.random.PRNGKey(2)
    b_, s, h, p, n = 2, 64, 3, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b_, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b_, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b_, s, h, n))
    cmat = jax.random.normal(ks[4], (b_, s, h, n))
    model_y = ssd_chunked(x, dt, a, bmat, cmat, chunk=16)
    # kernel layout: flatten (b, h) -> BH
    xk = x.transpose(0, 2, 1, 3).reshape(b_ * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b_ * h, s)
    ak = jnp.tile(a, b_)
    bk = bmat.transpose(0, 2, 1, 3).reshape(b_ * h, s, n)
    ck = cmat.transpose(0, 2, 1, 3).reshape(b_ * h, s, n)
    kern_y = ssd_scan_pallas(xk, dtk, ak, bk, ck, chunk=16, interpret=True)
    kern_y = kern_y.reshape(b_, h, s, p).transpose(0, 2, 1, 3)
    assert float(jnp.max(jnp.abs(kern_y - model_y))) < 2e-4


@pytest.mark.parametrize("s,d,window,block", [
    (128, 32, 0, 32),
    (128, 32, 32, 32),
    (256, 64, 64, 64),
    (256, 64, 128, 64),
    (512, 128, 128, 128),    # production tile
])
def test_swa_kernel_shapes(s, d, window, block):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (2, s, d)) * 0.5 for kk in ks)
    ref = swa_attention_ref(q, k, v, window=window)
    out = swa_attention_pallas(q, k, v, window=window, block=block,
                               interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("softcap", [0.0, 20.0, 50.0])
def test_swa_kernel_softcap(softcap):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = (jax.random.normal(kk, (1, 128, 32)) for kk in ks)
    ref = swa_attention_ref(q, k, v, window=64, softcap=softcap)
    out = swa_attention_pallas(q, k, v, window=64, softcap=softcap, block=32,
                               interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-2)])
def test_swa_kernel_bf16(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 32)).astype(dtype) for kk in ks)
    ref = swa_attention_ref(q, k, v, window=64).astype(jnp.float32)
    out = swa_attention_pallas(q, k, v, window=64, block=32,
                               interpret=True).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_swa_windowed_equals_global_when_window_covers_seq():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q, k, v = (jax.random.normal(kk, (1, 128, 16)) for kk in ks)
    a = swa_attention_pallas(q, k, v, window=128, block=32, interpret=True)
    b = swa_attention_pallas(q, k, v, window=0, block=32, interpret=True)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5
