"""Fault-injection scenario engine tests (ISSUE 7 tentpole).

Four contracts of ``repro.core.faults`` + its ``fl_round`` threading:

  * parity — the faulted scanned trajectory matches the faulted eager
    host loop, and the fault-free path is untouched by the new plumbing
    (``faults=None`` compiles the exact legacy round program — no extra
    metric keys, no PRNG stream change);
  * attack semantics — the adaptive reputation gate and the duty cycle
    behave exactly as specified (deterministic gate checks), sybil pools
    split one hoard across colluding IDs;
  * graceful mid-round degradation — a solve with dropped (h2=0, masked)
    lanes matches the exact n_eff-survivor solve ≤ 1e-5 on every surviving
    lane, for BOTH ``sic_mode`` families (the acceptance criterion);
  * compile behavior — a ≥3-attack × 2-defense × 2-seed grid runs as one
    sharded dispatch per (scheme, use_roni) with zero mid-grid retraces.

Plus seeded property tests (``tests/_prop`` fallback): reputation strictly
decreases for a detected poisoner and recovers boundedly after the attack
stops.

Shapes here are deliberately unusual (M=10 pool, hidden=22) so earlier
tests cannot have pre-warmed the jit cache and trace deltas are real.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _prop import given, settings, strategies as st

from repro.core import reputation as rep
from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.faults import (ATTACK_PROFILES, FaultConfig, FaultOps,
                               adaptive_attacker, attack_active,
                               duty_cycle_attacker, fault_ops,
                               stack_fault_ops, straggler_storm)
from repro.core.fl_round import (FLConfig, FLState, run_round,
                                 run_training_eager, run_training_scan,
                                 stack_states, sweep_training)
from repro.core.reputation import (BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS,
                                   ReputationState, init_reputation,
                                   update_interactions)
from repro.core.stackelberg import (TRACE_COUNTS, GameConfig,
                                    _physics_cached, _solve)
from repro.data.federated import make_federated_data, make_sybil_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

M, CAP, HID, NSEL = 10, 40, 22, 3
REL = 1e-5
STORM = FaultConfig(p_outage=0.4, p_slow=0.4, compute_slowdown=3.0,
                    channel_fade=0.4)


def _setup(seed=0, poison=0.3, m=M, cap=CAP, hidden=HID):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=m, cap=cap,
                               poison_ratio=poison)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784,
                                        hidden=hidden)
    state = FLState(params=params, rep=init_reputation(m),
                    v_max=sample_v_max(ks[2], m, DTConfig()),
                    distances=sample_positions(ks[3], m), key=ks[4])
    return state, data, logits_fn


def _fl(**kw):
    kw.setdefault("n_selected", NSEL)
    kw.setdefault("local_steps", 4)
    kw.setdefault("server_steps", 4)
    kw.setdefault("lr", 0.1)
    return FLConfig(**kw)


# ---------------------------------------------------------------------------
# parity: faulted scan == faulted eager; fault-free path untouched
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme,fc", [
    ("proposed", STORM),
    ("proposed", adaptive_attacker()),
    ("wo_dt", duty_cycle_attacker()),
])
def test_faulted_scan_matches_eager(scheme, fc):
    state, data, logits_fn = _setup(seed=1)
    fl = _fl(scheme=scheme)
    game = GameConfig()
    fs, stacked = run_training_scan(state, data, fl, game, logits_fn, 4,
                                    faults=fc)
    es, hist = run_training_eager(state, data, fl, game, logits_fn, 4,
                                  faults=fc)
    for k in ("val_acc", "latency", "energy", "n_dropped", "n_slowed",
              "n_attacking", "n_stragglers"):
        ref = jnp.asarray([h[k] for h in hist])
        rel = float(jnp.max(jnp.abs(stacked[k] - ref)
                            / jnp.maximum(jnp.abs(ref), 1e-12)))
        assert rel < REL, (k, rel)
    for new, old in zip(jax.tree_util.tree_leaves(fs.rep),
                        jax.tree_util.tree_leaves(es.rep)):
        assert bool(jnp.all(new == old))


def test_fault_free_path_has_no_fault_metrics():
    """``faults=None`` must compile the legacy round program: no fault
    metric keys, and identical results to the pre-fault engine (the
    figure-CSV byte-parity tests pin the numbers; here we pin the
    surface)."""
    state, data, logits_fn = _setup(seed=2)
    _, stacked = run_training_scan(state, data, _fl(), GameConfig(),
                                   logits_fn, 2)
    for k in ("n_dropped", "n_slowed", "n_attacking"):
        assert k not in stacked


def test_null_faultconfig_reproduces_static_attacker():
    """``FaultConfig()`` (gates wide open, no straggler process) is the
    legacy always-on label flipper: every selected poisoner attacks every
    round and nobody drops or slows."""
    state, data, logits_fn = _setup(seed=3)
    _, stacked = run_training_scan(state, data, _fl(), GameConfig(),
                                   logits_fn, 4, faults=FaultConfig())
    assert [int(x) for x in stacked["n_attacking"]] == \
           [int(x) for x in stacked["n_poisoned_selected"]]
    assert int(jnp.sum(stacked["n_dropped"])) == 0
    assert int(jnp.sum(stacked["n_slowed"])) == 0


# ---------------------------------------------------------------------------
# attack semantics
# ---------------------------------------------------------------------------
def test_adaptive_gate_blocks_low_reputation():
    """The reputation gate compares the attacker's own Eq.-16 score to the
    population median: a gate far above any plausible own/median ratio
    silences every attacker; a zero gate silences none (Z ≥ 0)."""
    state, data, logits_fn = _setup(seed=4)
    _, hi = run_training_scan(state, data, _fl(), GameConfig(), logits_fn,
                              3, faults=adaptive_attacker(rep_gate=50.0))
    assert int(jnp.sum(hi["n_attacking"])) == 0
    _, lo = run_training_scan(state, data, _fl(), GameConfig(), logits_fn,
                              3, faults=adaptive_attacker(rep_gate=0.0))
    assert [int(x) for x in lo["n_attacking"]] == \
           [int(x) for x in lo["n_poisoned_selected"]]


def test_duty_cycle_pattern():
    """period=2, on=1 ⇒ poison exactly on even rounds (round % 2 < 1)."""
    state, data, logits_fn = _setup(seed=5)
    _, m = run_training_scan(state, data, _fl(), GameConfig(), logits_fn,
                             6, faults=duty_cycle_attacker(period=2, on=1))
    att = [int(x) for x in m["n_attacking"]]
    pois = [int(x) for x in m["n_poisoned_selected"]]
    assert att[0::2] == pois[0::2]              # on-phase rounds
    assert att[1::2] == [0, 0, 0]               # off-phase rounds


def test_attack_active_gate_unit():
    """The gate function itself, off-trajectory: all three conjuncts."""
    fops = fault_ops(FaultConfig(rep_gate=0.5, duty_period=4, duty_on=2))
    poisoned = jnp.array([True, True, True, False])
    z = jnp.array([0.6, 0.4, 0.6, 0.9])
    z_ref = jnp.asarray(1.0)              # gate threshold = 0.5 · 1.0
    on = attack_active(fops, poisoned, z, z_ref,
                       jnp.asarray(1))                      # 1 % 4 < 2: on
    assert on.tolist() == [True, False, True, False]
    off = attack_active(fops, poisoned, z, z_ref,
                        jnp.asarray(3))                     # 3 % 4 ≥ 2: off
    assert off.tolist() == [False] * 4


def test_straggler_storm_metrics():
    """The storm scenario actually drops/slows clients, dropped clients
    count as stragglers (their update never arrives), and the trajectory
    stays finite through the masked re-solves."""
    state, data, logits_fn = _setup(seed=6, poison=0.0)
    _, m = run_training_scan(state, data, _fl(), GameConfig(), logits_fn,
                             8, faults=straggler_storm())
    assert int(jnp.sum(m["n_dropped"])) > 0
    assert int(jnp.sum(m["n_slowed"])) > 0
    assert bool(jnp.all(m["n_stragglers"] >= m["n_dropped"]))
    assert bool(jnp.all(jnp.isfinite(m["val_acc"])))
    assert bool(jnp.all(jnp.isfinite(m["latency"])))


def test_sybil_pool_split():
    """One hoard across P colluding IDs: equal small shares, flipped
    training labels, all flagged poisoned, clean slots untouched."""
    key = jax.random.PRNGKey(7)
    data = make_federated_data(key, SYNTHETIC_MNIST, m=M, cap=CAP,
                               poison_ratio=0.0)
    pool = 4
    syb = make_sybil_data(jax.random.PRNGKey(8), data, pool)
    share = CAP // pool
    assert syb.x.shape == data.x.shape
    assert bool(jnp.all(syb.poisoned[:pool]))
    assert bool(jnp.all(~syb.poisoned[pool:]))
    assert syb.sizes[:pool].tolist() == [float(share)] * pool
    assert int(jnp.sum(syb.mask[:pool])) == pool * share
    # flipped labels on the sybil slots, true labels preserved alongside
    assert bool(jnp.all(syb.y_train[:pool] == 9 - syb.y[:pool]))
    assert bool(jnp.all(syb.y_train[pool:] == data.y_train[pool:]))
    for f in ("x", "y", "mask", "sizes"):
        assert bool(jnp.all(getattr(syb, f)[pool:]
                            == getattr(data, f)[pool:])), f
    with pytest.raises(ValueError, match="pool size"):
        make_sybil_data(key, data, M + 1)


# ---------------------------------------------------------------------------
# graceful mid-round degradation: dropped lanes == exact-survivor solve
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sic_mode", ["sequential", "blocked"])
def test_dropped_lanes_match_survivor_solve(sic_mode):
    """The acceptance criterion: a solve where dropped clients ride as
    h2=0 masked tail lanes matches the exact n_eff-survivor solve ≤ 1e-5
    on every surviving lane, for both SIC engine families."""
    n, dropped = 8, (2, 5)
    rng = np.random.default_rng(17)
    h2 = np.sort(rng.uniform(0.2, 2.0, n).astype(np.float32))[::-1].copy()
    d = np.full(n, 200.0, np.float32)
    vm = np.full(n, 0.5, np.float32)
    phys = _physics_cached(GameConfig(), jnp.float32)
    tol = jnp.asarray(1e-6, jnp.float32)
    eps = jnp.asarray(0.05, jnp.float32)

    # dropped path: zero the outage lanes, re-sort (zeros sink to the
    # tail — exactly what the round body does), mask the tail
    alive = np.ones(n, bool)
    alive[list(dropped)] = False
    h2_f = np.where(alive, h2, 0.0)
    order = np.argsort(-h2_f, kind="stable")
    out_drop = _solve(phys, jnp.asarray(h2_f[order]), jnp.asarray(d[order]),
                      jnp.asarray(vm[order]), eps, 20, tol, "closed",
                      sic_mode, mask=jnp.asarray(alive[order]))

    # oracle: the survivors solved exactly at n_eff
    n_eff = int(alive.sum())
    out_ref = _solve(phys, jnp.asarray(h2[alive]), jnp.asarray(d[alive]),
                     jnp.asarray(vm[alive]), eps, 20, tol, "closed",
                     sic_mode, mask=None)

    for f in ("p", "q", "f", "alpha", "rates", "v"):
        got = np.asarray(getattr(out_drop, f))[:n_eff]
        ref = np.asarray(getattr(out_ref, f))
        rel = np.max(np.abs(got - ref) / np.maximum(np.abs(ref), 1e-12))
        assert rel <= REL, (f, rel)
    for f in ("t_total", "energy"):
        got, ref = float(getattr(out_drop, f)), float(getattr(out_ref, f))
        assert abs(got - ref) / max(abs(ref), 1e-12) <= REL, f
    assert bool(out_drop.feasible) == bool(out_ref.feasible)


def test_round_with_all_alive_matches_no_fault_solve():
    """p_outage=0 ⇒ the alive mask is all-True and the masked solve must
    equal the unmasked one (the mask plumbing itself is free)."""
    state, data, logits_fn = _setup(seed=9, poison=0.0)
    fl, game = _fl(), GameConfig()
    calm = FaultConfig()                         # no outage, no slowdown
    _, m_fault = run_round(state, data, fl, game, logits_fn, faults=calm)
    assert m_fault["n_dropped"] == 0
    # same state, no fault engine: latency/energy come from the same
    # equilibrium (the fault path only adds the extra PRNG split, which
    # feeds draws that gate NOTHING here)
    _, m_plain = run_round(state, data, fl, game, logits_fn)
    assert abs(m_fault["latency"] - m_plain["latency"]) <= REL
    assert abs(m_fault["energy"] - m_plain["energy"]) <= REL


# ---------------------------------------------------------------------------
# compile behavior: the attack-vs-defense grid
# ---------------------------------------------------------------------------
def test_attack_grid_zero_midgrid_retraces():
    """3 attacks × {reputation+RONI, reputation-only, no-defense} × 2
    seeds: ONE sweep dispatch per use_roni value (weights are traced, so
    rep-only and no-defense share the RONI-off executable) — the round
    body traces exactly twice for the whole grid."""
    per_seed = [_setup(seed=s) for s in range(2)]
    states = stack_states([s for s, _, _ in per_seed])
    data, logits_fn = per_seed[0][1], per_seed[0][2]
    attacks = [ATTACK_PROFILES["static"], ATTACK_PROFILES["adaptive"],
               ATTACK_PROFILES["duty"]]
    game = GameConfig()
    before = TRACE_COUNTS["run_round"]

    # defended: reputation + RONI (use_roni=True executable)
    fls_def = [_fl(weights=PROPOSED_WEIGHTS, use_roni=True)] * 3
    _, m_def = sweep_training(states, data, fls_def, game, logits_fn, 2,
                              faults=attacks)
    # rep-only and no-defense ride ONE RONI-off sweep: C = 3 attacks × 2
    # weight settings, weights traced along the config axis
    fls_off = ([_fl(weights=PROPOSED_WEIGHTS, use_roni=False)] * 3
               + [_fl(weights=BENCHMARK_WEIGHTS, use_roni=False)] * 3)
    _, m_off = sweep_training(states, data, fls_off, game, logits_fn, 2,
                              faults=attacks + attacks)
    assert TRACE_COUNTS["run_round"] - before == 2
    assert m_def["val_acc"].shape == (3, 2, 2)
    assert m_off["val_acc"].shape == (6, 2, 2)
    assert bool(jnp.all(jnp.isfinite(m_def["val_acc"])))
    assert bool(jnp.all(jnp.isfinite(m_off["val_acc"])))


def test_sweep_fault_validation():
    states = stack_states([_setup(seed=0)[0]])
    data, logits_fn = _setup(seed=0)[1], _setup(seed=0)[2]
    fls = [_fl()] * 2
    with pytest.raises(ValueError, match="fault axis mismatch"):
        sweep_training(states, data, fls, GameConfig(), logits_fn, 1,
                       faults=[FaultConfig()] * 3)
    with pytest.raises(ValueError, match=r"must be \[2\]-shaped"):
        sweep_training(states, data, fls, GameConfig(), logits_fn, 1,
                       faults=stack_fault_ops([FaultConfig()] * 3))
    with pytest.raises(ValueError, match="data_axis"):
        sweep_training(states, data, fls, GameConfig(), logits_fn, 1,
                       data_axis="nope")


# ---------------------------------------------------------------------------
# property tests: reputation under detection (tests/_prop fallback)
# ---------------------------------------------------------------------------
def _rep_state(pi: float, ni: float, m: int = 4) -> ReputationState:
    return ReputationState(ms=jnp.ones((m,)),
                           pi_count=jnp.full((m,), pi),
                           ni_count=jnp.full((m,), ni))


_D = jnp.full((4,), 100.0)
_IDX0 = jnp.asarray([0])
_POS = jnp.asarray([True])
_NEG = jnp.asarray([False])


@settings(max_examples=15)
@given(st.integers(min_value=1, max_value=40),
       st.integers(min_value=0, max_value=40),
       st.integers(min_value=1, max_value=6))
def test_reputation_strictly_decreases_on_detection(pi0, ni0, k):
    """Every recorded NI strictly sinks the detected poisoner's Eq.-16
    score (ξ3 > 0 and PI = pi/(pi+ni) is strictly decreasing in ni),
    while the untouched clients' scores never move."""
    state = _rep_state(float(pi0), float(ni0))
    z = rep.reputation(state, _D)
    for _ in range(k):
        state = update_interactions(state, _IDX0, _NEG)
        z_new = rep.reputation(state, _D)
        assert float(z_new[0]) < float(z[0])
        assert bool(jnp.all(z_new[1:] == z[1:]))
        z = z_new


@settings(max_examples=15)
@given(st.integers(min_value=1, max_value=10),
       st.integers(min_value=1, max_value=25))
def test_reputation_recovers_boundedly_after_attack_stops(n_attack, n_rec):
    """After the attack stops, PI recordings raise the score monotonically
    — but it stays STRICTLY below the counterfactual score of a client
    that was never detected (same positive history, no NIs): detections
    leave a permanent dent, recovery is bounded."""
    attacked = _rep_state(1.0, 0.0)
    clean = _rep_state(1.0, 0.0)
    for _ in range(n_attack):
        attacked = update_interactions(attacked, _IDX0, _NEG)
    z_prev = rep.reputation(attacked, _D)
    for _ in range(n_rec):
        attacked = update_interactions(attacked, _IDX0, _POS)
        clean = update_interactions(clean, _IDX0, _POS)
        z_att = rep.reputation(attacked, _D)
        assert float(z_att[0]) > float(z_prev[0])          # monotone up
        assert float(z_att[0]) < float(
            rep.reputation(clean, _D)[0])                  # bounded
        z_prev = z_att


def test_count_mask_skips_dropped_verdicts():
    """A dropped client's verdict is not recorded: count_mask=False rows
    leave both counters untouched (the server never saw an update)."""
    state = _rep_state(3.0, 2.0)
    idx = jnp.asarray([0, 1])
    verdicts = jnp.asarray([True, False])
    alive = jnp.asarray([False, True])
    out = update_interactions(state, idx, verdicts, count_mask=alive)
    assert float(out.pi_count[0]) == 3.0 and float(out.ni_count[0]) == 2.0
    assert float(out.ni_count[1]) == 3.0                   # recorded NI
    full = update_interactions(state, idx, verdicts)
    assert float(full.pi_count[0]) == 4.0                  # contrast
