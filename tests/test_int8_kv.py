"""int8-quantized KV cache (opt-in, decode path) vs bf16/f32 caches.

Per-(token, head) absmax scales; the test accepts the expected quantization
noise (≈127-level rounding through softmax) but requires greedy decisions to
be unchanged and the cache to actually be int8."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import decode_step, init_caches, init_params
from repro.models.attention import _quantize_kv, dequantize_cache


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 32)) * 3.0
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8
    x2 = q.astype(jnp.float32) * s[..., None]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(jnp.max(jnp.abs(x2 - x) / amax)) <= 1.0 / 127.0 + 1e-6


@pytest.mark.parametrize("arch", ["gemma2-9b", "granite-3-8b"])
def test_int8_decode_close_and_greedy_equal(arch):
    cfg = smoke_variant(get_config(arch)).replace(dtype="float32",
                                                  param_dtype="float32")
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    c1 = init_caches(cfg, 2, 16)
    c2 = init_caches(cfg8, 2, 16)
    leaf = c2["entries"][0]["k"]
    assert leaf.dtype == jnp.int8
    assert "k_scale" in c2["entries"][0]
    l1 = l2 = None
    for t in range(10):
        l1, c1 = decode_step(params, toks[:, t:t + 1], c1, cfg)
        l2, c2 = decode_step(params, toks[:, t:t + 1], c2, cfg8)
    rel = float(jnp.max(jnp.abs(l1 - l2))) / float(jnp.max(jnp.abs(l1)))
    assert rel < 0.15, rel                       # quantization noise bound
    assert bool(jnp.all(jnp.argmax(l1, -1) == jnp.argmax(l2, -1)))


def test_int8_cache_halves_residency():
    cfg = smoke_variant(get_config("granite-3-8b"))
    c_bf = init_caches(cfg, 2, 64)
    c_q = init_caches(cfg.replace(kv_cache_dtype="int8"), 2, 64)
    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree))
    # int8 values + f32 scales ≈ (1 + 4/hd)/2 of bf16 — close to half
    assert nbytes(c_q) < 0.6 * nbytes(c_bf)
