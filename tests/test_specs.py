"""Input-spec / shape-policy tests (deliverable f plumbing)."""
import jax.numpy as jnp
import pytest

from repro.configs import ALIASES, get_config
from repro.launch.specs import (LONG_DECODE_WINDOW, SHAPES, adapt_config,
                                input_specs, shape_applicable, token_specs)


def test_all_shapes_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", list(ALIASES))
def test_specs_cover_all_archs(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        ok, reason = shape_applicable(cfg, shape)
        if not ok:
            assert arch == "seamless-m4t-large-v2" and name == "long_500k"
            assert reason
            continue
        acfg = adapt_config(cfg, shape)
        spec = input_specs(acfg, shape)
        if shape.mode in ("train", "prefill"):
            toks = spec["batch"]["tokens"]
            assert toks.dtype == jnp.int32
            assert toks.shape[0] == shape.global_batch
            if cfg.num_patch_tokens:
                assert spec["batch"]["patches"].shape == \
                    (shape.global_batch, cfg.num_patch_tokens, cfg.d_model)
                assert toks.shape[1] == shape.seq_len - cfg.num_patch_tokens
            elif cfg.encoder_layers:
                assert spec["batch"]["frames"].shape[1] == \
                    shape.seq_len // cfg.encoder_ratio
            else:
                assert toks.shape[1] == shape.seq_len
        else:
            assert spec["token"].shape == (shape.global_batch, 1)
            assert "caches" in spec


def test_long_decode_forces_window_for_full_attention():
    for arch, expect_window in (("granite-3-8b", True), ("nemotron-4-340b", True),
                                ("mamba2-2.7b", False), ("gemma2-9b", False),
                                ("zamba2-2.7b", False)):
        cfg = adapt_config(get_config(arch), SHAPES["long_500k"])
        if expect_window:
            assert cfg.decode_window == LONG_DECODE_WINDOW, arch
        else:
            assert cfg.decode_window == 0, arch


def test_windowed_decode_cache_is_ring_sized():
    import jax
    from repro.models import init_caches
    cfg = adapt_config(get_config("granite-3-8b"), SHAPES["long_500k"])
    caches = jax.eval_shape(lambda: init_caches(cfg, 1, 524_288))
    k = caches["entries"][0]["k"]
    assert k.shape[-3] == LONG_DECODE_WINDOW      # ring buffer, not 500k
    # whereas the unwindowed variant would be full-length
    cfg2 = get_config("granite-3-8b")
    caches2 = jax.eval_shape(lambda: init_caches(cfg2, 1, 524_288))
    assert caches2["entries"][0]["k"].shape[-3] == 524_288
