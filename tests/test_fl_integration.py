"""FL system integration tests: training improves accuracy; RONI + PI
reputation defends against poisoning; schemes behave per the paper."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, run_training
from repro.core.reputation import (BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS,
                                   init_reputation)
from repro.core.stackelberg import GameConfig
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier


def _run(seed=0, rounds=12, poison=0.0, weights=PROPOSED_WEIGHTS,
         use_roni=True, scheme="proposed", epsilon=0.0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=12, cap=96,
                               poison_ratio=poison)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784, hidden=48)
    fl = FLConfig(n_selected=4, local_steps=12, server_steps=12, lr=0.1,
                  weights=weights, use_roni=use_roni, scheme=scheme,
                  epsilon=epsilon)
    state = FLState(params=params, rep=init_reputation(12),
                    v_max=sample_v_max(ks[2], 12, DTConfig()),
                    distances=sample_positions(ks[3], 12), key=ks[4])
    state, hist = run_training(state, data, fl, GameConfig(), logits_fn,
                               rounds)
    return hist


def test_fl_training_improves_accuracy():
    hist = _run(rounds=12)
    assert hist[-1]["val_acc"] > hist[0]["val_acc"] + 0.2
    assert hist[-1]["val_acc"] > 0.5


def test_fl_metrics_structure():
    hist = _run(rounds=2)
    h = hist[0]
    for k in ("val_acc", "latency", "energy", "total_cost",
              "n_excluded_roni", "n_stragglers", "mean_v"):
        assert k in h
    assert h["latency"] > 0 and h["energy"] > 0
    assert 0 <= h["mean_v"] <= 1


def test_roni_defends_against_poisoning():
    """With 40% poisoners, proposed (PI+RONI) ends above the PI-blind
    benchmark; and RONI actually fires."""
    prop = _run(seed=5, rounds=14, poison=0.4)
    bench = _run(seed=5, rounds=14, poison=0.4, weights=BENCHMARK_WEIGHTS,
                 use_roni=False)
    p = max(h["val_acc"] for h in prop[-4:])
    b = max(h["val_acc"] for h in bench[-4:])
    assert p >= b - 0.02, (p, b)
    assert sum(h["n_excluded_roni"] for h in prop) >= 1


def test_ideal_scheme_upper_bounds_proposed():
    ideal = _run(seed=3, rounds=10, scheme="ideal")
    prop = _run(seed=3, rounds=10, scheme="proposed")
    assert max(h["val_acc"] for h in ideal[-3:]) >= \
        max(h["val_acc"] for h in prop[-3:]) - 0.08


def test_dt_deviation_degrades_accuracy():
    clean = _run(seed=9, rounds=12, epsilon=0.0)
    noisy = _run(seed=9, rounds=12, epsilon=0.8)
    assert max(h["val_acc"] for h in clean[-4:]) >= \
        max(h["val_acc"] for h in noisy[-4:]) - 0.05


def test_staleness_selection_rotates_clients():
    hist = _run(rounds=10)
    seen = set()
    for h in hist:
        seen.update(int(i) for i in h["selected"])
    assert len(seen) >= 8   # MS term forces rotation across 12 clients
