"""NOMA transmission-model tests (paper §II-C), incl. the SIC capacity-region
property: uplink SIC achieves the MAC sum capacity EXACTLY."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline: seeded example replay (tests/_prop.py)
    from _prop import given, settings, strategies as st

from repro.core.channel import noise_power, sample_channel_gains, sample_positions
from repro.core.noma import (noma_rates, oma_rates, sic_order, sum_capacity,
                             tx_energy, tx_latency)


def test_sic_order_descending():
    h2 = jnp.array([3., 1., 7., 2.])
    o = sic_order(h2)
    assert list(h2[o]) == sorted(h2.tolist(), reverse=True)


@given(st.lists(st.floats(1e-14, 1e-9), min_size=2, max_size=8),
       st.lists(st.floats(0.01, 0.1), min_size=2, max_size=8))
@settings(max_examples=40, deadline=None)
def test_sum_rate_equals_mac_capacity(h2_list, p_list):
    """Σ_n R_n == B·log2(1 + Σ p|h|²/σ²): SIC loses nothing (property)."""
    n = min(len(h2_list), len(p_list))
    h2 = jnp.sort(jnp.array(h2_list[:n]))[::-1]
    p = jnp.array(p_list[:n])
    rates = noma_rates(p, h2)
    cap = sum_capacity(p, h2)
    assert float(jnp.sum(rates)) == pytest.approx(float(cap), rel=1e-4)


def test_last_decoded_interference_free():
    h2 = jnp.array([1e-10, 5e-11, 2e-11])
    p = jnp.full((3,), 0.05)
    rates = noma_rates(p, h2)
    expect = 1e6 * jnp.log2(1 + p[2] * h2[2] / noise_power())
    assert float(rates[2]) == pytest.approx(float(expect), rel=1e-6)


def test_rates_increase_with_own_power_last_client():
    h2 = jnp.array([1e-10, 5e-11])
    r1 = noma_rates(jnp.array([0.05, 0.02]), h2)
    r2 = noma_rates(jnp.array([0.05, 0.08]), h2)
    assert float(r2[1]) > float(r1[1])
    # and raising the later-decoded client's power hurts the earlier one
    assert float(r2[0]) < float(r1[0])


def test_sic_power_independence_downstream():
    """§V-B-3 premise: p_n does not affect R_m for m > n (decoded later)."""
    h2 = jnp.array([1e-10, 5e-11, 2e-11])
    ra = noma_rates(jnp.array([0.01, 0.05, 0.03]), h2)
    rb = noma_rates(jnp.array([0.09, 0.05, 0.03]), h2)
    assert jnp.allclose(ra[1:], rb[1:])


def test_oma_vs_noma_sum_rate():
    """NOMA ≥ OMA in sum rate for the same powers (spectral efficiency)."""
    key = jax.random.PRNGKey(0)
    h2 = jnp.sort(sample_channel_gains(
        key, sample_positions(jax.random.PRNGKey(1), 5)))[::-1]
    p = jnp.full((5,), 0.05)
    assert float(jnp.sum(noma_rates(p, h2))) > float(jnp.sum(oma_rates(p, h2)))


def test_latency_energy_formulas():
    r = jnp.array([2e6])
    t = tx_latency(1e6, r)
    assert float(t[0]) == pytest.approx(0.5)
    assert float(tx_energy(jnp.array([0.1]), t)[0]) == pytest.approx(0.05)


def test_noise_power_matches_table1():
    # −174 dBm/Hz over 1 MHz = −114 dBm ≈ 3.98e−15 W
    assert noise_power() == pytest.approx(3.981e-15, rel=1e-3)
