"""Scan-compiled FL trajectory tests (ISSUE 3 tentpole).

Three properties of ``run_training_scan`` / ``batched_training``:

  * parity — the scanned trajectory matches the legacy host-loop
    (``run_training_eager``) on final params and per-round metrics, for
    proposed + ideal schemes, with and without RONI;
  * compile behavior — ``TRACE_COUNTS['run_round']`` shows the round body
    traces exactly ONCE per (scheme, use_roni, shape) for an R-round scan
    and for an S-seed vmap, and numeric knobs (lr, ε, t_max) are traced
    operands, not compile keys;
  * trace-safe bookkeeping — a round where RONI rejects every update keeps
    the previous global params INSIDE the scan (no host branch).

Shapes here are deliberately unusual (M=11 pool, hidden=24) so earlier
tests cannot have pre-warmed the jit cache and trace deltas are real.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import (FLConfig, FLState, batched_training,
                                 run_training, run_training_eager,
                                 run_training_scan, stack_states)
from repro.core.reputation import init_reputation
from repro.core.stackelberg import GameConfig, TRACE_COUNTS
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

M, CAP, HID, NSEL = 11, 48, 24, 3
REL = 1e-5
SCALAR_METRICS = ("val_acc", "latency", "energy", "total_cost", "mean_v")
INT_METRICS = ("round", "n_excluded_roni", "n_stragglers",
               "n_poisoned_selected")


def _setup(seed=0, poison=0.25, m=M, cap=CAP, hidden=HID):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=m, cap=cap,
                               poison_ratio=poison)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784,
                                        hidden=hidden)
    state = FLState(params=params, rep=init_reputation(m),
                    v_max=sample_v_max(ks[2], m, DTConfig()),
                    distances=sample_positions(ks[3], m), key=ks[4])
    return state, data, logits_fn


def _fl(**kw):
    kw.setdefault("n_selected", NSEL)
    kw.setdefault("local_steps", 6)
    kw.setdefault("server_steps", 6)
    kw.setdefault("lr", 0.1)
    return FLConfig(**kw)


def _rel_params(a, b):
    """Per-leaf max |a−b| normalized by the leaf's magnitude."""
    return max(float(jnp.max(jnp.abs(x - y)) /
                     jnp.maximum(jnp.max(jnp.abs(y)), 1e-12))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _assert_scan_matches_eager(scheme, use_roni, rounds=4, seed=0,
                               params_rel=REL):
    state, data, logits_fn = _setup(seed=seed)
    fl = _fl(scheme=scheme, use_roni=use_roni)
    game = GameConfig()
    fs, stacked = run_training_scan(state, data, fl, game, logits_fn, rounds)
    es, hist = run_training_eager(state, data, fl, game, logits_fn, rounds)
    assert _rel_params(fs.params, es.params) < params_rel, (scheme, use_roni)
    assert _rel_params(fs.rep, es.rep) == 0.0
    for k in SCALAR_METRICS:
        ref = jnp.asarray([h[k] for h in hist])
        rel = float(jnp.max(jnp.abs(stacked[k] - ref)
                            / jnp.maximum(jnp.abs(ref), 1e-12)))
        assert rel < REL, (scheme, use_roni, k, rel)
    for k in INT_METRICS:
        assert [int(x) for x in stacked[k]] == [int(h[k]) for h in hist], k
    assert stacked["selected"].shape == (rounds, NSEL)
    for r, h in enumerate(hist):
        assert stacked["selected"][r].tolist() == h["selected"].tolist()


@pytest.mark.parametrize("scheme,use_roni", [("proposed", True),
                                             ("proposed", False),
                                             ("ideal", True),
                                             ("ideal", False)])
def test_scan_matches_host_loop(scheme, use_roni):
    _assert_scan_matches_eager(scheme, use_roni)


@pytest.mark.slow
def test_scan_matches_host_loop_long():
    """R = 20: per-round metrics stay ≤ 1e-5 rel and the discrete
    trajectory (selection, RONI verdicts, stragglers) is identical; the raw
    weights accumulate fp32 fusion-reordering drift through R×steps SGD
    updates, so they get a proportionally looser bound."""
    _assert_scan_matches_eager("proposed", True, rounds=20, seed=3,
                               params_rel=5e-3)


def test_run_training_shim_history_format():
    """The compat shim returns the legacy list-of-dicts history with python
    scalars (``selected`` stays an [N] int array per round)."""
    state, data, logits_fn = _setup(seed=1)
    fl = _fl()
    _, hist = run_training(state, data, fl, GameConfig(), logits_fn, 3)
    assert len(hist) == 3
    for r, h in enumerate(hist):
        assert isinstance(h["val_acc"], float)
        assert isinstance(h["n_excluded_roni"], int)
        assert h["round"] == r
        assert len(h["selected"]) == NSEL


# ---------------------------------------------------------------------------
# compile behavior
# ---------------------------------------------------------------------------
def test_scan_traces_round_body_once():
    """An R-round training is ONE ``lax.scan`` dispatch: the round body
    traces exactly once, and changing R or any numeric knob (lr, ε, t_max)
    must not retrace it — only (scheme, use_roni, shape) are compile keys."""
    state, data, logits_fn = _setup(seed=2, m=13, hidden=20)
    fl = _fl(scheme="wo_dt")       # scheme not used by other tests here
    game = GameConfig()
    before = TRACE_COUNTS["run_round"]
    _, stacked = run_training_scan(state, data, fl, game, logits_fn, 6)
    assert stacked["val_acc"].shape == (6,)
    assert TRACE_COUNTS["run_round"] - before == 1

    run_training_scan(state, data, fl, game, logits_fn, 6)
    assert TRACE_COUNTS["run_round"] - before == 1, "re-dispatch retraced"

    fl2 = dataclasses.replace(fl, lr=0.07, epsilon=0.2, roni_threshold=0.05)
    game2 = dataclasses.replace(game, t_max=8.0, bandwidth=2e6)
    run_training_scan(state, data, fl2, game2, logits_fn, 6)
    assert TRACE_COUNTS["run_round"] - before == 1, \
        "numeric FL/game knobs must be traced operands, not compile keys"


def test_batched_training_traces_round_body_once():
    """An S-seed × R-round sweep is one vmapped scan: one trace of the
    round body, and every seed matches its own sequential scan."""
    per_seed = [_setup(seed=s, m=13, hidden=20) for s in range(3)]
    states = stack_states([s for s, _, _ in per_seed])
    data, logits_fn = per_seed[0][1], per_seed[0][2]
    fl = _fl(scheme="wo_dt")
    game = GameConfig()
    before = TRACE_COUNTS["run_round"]
    bstate, bm = batched_training(states, data, fl, game, logits_fn, 4)
    assert TRACE_COUNTS["run_round"] - before == 1
    assert bm["val_acc"].shape == (3, 4)
    assert bm["selected"].shape == (3, 4, NSEL)
    for s in range(3):
        _, ref = run_training_scan(per_seed[s][0], data, fl, game,
                                   logits_fn, 4)
        rel = float(jnp.max(jnp.abs(bm["val_acc"][s] - ref["val_acc"])))
        assert rel < REL, s
        assert bm["selected"][s].tolist() == ref["selected"].tolist()
    assert TRACE_COUNTS["run_round"] - before == 2, \
        "per-seed reference scans share one (earlier-cached) trace"


def test_batched_training_per_seed_data_axis():
    """Per-seed datasets (e.g. an attacker-fraction axis) vmap alongside
    the seed axis and match per-dataset sequential scans."""
    a = _setup(seed=4, poison=0.0, m=13, hidden=20)
    b = _setup(seed=5, poison=0.4, m=13, hidden=20)
    states = stack_states([a[0], b[0]])
    data = jax.tree_util.tree_map(lambda x, y: jnp.stack([x, y]), a[1], b[1])
    fl = _fl(scheme="wo_dt")
    game = GameConfig()
    _, bm = batched_training(states, data, fl, game, logits_fn=a[2],
                             rounds=3)
    assert bm["val_acc"].shape == (2, 3)
    for s, (st, dt, fn) in enumerate((a, b)):
        _, ref = run_training_scan(st, dt, fl, game, fn, 3)
        assert float(jnp.max(jnp.abs(bm["val_acc"][s]
                                     - ref["val_acc"]))) < REL, s
    assert int(jnp.sum(bm["n_poisoned_selected"][0])) == 0
    assert int(jnp.sum(bm["n_poisoned_selected"][1])) >= 1


# ---------------------------------------------------------------------------
# trace-safe keep-previous-params
# ---------------------------------------------------------------------------
def test_empty_include_keeps_previous_params_inside_scan():
    """With an impossible RONI threshold every update (clients AND the
    DT/server one) is rejected each round; the keep-previous-params
    ``jnp.where`` must leave the global model bit-identical across the
    whole scanned trajectory."""
    state, data, logits_fn = _setup(seed=6)
    fl = _fl(roni_threshold=-10.0)     # acc would have to IMPROVE by 10
    final, stacked = run_training_scan(state, data, fl, GameConfig(),
                                       logits_fn, 4)
    assert [int(x) for x in stacked["n_excluded_roni"]] == [NSEL] * 4
    for new, old in zip(jax.tree_util.tree_leaves(final.params),
                        jax.tree_util.tree_leaves(state.params)):
        assert bool(jnp.all(new == old))
    # val_acc is therefore flat at the initial model's accuracy
    assert float(jnp.max(stacked["val_acc"])
                 - jnp.min(stacked["val_acc"])) == 0.0
