"""Mechanism-design layer: end-to-end knob learning through the game.

Four contracts of ``core.mechanism``:

  * transforms — ``init_params`` inverts ``params_to_knobs`` so tuning
    starts AT the paper's hand-picked point, and the knob space is
    constrained (ξ simplex, ε ≥ 0, threshold in [RONI_LO, RONI_HI]);
  * learning — a few AdamW steps strictly improve the objective from the
    hand-picked start, with finite gradients on every leaf, and the whole
    run is ONE compile (``TRACE_COUNTS['mechanism_step']``);
  * IFT plumbing — the objective's gradient flows through the solved
    Stackelberg equilibria (the selection-weight logits move the solve's
    cohort scoring; their gradient is nonzero);
  * round-trip — learned knobs evaluated through the REAL training engine
    via ``to_fl_config`` (host floats) and ``to_fl_ops`` + ``ops_override``
    (traced operands) are the SAME trajectory, with no new compile keys,
    and unknown override keys fail loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mechanism as mech
from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, fl_ops, run_training_scan
from repro.core.mechanism import (MechanismStatics, init_params,
                                  mechanism_objective, mechanism_step,
                                  params_to_knobs, synthetic_context,
                                  to_fl_config, to_fl_ops, tune_mechanism)
from repro.core.reputation import PROPOSED_WEIGHTS, init_reputation
from repro.core.stackelberg import TRACE_COUNTS
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier
from repro.optim.adamw import init_opt_state

M, K = 20, 2
STATICS = MechanismStatics(n_selected=5)


def _ctx(seed=0, m=M, k_draws=K):
    return synthetic_context(jax.random.PRNGKey(seed), m=m, k_draws=k_draws)


class TestKnobTransforms:
    def test_init_params_inverts_to_handpicked_point(self):
        p = init_params(M, weights=PROPOSED_WEIGHTS, epsilon=10.0,
                        roni_threshold=0.02, reward=0.1)
        k = params_to_knobs(p)
        np.testing.assert_allclose(np.asarray(k["xi"]),
                                   np.asarray(PROPOSED_WEIGHTS), rtol=1e-5)
        assert float(k["epsilon"]) == pytest.approx(10.0, rel=1e-4)
        assert float(k["roni_threshold"]) == pytest.approx(0.02, rel=1e-4)
        np.testing.assert_allclose(np.asarray(k["rewards"]), 0.1, rtol=1e-4)

    def test_knobs_respect_constraints_everywhere(self):
        key = jax.random.PRNGKey(3)
        p = init_params(M)
        wild = jax.tree_util.tree_map(
            lambda x: x + 5.0 * jax.random.normal(key, x.shape, x.dtype), p)
        k = params_to_knobs(wild)
        assert float(jnp.sum(k["xi"])) == pytest.approx(1.0, abs=1e-5)
        assert bool(jnp.all(k["xi"] >= 0))
        assert float(k["epsilon"]) >= 0.0
        assert mech.RONI_LO <= float(k["roni_threshold"]) <= mech.RONI_HI
        assert bool(jnp.all(k["rewards"] >= 0))


class TestTuning:
    def test_objective_improves_and_grads_finite_one_trace(self):
        ctx = _ctx()
        params = init_params(M)
        before = TRACE_COUNTS["mechanism_step"]

        opt = init_opt_state(params, STATICS.adamw)
        _p, _o, j0, grads = mechanism_step(params, opt, ctx, STATICS)
        for leaf in jax.tree_util.tree_leaves(grads):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        # the selection-weight gradient flows through the equilibria/IFT
        assert float(jnp.max(jnp.abs(grads.xi_logits))) > 0.0

        # 16 steps: AdamW dips for the first ~8 warmup steps, then the
        # leak/selection terms pull the objective well past the start
        tuned, hist = tune_mechanism(params, ctx, STATICS, steps=16)
        assert all(np.isfinite(hist["objective"]))
        assert hist["objective"][-1] > hist["objective"][0]
        assert hist["objective"][0] == pytest.approx(float(j0), rel=1e-5)
        # 17 steps, 1 executable
        assert TRACE_COUNTS["mechanism_step"] - before == 1

    def test_context_value_swap_reuses_executable(self):
        params = init_params(M)
        opt = init_opt_state(params, STATICS.adamw)
        mechanism_step(params, opt, _ctx(seed=0), STATICS)
        before = TRACE_COUNTS["mechanism_step"]
        _, _, j, _ = mechanism_step(params, opt, _ctx(seed=7), STATICS)
        assert TRACE_COUNTS["mechanism_step"] == before
        assert bool(jnp.isfinite(j))

    def test_learned_rewards_separate_honest_from_attackers(self):
        """The incentive layer must learn to pay honest clients more than
        attackers (who should not be worth their reward)."""
        ctx = _ctx()
        tuned, _ = tune_mechanism(init_params(M), ctx, STATICS, steps=10)
        r = params_to_knobs(tuned)["rewards"]
        n_bad = M // 4
        honest_r = float(jnp.mean(r[: M - n_bad]))
        attacker_r = float(jnp.mean(r[M - n_bad:]))
        assert honest_r > attacker_r


class TestEngineRoundTrip:
    def _setup(self, m=9):
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 6)
        data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=m, cap=32,
                                   poison_ratio=0.25)
        params, logits_fn = make_classifier("mlp", ks[1], in_dim=784,
                                            hidden=16)
        state = FLState(params=params, rep=init_reputation(m),
                        v_max=sample_v_max(ks[2], m, DTConfig()),
                        distances=sample_positions(ks[3], m), key=ks[4])
        return state, data, logits_fn

    def test_ops_override_matches_config_path_without_retrace(self):
        """to_fl_ops(params) through ops_override ≡ to_fl_config(params)
        baked into the config — same trajectory, same executable."""
        from repro.core.stackelberg import GameConfig
        state, data, logits_fn = self._setup()
        mp = init_params(9, weights=(0.2, 0.3, 0.5), epsilon=5.0,
                         roni_threshold=0.05)
        base = FLConfig(n_selected=3, local_steps=4, server_steps=4)
        game = GameConfig()

        cfg_path = to_fl_config(mp, base)
        fs_a, hist_a = run_training_scan(state, data, cfg_path, game,
                                         logits_fn, rounds=2)
        before = TRACE_COUNTS["run_round"]
        fs_b, hist_b = run_training_scan(state, data, base, game, logits_fn,
                                         rounds=2,
                                         ops_override=to_fl_ops(mp))
        assert TRACE_COUNTS["run_round"] == before   # same compile keys
        for la, lb in zip(jax.tree_util.tree_leaves(fs_a.params),
                          jax.tree_util.tree_leaves(fs_b.params)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-6)
        np.testing.assert_allclose(np.asarray(hist_a["val_acc"]),
                                   np.asarray(hist_b["val_acc"]), rtol=1e-6)

    def test_unknown_override_key_raises(self):
        from repro.core.stackelberg import GameConfig
        state, data, logits_fn = self._setup()
        with pytest.raises(ValueError, match="not FL knobs"):
            run_training_scan(state, data, FLConfig(n_selected=3), GameConfig(),
                              logits_fn, rounds=1,
                              ops_override={"learning_rate": 0.1})

    def test_fl_ops_exposes_every_numeric_knob(self):
        ops = fl_ops(FLConfig(), jnp.float32)
        assert set(ops) == {"lr", "epsilon", "roni_threshold",
                            "samples_per_unit", "weights"}
        assert ops["weights"].shape == (3,)
