"""Offline fallback for the ``hypothesis`` property-testing surface.

The tier-1 suite must collect and run in containers without ``hypothesis``
installed.  This module mirrors the tiny subset of the API the tests use —
``given``, ``settings`` and ``strategies`` (``floats`` / ``integers`` /
``lists``) — backed by seeded ``jax.random`` example generation, so the
property tests still execute as deterministic seeded example tests.

Usage in test modules (real hypothesis wins when available):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _prop import given, settings, strategies as st

No shrinking, no database, no assume(): just ``max_examples`` draws per
test, seeded from the test name so failures reproduce across runs.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import jax
import jax.numpy as jnp

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, key):
        """Draw one example from a jax PRNG key."""
        return self._draw(key)


def _floats(min_value, max_value):
    lo, hi = float(min_value), float(max_value)

    def draw(key):
        u = float(jax.random.uniform(key, ()))
        return lo + u * (hi - lo)
    return _Strategy(draw)


def _integers(min_value, max_value):
    lo, hi = int(min_value), int(max_value)

    def draw(key):
        return int(jax.random.randint(key, (), lo, hi + 1))
    return _Strategy(draw)


def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10):
    def draw(key):
        k_size, k_elems = jax.random.split(key)
        size = int(jax.random.randint(k_size, (), min_size, max_size + 1))
        keys = jax.random.split(k_elems, max(size, 1))
        return [elements.example(keys[i]) for i in range(size)]
    return _Strategy(draw)


class strategies:
    """Namespace matching ``hypothesis.strategies`` for the subset used."""
    floats = staticmethod(_floats)
    integers = staticmethod(_integers)
    lists = staticmethod(_lists)


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records ``max_examples`` on the test; other kwargs are accepted and
    ignored (deadline etc. have no meaning for seeded example replay)."""
    def deco(fn):
        fn._prop_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    """Runs the test once per seeded example; example i of test ``t`` uses
    PRNGKey(crc32(t) ^ i) so the sequence is stable across processes."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_prop_max_examples",
                        getattr(fn, "_prop_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            base = zlib.crc32(fn.__name__.encode())
            for i in range(n):
                key = jax.random.PRNGKey((base ^ i) & 0x7FFFFFFF)
                keys = jax.random.split(key, max(len(strats), 1))
                example = [s.example(keys[j]) for j, s in enumerate(strats)]
                try:
                    fn(*args, *example, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example (seeded fallback, draw {i}): "
                        f"{example!r}") from e
        # hide the example parameters from pytest's fixture resolution:
        # the wrapper supplies them itself, so it must present a bare
        # signature (and not advertise the original via __wrapped__)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
