"""Service-level chaos suite (marker: chaos — CI runs it as its own
job).  Drives `repro.launch.serve_chaos` scenarios through a live
AllocationService and asserts the graceful-degradation contract: the
exactly-once invariant under every storm, structured shedding that
spares high priority, and breaker trip→recovery mid-stream."""
import time

import numpy as np
import pytest

from repro.launch.alloc_serve import AllocationService, AllocRequest
from repro.launch.serve_chaos import (SCENARIOS, ChaosScenario,
                                      assert_exactly_once, run_chaos)

pytestmark = pytest.mark.chaos


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_preset_scenarios_exactly_once(name):
    report = run_chaos(SCENARIOS[name])
    assert_exactly_once(report)
    assert report.submitted + report.malformed_raised == \
        SCENARIOS[name].n_requests
    assert len(report.results) == report.submitted


def test_full_chaos_injections_fired_and_contained():
    report = run_chaos(SCENARIOS["full_chaos"])
    assert_exactly_once(report)
    inj = report.injection
    assert inj["injected_stalls"] == 1
    assert inj["injected_failures"] == 1
    assert inj["injected_poison"] == 1
    assert report.malformed_raised > 0           # malformed rows raised ...
    assert report.status_counts.get("ok", 0) > 0  # ... and the stream lived
    # NaN-channel requests were rejected structurally, not solved
    assert report.status_counts.get("rejected", 0) > 0
    assert report.health["counters"]["dispatch_retries"] >= 1


def test_overload_sheds_low_priority_only():
    # one bucket key, max_batch larger than the stream's burst so nothing
    # dispatches until drain: the bounded queue must shed — and with
    # fewer high-priority requests than queue slots, ONLY low priority
    scenario = ChaosScenario(
        name="shed_burst", n_requests=40, seed=5, hi_priority_frac=0.15,
        service_kwargs={"max_queue": 8, "max_batch": 16, "buckets": (8,)})
    report = run_chaos(scenario)
    assert_exactly_once(report)
    shed = [r for r in report.results if r.status == "shed"]
    hi = [r for r in report.results if r.priority == 2]
    assert len(shed) > 0                         # overload really shed
    assert {r.priority for r in shed} == {0}     # never a hi-priority row
    assert hi and all(r.status == "ok" for r in hi)
    assert report.health["counters"]["shed"] == len(shed)


def test_stall_does_not_lose_requests():
    report = run_chaos(SCENARIOS["stalled_dispatch"])
    assert_exactly_once(report)
    assert report.injection["injected_stalls"] == 1
    assert report.status_counts == {"ok": report.submitted}


def test_breaker_trips_and_recovers_mid_chaos():
    scenario = ChaosScenario(
        name="poison_run", n_requests=24, seed=9,
        poison_dispatches=(0, 1, 2),
        service_kwargs={"max_batch": 4, "buckets": (8,),
                        "breaker_threshold": 3,
                        "breaker_cooldown_s": 0.05})
    svc = AllocationService(**dict(scenario.service_kwargs))
    report = run_chaos(scenario, service=svc)
    assert_exactly_once(report)
    c = report.health["counters"]
    assert c["breaker_open"] >= 1                # three poisoned batches
    assert c["breaker_rejected"] >= 1            # fast-fail while open
    # cooldown elapses, executable is healthy again: half-open probe
    # closes the breaker and the stream resumes (seam passes through —
    # all poison ordinals are long consumed)
    time.sleep(0.06)
    rid = svc.submit(AllocRequest(h2=np.ones(3)))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"
    states = {b["state"] for b in svc.health()["breakers"].values()}
    assert states == {"closed"}
    log = svc.health()["breaker_transitions"]
    assert ("n8/proposed/projected/sequential", "open", "half_open") in log
    assert ("n8/proposed/projected/sequential", "half_open", "closed") in log


def test_chaos_run_is_deterministic_in_accounting():
    a = run_chaos(SCENARIOS["nan_storm"])
    b = run_chaos(SCENARIOS["nan_storm"])
    assert a.status_counts == b.status_counts
    assert a.submitted == b.submitted
    assert [r.rid for r in a.results] == [r.rid for r in b.results]
