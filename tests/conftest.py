"""Shared test fixtures.

Trace-counter isolation: many tests assert ``TRACE_COUNTS`` deltas to
prove compile sharing (test_sweep_engine.py, test_training_scan.py,
test_training_sweep.py).  The counters are module-level state, so without
a reset they accumulate across tests and an assertion could pass or fail
depending on execution order.  The autouse fixture zeroes them before
every test; each test still snapshots its own ``before`` value, and the
jit caches themselves are untouched (tests that need a genuinely cold
cache use shapes no other test compiles)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.core.stackelberg import reset_trace_counts


@pytest.fixture(autouse=True)
def _fresh_trace_counts():
    reset_trace_counts()
    yield
