"""Per-architecture smoke tests (deliverable f): reduced variants of each
assigned arch run one forward/train step on CPU — shapes + no NaNs —
plus decode-path/forward-path consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.launch.steps import make_train_step
from repro.models import (decode_step, forward_logits, init_caches,
                          init_params, loss_fn)
from repro.optim import AdamWConfig, init_opt_state


def _batch(cfg, key, b=2, s=32):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.num_patch_tokens:
        p = cfg.num_patch_tokens
        batch = {"tokens": toks[:, :s - p], "targets": toks[:, :s - p],
                 "patches": jax.random.normal(key, (b, p, cfg.d_model))}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (b, s // cfg.encoder_ratio, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    assert cfg.num_layers <= 2 * len(cfg.pattern)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = init_opt_state(params, AdamWConfig())
    step = make_train_step(cfg, AdamWConfig(), num_microbatches=2)
    batch = _batch(cfg, key)
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(m["loss"]), arch
    assert jnp.isfinite(m["grad_norm"]), arch
    # params actually changed
    d0 = jax.tree_util.tree_leaves(params)[0]
    d1 = jax.tree_util.tree_leaves(p2)[0]
    assert not jnp.allclose(d0, d1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    caches = init_caches(cfg, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, caches2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, cfg))(params, tok, caches)
    assert logits.shape == (2, cfg.padded_vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert int(caches2["pos"]) == 1


@pytest.mark.parametrize("arch", ["gemma2_9b", "mamba2_2p7b", "zamba2_2p7b",
                                  "olmoe_1b_7b"])
def test_decode_matches_forward(arch):
    """Greedy next token from the decode path == full-forward argmax.

    MoE archs need capacity_factor ≥ E/k so the forward pass's
    expert-choice dispatch drops nothing (decode always serves exactly)."""
    cfg = smoke_variant(get_config(arch))
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=float(
            cfg.num_experts / max(cfg.num_experts_per_tok, 1)) + 1.0)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits_fwd, _ = forward_logits(params, {"tokens": toks}, cfg)
    caches = init_caches(cfg, b, s + 2)
    logits_dec = None
    for t in range(s):
        logits_dec, caches = decode_step(params, toks[:, t:t + 1], caches, cfg)
    a = jnp.argmax(logits_fwd[:, -1, :cfg.vocab_size], -1)
    bb = jnp.argmax(logits_dec[:, :cfg.vocab_size], -1)
    assert jnp.array_equal(a, bb), arch


def test_padded_vocab_masked():
    cfg = smoke_variant(get_config("mamba2_2p7b")).replace(vocab_size=500)
    assert cfg.padded_vocab_size == 512
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _ = forward_logits(params, {"tokens": toks}, cfg)
    assert bool(jnp.all(logits[..., 500:] < -1e29))


def test_loss_mask_excludes_positions():
    cfg = smoke_variant(get_config("granite_3_8b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    full, _ = loss_fn(params, {"tokens": toks, "targets": toks}, cfg)
    masked, _ = loss_fn(params, {"tokens": toks, "targets": toks,
                                 "loss_mask": jnp.zeros((2, 16)).at[:, :4].set(1.0)},
                        cfg)
    assert not jnp.allclose(full, masked)
