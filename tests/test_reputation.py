"""Reputation / selection / aggregation / RONI / DT tests (paper §III, Eq. 3)."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline: seeded example replay (tests/_prop.py)
    from _prop import given, settings, strategies as st

import repro.core.reputation as rep
from repro.core.aggregation import dt_aggregate, fedavg
from repro.core.digital_twin import dt_feature_noise, split_mapping_mask
from repro.core.roni import roni_filter


def test_ac_increasing_concave():
    d = jnp.linspace(0, 5000, 100)
    ac = rep.accuracy_contribution(d)
    diffs = jnp.diff(ac)
    assert bool(jnp.all(diffs > 0))           # increasing
    assert bool(jnp.all(jnp.diff(diffs) < 1e-9))  # concave


def test_staleness_update_and_normalization():
    state = rep.init_reputation(4)
    sel = jnp.array([True, False, False, False])
    state = rep.update_staleness(state, sel)
    state = rep.update_staleness(state, jnp.zeros(4, bool))
    # client 0 selected at round 1 → ms reset to 1 then +1 = 2; others 3
    assert list(state.ms) == [2.0, 3.0, 3.0, 3.0]
    ms_bar = rep.normalized_staleness(state.ms)
    assert float(jnp.sum(ms_bar)) == pytest.approx(1.0)


def test_pi_ratio():
    state = rep.init_reputation(2)
    state = rep.update_interactions(state, jnp.array([0, 1]),
                                    jnp.array([True, False]))
    pi = rep.positive_interaction(state)
    assert float(pi[0]) == pytest.approx(1.0)       # 2 PI / 2
    assert float(pi[1]) == pytest.approx(0.5)       # 1 PI, 1 NI


def test_selection_prefers_high_reputation():
    state = rep.init_reputation(6)
    state.ni_count = state.ni_count.at[0].set(50.0)   # notorious poisoner
    d = jnp.full((6,), 1000.0)
    sel, z = rep.select_clients(state, d, 3)
    assert 0 not in sel.tolist()


def test_selection_staleness_rotation():
    """Unselected clients gain staleness and eventually get picked."""
    state = rep.init_reputation(6)
    d = jnp.full((6,), 1000.0)
    seen = set()
    for _ in range(6):
        sel, _ = rep.select_clients(state, d, 2)
        seen.update(sel.tolist())
        mask = jnp.zeros((6,), bool).at[sel].set(True)
        state = rep.update_staleness(state, mask)
    assert seen == set(range(6))   # MS term guarantees coverage


def test_selection_tie_break_is_lowest_index():
    """Equal reputations (the init-state norm: identical priors, equal
    data) must select the LOWEST indices — the tie-break is part of the
    selection contract, not a backend sort accident."""
    state = rep.init_reputation(8)
    d = jnp.full((8,), 1000.0)
    sel, z = rep.select_clients(state, d, 3)
    assert bool(jnp.all(z == z[0]))          # genuinely tied
    assert sel.tolist() == [0, 1, 2]
    # a single strictly-better client still wins; ties fill the rest
    # (init PI ratio is already 1.0, so demote everyone except client 5)
    state2 = rep.init_reputation(8)
    state2.ni_count = jnp.ones((8,)).at[5].set(0.0)
    sel2, z2 = rep.select_clients(state2, d, 3)
    assert float(z2[5]) > float(z2[0])
    assert sel2.tolist() == [5, 0, 1]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_update_interactions_preserves_counter_dtype(dtype):
    """Integer (or any non-default) counter dtypes must survive the
    scatter-add: the bool verdict mask is cast to the counter dtype, not
    the other way round (pre-fix, int counters were silently promoted —
    or the add rejected — depending on jax version)."""
    state = rep.ReputationState(ms=jnp.ones((3,)),
                                pi_count=jnp.ones((3,), dtype),
                                ni_count=jnp.zeros((3,), dtype))
    out = rep.update_interactions(state, jnp.array([0, 2]),
                                  jnp.array([True, False]))
    assert out.pi_count.dtype == dtype
    assert out.ni_count.dtype == dtype
    assert out.pi_count.tolist() == [2, 1, 1]
    assert out.ni_count.tolist() == [0, 0, 1]
    # count_mask gating keeps dtype too and records nothing when masked
    out2 = rep.update_interactions(state, jnp.array([0, 2]),
                                   jnp.array([True, False]),
                                   count_mask=jnp.array([False, False]))
    assert out2.pi_count.dtype == dtype
    assert out2.pi_count.tolist() == [1, 1, 1]
    assert out2.ni_count.tolist() == [0, 0, 0]


def test_reputation_accepts_traced_weights():
    """Eq. 16 is linear in ξ — the mechanism layer differentiates through
    the weights, so ``reputation`` must accept a traced weight vector."""
    state = rep.init_reputation(4)
    d = jnp.linspace(500.0, 2000.0, 4)

    def z_sum(w):
        return jnp.sum(rep.reputation(state, d, 0.0, (w[0], w[1], w[2])))

    g = jax.grad(z_sum)(jnp.array([0.3, 0.5, 0.2]))
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(g[1]) == pytest.approx(1.0)   # Σ MS̄ = 1 exactly


@given(st.integers(2, 8), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_weights_bound_reputation(n, seed):
    key = jax.random.PRNGKey(seed)
    state = rep.init_reputation(n)
    d = jax.random.uniform(key, (n,)) * 5000
    z = rep.reputation(state, d)
    assert bool(jnp.all(z >= 0)) and bool(jnp.all(z <= 1.0 + 1e-6))


# ---------------------------------------------------------------------------
# aggregation Eq. (3)
# ---------------------------------------------------------------------------
def _toy_params(vals):
    return {"w": jnp.stack([jnp.full((3,), v) for v in vals])}


def test_aggregate_identity_when_all_equal():
    """Γ-property (Eq. 4): if w_n = w_S = w and ε = 0, aggregate returns w."""
    client = _toy_params([2.0, 2.0])
    server = {"w": jnp.full((3,), 2.0)}
    d = jnp.array([10.0, 30.0])
    v = jnp.array([0.25, 0.5])
    out = dt_aggregate(client, server, d, v, epsilon=0.0)
    assert jnp.allclose(out["w"], 2.0)


def test_aggregate_gamma_scaling_with_epsilon():
    """With ε > 0 the same-weights aggregate scales by Γ = 1 + εN/D (Eq. 4)."""
    client = _toy_params([1.0, 1.0])
    server = {"w": jnp.ones((3,))}
    d = jnp.array([10.0, 30.0])
    v = jnp.array([0.2, 0.2])
    eps = 2.0
    out = dt_aggregate(client, server, d, v, epsilon=eps)
    gamma = 1 + eps * 2 / 40.0
    assert jnp.allclose(out["w"], gamma)


def test_aggregate_weights_by_data_size():
    client = _toy_params([0.0, 1.0])
    server = {"w": jnp.zeros((3,))}
    d = jnp.array([10.0, 90.0])
    v = jnp.zeros((2,))
    out = dt_aggregate(client, server, d, v, epsilon=0.0)
    assert jnp.allclose(out["w"], 0.9)


def test_fedavg_excludes_masked():
    client = _toy_params([1.0, 5.0])
    out = fedavg(client, jnp.array([10., 10.]),
                 include_mask=jnp.array([True, False]))
    assert jnp.allclose(out["w"], 1.0)


# ---------------------------------------------------------------------------
# RONI
# ---------------------------------------------------------------------------
def test_roni_flags_poisoned_update():
    """A client pushing the aggregate across the decision boundary is
    detected by the leave-one-out validation sweep."""
    def logits_fn(p, x):
        s = (x @ p["w"])
        return jnp.stack([-s, s], axis=1)

    x_val = jnp.array([[1.0], [-1.0], [2.0], [-2.0]])
    y_val = jnp.array([1, 0, 1, 0])
    one = jnp.ones((1,))
    client = {"w": jnp.stack([one, one, -9.0 * one])}
    server = {"w": one}
    d = jnp.full((3,), 10.0)
    v = jnp.zeros((3,))
    pos, _, _ = roni_filter(client, server, d, v, 0.0, logits_fn,
                            x_val, y_val, 0.02)
    assert pos.tolist() == [True, True, False]


# ---------------------------------------------------------------------------
# digital twin
# ---------------------------------------------------------------------------
def test_mapping_mask_respects_ratio():
    key = jax.random.PRNGKey(0)
    mask = jnp.ones((2, 2000), bool)
    v = jnp.array([0.0, 0.5])
    mm = split_mapping_mask(key, mask, v)
    assert int(mm[0].sum()) == 0
    frac = float(mm[1].mean())
    assert 0.42 < frac < 0.58


def test_dt_noise_bounded():
    key = jax.random.PRNGKey(1)
    x = jnp.ones((100, 10))
    for eps in (0.0, 0.3):
        xn = dt_feature_noise(key, x, eps)
        assert bool(jnp.all(jnp.abs(xn - x) <= eps + 1e-6))
