"""Batched Stackelberg engine tests: the jitted/vmapped solver must be a
drop-in replacement for the legacy eager loop (ISSUE 1 acceptance).

 (a) jitted single-instance solve == legacy eager loop on 20 random draws
     (energy/latency within 1e-5 relative);
 (b) vmap over K=32 draws == the K sequential jitted solves;
 (c) deadline feasibility whenever a feasible iterate exists.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import sample_channel_gains, sample_positions
from repro.core.stackelberg import (Allocation, GameConfig,
                                    batched_equilibrium,
                                    batched_wo_dt_allocation, equilibrium,
                                    equilibrium_eager, wo_dt_allocation)

CFG = GameConfig()
N = 5
REL = 1e-5


def _draw(seed: int, n: int = N):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h2 = jnp.sort(sample_channel_gains(k2, sample_positions(k1, n)))[::-1]
    d = 100.0 + 200.0 * jax.random.uniform(k3, (n,))
    vmax = 0.3 + 0.5 * jax.random.uniform(k4, (n,))
    return h2, d, vmax


def _batch(k: int, seed0: int = 100):
    hs, ds, vs = zip(*[_draw(seed0 + s) for s in range(k)])
    return jnp.stack(hs), jnp.stack(ds), jnp.stack(vs)


def _rel(a, b):
    return abs(float(a) - float(b)) / max(abs(float(b)), 1e-12)


# ---------------------------------------------------------------------------
# (a) jitted engine ≡ legacy eager loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(20))
def test_jit_matches_eager(seed):
    h2, d, vmax = _draw(seed)
    a = equilibrium(CFG, h2, d, vmax)
    b = equilibrium_eager(CFG, h2, d, vmax)
    assert _rel(a.energy, b.energy) < REL, (a.energy, b.energy)
    assert _rel(a.t_total, b.t_total) < REL, (a.t_total, b.t_total)
    assert int(a.iterations) == int(b.iterations)
    assert bool(a.feasible) == bool(b.feasible)
    assert jnp.allclose(a.p, b.p, rtol=1e-5)
    assert jnp.allclose(a.f, b.f, rtol=1e-5)
    assert jnp.allclose(a.alpha, b.alpha, rtol=1e-5)


def test_jit_matches_eager_wo_dt():
    """The v≡0 (W/O-DT) route shares the engine and must match too."""
    h2, d, _ = _draw(3)
    a = wo_dt_allocation(CFG, h2, d)
    b = equilibrium_eager(CFG, h2, d, jnp.zeros((N,)))
    assert _rel(a.energy, b.energy) < REL
    assert _rel(a.t_total, b.t_total) < REL
    assert bool(jnp.all(a.v == 0.0))


# ---------------------------------------------------------------------------
# (b) vmap over K draws ≡ K sequential solves
# ---------------------------------------------------------------------------
def test_vmap_equals_sequential():
    k = 32
    h2b, db, vmb = _batch(k)
    ab = batched_equilibrium(CFG, h2b, db, vmb)
    assert ab.energy.shape == (k,)
    assert ab.f.shape == (k, N)
    for s in range(k):
        a1 = equilibrium(CFG, h2b[s], db[s], vmb[s])
        assert _rel(ab.energy[s], a1.energy) < REL, s
        assert _rel(ab.t_total[s], a1.t_total) < REL, s
        assert bool(ab.feasible[s]) == bool(a1.feasible), s


def test_batched_broadcasts_shared_inputs():
    """[N] data sizes / v_max broadcast across the K channel draws."""
    k = 8
    h2b, _, _ = _batch(k)
    d = jnp.full((N,), 200.0)
    vmax = jnp.full((N,), 0.5)
    ab = batched_equilibrium(CFG, h2b, d, vmax)
    a0 = equilibrium(CFG, h2b[0], d, vmax)
    assert _rel(ab.energy[0], a0.energy) < REL


def test_batched_wo_dt_matches_per_instance():
    k = 8
    h2b, db, _ = _batch(k, seed0=300)
    ab = batched_wo_dt_allocation(CFG, h2b, db)
    assert bool(jnp.all(ab.v == 0.0))
    a0 = wo_dt_allocation(CFG, h2b[0], db[0])
    assert _rel(ab.energy[0], a0.energy) < REL


# ---------------------------------------------------------------------------
# (c) feasibility invariant
# ---------------------------------------------------------------------------
def test_deadline_met_when_feasible():
    """max(t_cmp + t_com) ≤ t_max·1.001 whenever a feasible iterate exists
    (the best-iterate safeguard prefers feasible iterates lexicographically)."""
    k = 64
    h2b, db, vmb = _batch(k, seed0=500)
    ab = batched_equilibrium(CFG, h2b, db, vmb)
    worst = jnp.max(ab.t_cmp + ab.t_com, axis=-1)
    feas = ab.feasible
    assert bool(jnp.any(feas)), "expected some feasible draws in the batch"
    assert bool(jnp.all(jnp.where(feas, worst, 0.0) <= CFG.t_max * 1.001)), \
        worst[feas]


def test_allocation_is_pytree():
    """Whole allocations cross jit boundaries (engine contract)."""
    h2, d, vmax = _draw(0)
    leaves = jax.tree_util.tree_leaves(equilibrium(CFG, h2, d, vmax))
    assert len(leaves) == 15     # every Allocation field is a data leaf

    @jax.jit
    def energy_of(alloc: Allocation):
        return alloc.energy + 0.0

    a = equilibrium(CFG, h2, d, vmax)
    assert float(energy_of(a)) == pytest.approx(float(a.energy))


# ---------------------------------------------------------------------------
# (h) N=1 / degenerate-input regressions (ISSUE 6 satellite — the edges the
#     serving layer's smallest bucket and dummy batch-padding rows surface)
# ---------------------------------------------------------------------------
def test_n1_batched_both_sic_modes():
    """N=1: no later-decoded clients, interference 0.  Both SIC engines
    must agree with each other and stay finite."""
    h2, d, vmax = _draw(7, n=1)
    outs = {}
    for mode in ("sequential", "blocked"):
        cfg = GameConfig(sic_mode=mode)
        a = batched_equilibrium(cfg, h2[None, :], d[None, :], vmax[None, :])
        assert all(bool(jnp.all(jnp.isfinite(getattr(a, f))))
                   for f in ("p", "q", "f", "alpha", "energy", "t_total")), \
            mode
        outs[mode] = a
    for f in ("p", "q", "f", "energy", "t_total"):
        a = jnp.asarray(getattr(outs["sequential"], f))
        b = jnp.asarray(getattr(outs["blocked"], f))
        assert float(jnp.max(jnp.abs(a - b) /
                             jnp.maximum(jnp.abs(a), 1e-12))) <= REL, f


@pytest.mark.parametrize("sic_mode", ["sequential", "blocked"])
def test_all_infeasible_batch_finite(sic_mode):
    """An impossibly tight deadline makes EVERY draw infeasible: the
    best-iterate safeguard must still hand back finite allocations with
    feasible=False everywhere — no nan/inf leaks through the
    lexicographic (infeasible, energy) selection."""
    h2b, db, vmb = _batch(3)
    cfg = GameConfig(t_max=1e-3, sic_mode=sic_mode)
    a = batched_equilibrium(cfg, h2b, db, vmb)
    assert not bool(jnp.any(a.feasible))
    for f in ("p", "q", "f", "alpha", "rates", "energy", "t_total"):
        assert bool(jnp.all(jnp.isfinite(getattr(a, f)))), f


def test_zero_channel_row_finite():
    """A dead-channel draw (all gains 0 — the service's all-masked dummy
    row without the mask) must not poison the batch: rates clamp at the
    1e-9 floor, latencies are huge but finite, energies finite."""
    h2b, db, vmb = _batch(2)
    h2b = h2b.at[1].set(0.0)
    a = batched_equilibrium(GameConfig(), h2b, db, vmb)
    for f in ("p", "q", "f", "alpha", "energy", "t_total"):
        assert bool(jnp.all(jnp.isfinite(getattr(a, f)))), f
    # the healthy row is untouched by its dead neighbour
    solo = batched_equilibrium(GameConfig(), h2b[:1], db[:1], vmb[:1])
    assert float(jnp.abs(a.energy[0] - solo.energy[0])) <= \
        REL * max(float(jnp.abs(solo.energy[0])), 1e-12)


def test_follower_alpha_all_masked_guard():
    """Regression for the Eq.-26 0/0: with load = 0 and t_total = 0 (an
    all-masked dummy row) follower_alpha used to return NaN; the 1e-12
    denominator floor pins it at 0."""
    from repro.core.stackelberg import follower_alpha
    alpha, t_s = follower_alpha(jnp.zeros(4), jnp.zeros(4),
                                jnp.zeros(()), jnp.asarray(1e9))
    assert bool(jnp.all(jnp.isfinite(alpha))) and bool(jnp.isfinite(t_s))
    assert bool(jnp.all(alpha == 0.0))
