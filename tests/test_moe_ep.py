"""Expert-parallel (shard_map all-to-all) MoE vs the baseline dispatch.

Runs in a subprocess with 8 host devices (mesh 2×4: data×model)."""
import json
import subprocess
import sys
import textwrap

import pytest

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.models.config import MOE, BlockSpec, ModelConfig
    from repro.models.moe import init_moe, moe_forward
    from repro.models.moe_ep import moe_forward_ep

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, head_dim=8, d_ff=64,
                      vocab_size=64, pattern=(BlockSpec(MOE),),
                      num_experts=8, num_experts_per_tok=2,
                      capacity_factor=8.0,   # no drops → paths must agree
                      dtype="float32", param_dtype="float32",
                      moe_chunk_tokens=0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    y_ref, aux_ref = moe_forward(p, x, cfg)
    y_ep, aux_ep = moe_forward_ep(p, x, cfg, mesh)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    # EP capacity/tie-breaking is per-shard: tiny numerical/routing edge
    # differences possible at ties; with cf=8 nothing drops and routing is
    # unambiguous for random inputs
    print(json.dumps({"err": err, "aux_ref": float(aux_ref),
                      "aux_ep": float(aux_ep)}))
""")


@pytest.mark.slow
def test_moe_ep_matches_baseline():
    r = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        cwd=".", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-4, out
    # aux is a per-shard estimator of the global load-balance statistic —
    # E·Σ f_e·p_e is not linear in token subsetting, so the two differ by a
    # bounded amount (both are valid balancing pressures)
    assert abs(out["aux_ref"] - out["aux_ep"]) < 0.5, out
