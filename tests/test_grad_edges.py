"""Gradient finiteness at the closed forms' exact edge cases.

The forward values of ``_p_floor`` / ``_inner_projected`` /
``follower_alpha`` / ``dt_compute_latency`` were always finite — the
hazard is reverse-mode: a ``jnp.where`` (or clamp) whose *untaken* branch
evaluates inf produces ``0 · inf = NaN`` cotangents, and a ``max(·, tiny)``
clamp multiplies cotangents by 1/tiny.  The double-``where`` rewrites must
keep forward values bit-identical while making every ``jax.grad`` finite
at: q → 0 (Dinkelbach cold start), dead/masked lanes (f_eff = 0, h2 = 0),
the saturated Eq.-29 branch, and the ``leader_f`` clip boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dinkelbach import _inner_projected, _p_floor, dinkelbach_power
from repro.core.stackelberg import (GameConfig, dt_compute_latency,
                                    equilibrium, follower_alpha, leader_f)

CFG = GameConfig()


def _all_finite(tree):
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(tree))


class TestInnerProjected:
    def test_grad_at_cold_start_q_zero(self):
        """q = 0 (the Dinkelbach cold start): the stationary point is a
        division by ~0 — its gradient must not be NaN."""
        def f(q, f_eff):
            return _inner_projected(q, 1e6, f_eff, CFG.bandwidth,
                                    jnp.asarray(0.01), jnp.asarray(0.1))
        g = jax.grad(f, argnums=(0, 1))(jnp.asarray(0.0), jnp.asarray(1e3))
        assert _all_finite(g)

    def test_grad_at_dead_lane_feff_zero(self):
        """f_eff = 0 (masked lane, h2 = 0): 1/f_eff is inf in the naive
        form."""
        def f(q, f_eff):
            return _inner_projected(q, 1e6, f_eff, CFG.bandwidth,
                                    jnp.asarray(0.1), jnp.asarray(0.1))
        g = jax.grad(f, argnums=(0, 1))(jnp.asarray(5e3), jnp.asarray(0.0))
        assert _all_finite(g)

    def test_forward_parity_with_clamped_form(self):
        """The rewrite must be value-identical to the old
        ``max(q, 1e-30)`` / raw ``1/f_eff`` form in every reachable
        regime (interior, both clip edges, cold start)."""
        d, bw = 1e6, CFG.bandwidth
        lo, hi = jnp.asarray(0.013), jnp.asarray(0.1)
        old = lambda q, fe: jnp.clip(
            bw / (0.6931471805599453 * jnp.maximum(q, 1e-30) * d) - 1.0 / fe,
            lo, hi)
        for q, fe in [(5e3, 1e3), (1e2, 1e3), (1e6, 1e4), (0.0, 1e3),
                      (5e3, 1e2)]:
            new = _inner_projected(jnp.asarray(q), d, jnp.asarray(fe), bw,
                                   lo, hi)
            np.testing.assert_allclose(np.asarray(new),
                                       np.asarray(old(q, fe)), rtol=0)


class TestPFloor:
    def test_grad_at_starved_deadline(self):
        """A starved slack g → 2**huge overflowed to inf pre-fix (forward
        survives the min(·, p_max) clamp; backward did not)."""
        def f(g, f_eff):
            lo = jnp.minimum(_p_floor(1e6, g, f_eff, CFG.bandwidth,
                                      CFG.p_min), CFG.p_max)
            return lo
        grads = jax.grad(f, argnums=(0, 1))(jnp.asarray(1e-3),
                                            jnp.asarray(1e3))
        assert _all_finite(grads)

    def test_grad_at_dead_lane(self):
        def f(g, f_eff):
            return jnp.minimum(_p_floor(1e6, g, f_eff, CFG.bandwidth,
                                        CFG.p_min), CFG.p_max)
        grads = jax.grad(f, argnums=(0, 1))(jnp.asarray(5.0),
                                            jnp.asarray(0.0))
        assert _all_finite(grads)

    def test_forward_parity(self):
        old = lambda d, g, fe: jnp.maximum(
            CFG.p_min,
            (2.0 ** (d / (jnp.maximum(g, 1e-9) * CFG.bandwidth)) - 1.0) / fe)
        for g, fe in [(5.0, 1e3), (0.5, 1e2), (9.9, 1e4)]:
            new = _p_floor(1e6, jnp.asarray(g), jnp.asarray(fe),
                           CFG.bandwidth, CFG.p_min)
            np.testing.assert_allclose(np.asarray(new),
                                       np.asarray(old(1e6, g, fe)), rtol=0)
        # starved / dead regimes: parity holds after the caller's clamp
        for g, fe in [(1e-4, 1e3), (5.0, 0.0)]:
            new = jnp.minimum(_p_floor(1e6, jnp.asarray(g), jnp.asarray(fe),
                                       CFG.bandwidth, CFG.p_min), CFG.p_max)
            ref = jnp.minimum(old(1e6, g, fe), CFG.p_max)
            np.testing.assert_allclose(np.asarray(new), np.asarray(ref),
                                       rtol=0)


class TestFollowerAlpha:
    def test_grad_all_masked_lane(self):
        """All-zero DT load AND zero round latency (every client masked):
        0/0 in both Eq. 26 and Eq. 29 without the guards."""
        def f(d_hat, t_total):
            alpha, t_s = follower_alpha(CFG.cycles_per_sample, d_hat,
                                        t_total, CFG.f_server)
            return jnp.sum(alpha) + t_s
        g = jax.grad(f, argnums=(0, 1))(jnp.zeros(4), jnp.asarray(0.0))
        assert _all_finite(g)

    def test_grad_saturated_eq29_branch(self):
        """Server saturated (Σα > 1): the Eq.-29 branch is live and the
        discarded Eq.-26 branch must not poison the cotangents."""
        d_hat = jnp.asarray([4e3, 3e3, 2e3, 1e3])
        t_total = jnp.asarray(1e-4)     # tiny latency → case-1 α explodes
        alpha, _ = follower_alpha(CFG.cycles_per_sample, d_hat, t_total,
                                  CFG.f_server)
        np.testing.assert_allclose(float(jnp.sum(alpha)), 1.0, rtol=1e-6)

        def f(dh, tt):
            a, t_s = follower_alpha(CFG.cycles_per_sample, dh, tt,
                                    CFG.f_server)
            return jnp.sum(a ** 2) + t_s
        g = jax.grad(f, argnums=(0, 1))(d_hat, t_total)
        assert _all_finite(g)

    def test_grad_mixed_masked_lanes(self):
        """Zero-load lanes inside a live cell (the padded-bucket case)."""
        d_hat = jnp.asarray([4e3, 0.0, 2e3, 0.0])
        def f(dh):
            a, _ = follower_alpha(CFG.cycles_per_sample, dh, jnp.asarray(2.0),
                                  CFG.f_server)
            return jnp.sum(a)
        assert _all_finite(jax.grad(f)(d_hat))

    def test_forward_parity(self):
        """Double-where == the old max(·, 1e-12) clamps, bit for bit."""
        c, fs = CFG.cycles_per_sample, CFG.f_server
        def old(d_hat, t_total):
            load = c * d_hat
            a1 = load / jnp.maximum(t_total * fs, 1e-12)
            sat = jnp.sum(a1) > 1.0
            a2 = load / jnp.maximum(jnp.sum(load), 1e-12)
            return jnp.where(sat, a2, a1)
        for d_hat, tt in [([4e3, 3e3, 2e3, 1e3], 2.0),
                          ([4e3, 3e3, 2e3, 1e3], 1e-4),
                          ([0.0, 0.0], 0.0),
                          ([1e3, 0.0], 3.0)]:
            d_hat = jnp.asarray(d_hat)
            new, _ = follower_alpha(c, d_hat, jnp.asarray(tt), fs)
            np.testing.assert_array_equal(np.asarray(new),
                                          np.asarray(old(d_hat, tt)))


class TestDtComputeLatency:
    def test_grad_alpha_zero_lane(self):
        def f(d_hat, alpha):
            return jnp.sum(dt_compute_latency(CFG.cycles_per_sample, d_hat,
                                              alpha, CFG.f_server))
        g = jax.grad(f, argnums=(0, 1))(jnp.asarray([1e3, 0.0]),
                                        jnp.asarray([0.5, 0.0]))
        assert _all_finite(g)

    def test_forward_parity(self):
        c, fs = CFG.cycles_per_sample, CFG.f_server
        old = lambda dh, a: c * dh / (jnp.maximum(a, 1e-12) * fs)
        for dh, a in [([1e3, 2e3], [0.3, 0.7]), ([1e3, 0.0], [0.5, 0.0]),
                      ([0.0], [0.0])]:
            dh, a = jnp.asarray(dh), jnp.asarray(a)
            np.testing.assert_array_equal(
                np.asarray(dt_compute_latency(c, dh, a, fs)),
                np.asarray(old(dh, a)))


class TestLeaderF:
    @pytest.mark.parametrize("a_n", [1e-3, 0.08, 5.0, 100.0])
    def test_grad_finite_across_clip_boundaries(self, a_n):
        """a_n spanning f̃ > f_max (left clip), interior, and f̃ < f_min
        (right clip) — gradients must be finite (0 at the clips)."""
        def f(v, a):
            return jnp.sum(leader_f(CFG.cycles_per_sample, v, 500.0, a,
                                    CFG.f_min, CFG.f_max))
        g = jax.grad(f, argnums=(0, 1))(jnp.asarray([0.3]),
                                        jnp.asarray([a_n]))
        assert _all_finite(g)


class TestDinkelbachGradSafety:
    def test_vjp_through_inner_solve_chain(self):
        """One full grad-safe inner chain: floor → project → rate, at a
        masked lane and a live lane simultaneously."""
        def loss(h2, g_n):
            f_eff = h2 / CFG.sigma2
            lo = jnp.minimum(_p_floor(1e6, g_n, f_eff, CFG.bandwidth,
                                      CFG.p_min), CFG.p_max)
            p = _inner_projected(jnp.asarray([5e3, 0.0]), 1e6, f_eff,
                                 CFG.bandwidth, lo,
                                 CFG.p_max * jnp.ones_like(lo))
            return jnp.sum(p)
        g = jax.grad(loss, argnums=(0, 1))(jnp.asarray([1e-12, 0.0]),
                                           jnp.asarray([5.0, 5.0]))
        assert _all_finite(g)

    def test_forward_unchanged_vs_reference_solver(self):
        """The grad-safe rewrites must not move the Dinkelbach solutions:
        p*, q* at a representative operating point stay put."""
        p, q, it = dinkelbach_power(1e6, 5.0, 1e4, CFG.bandwidth, CFG.p_min,
                                    CFG.p_max)
        # optimum is interior or at a box edge; invariants of the solve
        assert CFG.p_min - 1e-9 <= float(p) <= CFG.p_max + 1e-9
        rate = CFG.bandwidth * jnp.log2(1.0 + p * 1e4)
        np.testing.assert_allclose(float(q), float(rate / (p * 1e6)),
                                   rtol=1e-5)


class TestEquilibriumForwardUnchanged:
    def test_solver_output_stable_under_rewrites(self):
        """End-to-end guard: the jitted equilibrium on a fixed draw is
        unchanged by the grad-safety rewrites (values pinned against the
        eager reference, which shares the same closed forms)."""
        key = jax.random.PRNGKey(7)
        h2 = jnp.sort(jax.random.exponential(key, (6,)) * 1e-6)[::-1]
        alloc = equilibrium(CFG, h2, 500.0, 0.4, epsilon=10.0)
        assert _all_finite((alloc.f, alloc.p, alloc.q, alloc.energy))
        assert bool(jnp.all(alloc.p <= CFG.p_max + 1e-9))
        assert bool(jnp.all(alloc.f <= CFG.f_max * (1 + 1e-6)))
