"""Substrate tests: optimizer, checkpoint, data pipeline, sharding rules,
HLO walker."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.data.pipeline import PipelineConfig, lm_batches
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST, lm_token_batch


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    opt = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    opt = init_opt_state(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw_update(huge, opt, params, cfg)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0


def test_adamw_bf16_moments():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    cfg = AdamWConfig(moment_dtype="bfloat16")
    opt = init_opt_state(params, cfg)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, o2 = adamw_update(g, opt, params, cfg)
    assert o2["mu"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)},
            "count": jnp.zeros((), jnp.int32)}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, step=3)
    save_checkpoint(path, tree, step=7)
    assert latest_step(path) == 7
    out = restore_checkpoint(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention(tmp_path):
    path = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(path, {"x": jnp.zeros(1)}, step=s, keep=2)
    import os
    steps = [d for d in os.listdir(path) if d.startswith("step_")]
    assert len(steps) == 2


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_lm_batches_deterministic():
    pipe = PipelineConfig(global_batch=4, seq_len=16, vocab_size=100, seed=1)
    a = next(lm_batches(pipe))
    b = next(lm_batches(pipe))
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert int(a["tokens"].max()) < 100


def test_lm_stream_has_structure():
    toks = lm_token_batch(jax.random.PRNGKey(0), 8, 256, 1000)
    # copy-back structure → token t equals token t-2 far above chance
    eq = float(jnp.mean((toks[:, 2:] == toks[:, :-2]).astype(jnp.float32)))
    assert eq > 0.3


def test_federated_partitions():
    data = make_federated_data(jax.random.PRNGKey(0), SYNTHETIC_MNIST, m=10,
                               cap=64, poison_ratio=0.3, iid=False,
                               labels_per_client=1)
    assert int(data.poisoned.sum()) == 3
    # non-IID: each client's valid labels take ≤ labels_per_client values
    for i in range(10):
        labs = np.unique(np.asarray(data.y[i])[np.asarray(data.mask[i])])
        assert len(labs) <= 1
    # poisoned client's training labels flipped
    pi = int(jnp.argmax(data.poisoned.astype(jnp.int32)))
    assert bool(jnp.all(data.y_train[pi] == 9 - data.y[pi]))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_param_specs_divisible():
    """Every spec the rules emit must evenly divide the leaf dims."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.sharding.rules import param_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("granite-3-8b")
    sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    mesh = FakeMesh()

    def check(path, leaf):
        spec = param_spec(path, leaf, mesh)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(check, sds)


# ---------------------------------------------------------------------------
# HLO walker
# ---------------------------------------------------------------------------
def test_hlo_walker_trip_count():
    """The walker multiplies while bodies by known_trip_count (raw XLA cost
    analysis counts them once)."""
    from repro.analysis.hlo_walk import HloCost

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    walk = HloCost(compiled.as_text()).entry_cost()
    one_matmul = 2 * 64 * 64 * 64
    assert walk["flops"] >= 8 * one_matmul * 0.99, walk["flops"]
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):   # pre-0.4.x API returns [dict]
        raw = raw[0]
    assert raw["flops"] < 2 * one_matmul  # raw undercounts — why walker exists
