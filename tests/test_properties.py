"""Hypothesis property tests on system invariants (model + game layers)."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline: seeded example replay (tests/_prop.py)
    from _prop import given, settings, strategies as st

from repro.core.aggregation import dt_aggregate, fedavg
from repro.kernels.ref import ssd_scan_ref, swa_attention_ref
from repro.models.ssm import ssd_chunked
from repro.optim import AdamWConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------
@given(st.integers(0, 5), st.floats(0.3, 3.0))
@settings(max_examples=10, deadline=None)
def test_ssd_linear_in_x(seed, scale):
    """y(αx) = α·y(x): the SSD map is linear in the input stream."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 16, 2, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, h, n))
    C = jax.random.normal(ks[4], (b, s, h, n))
    y1 = ssd_chunked(x, dt, a, B, C, 4)
    y2 = ssd_chunked(scale * x, dt, a, B, C, 4)
    assert float(jnp.max(jnp.abs(y2 - scale * y1))) < 1e-3 * max(1.0, scale)


@given(st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_ssd_causality(seed):
    """Perturbing x at time t must not change y before t."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 16, 2, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, h, n))
    C = jax.random.normal(ks[4], (b, s, h, n))
    t = 8
    y1 = ssd_chunked(x, dt, a, B, C, 4)
    x2 = x.at[:, t:].add(3.0)
    y2 = ssd_chunked(x2, dt, a, B, C, 4)
    assert float(jnp.max(jnp.abs(y2[:, :t] - y1[:, :t]))) < 1e-5


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------
@given(st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_attention_causality(seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(kk, (1, 32, 8)) for kk in ks)
    t = 16
    y1 = swa_attention_ref(q, k, v)
    y2 = swa_attention_ref(q, k.at[:, t:].add(2.0), v.at[:, t:].add(2.0))
    assert float(jnp.max(jnp.abs(y2[:, :t] - y1[:, :t]))) < 1e-5


@given(st.integers(1, 4))
@settings(max_examples=6, deadline=None)
def test_attention_window_monotone_coverage(w_blocks):
    """Growing the window toward S must converge to global attention."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 32, 8)) for kk in ks)
    full = swa_attention_ref(q, k, v, window=0)
    win = swa_attention_ref(q, k, v, window=8 * w_blocks)
    err = float(jnp.max(jnp.abs(full - win)))
    if w_blocks >= 4:       # window == S
        assert err < 1e-6
    # rows within the window are exact regardless
    assert float(jnp.max(jnp.abs(full[:, :8 * w_blocks]
                                 - win[:, :8 * w_blocks]))) < 1e-6


# ---------------------------------------------------------------------------
# aggregation invariants
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(1.0, 100.0), min_size=2, max_size=6),
       st.floats(0.0, 0.9))
@settings(max_examples=20, deadline=None)
def test_aggregate_convex_combination(sizes, vv):
    """With ε=0 the aggregate lies in the convex hull of the inputs."""
    d = jnp.array(sizes)
    n = d.shape[0]
    vals = jnp.linspace(-2.0, 3.0, n)
    client = {"w": vals[:, None] * jnp.ones((n, 4))}
    server = {"w": jnp.full((4,), 0.5)}
    v = jnp.full((n,), vv)
    out = dt_aggregate(client, server, d, v, epsilon=0.0)
    lo = min(float(vals.min()), 0.5) - 1e-5
    hi = max(float(vals.max()), 0.5) + 1e-5
    assert bool(jnp.all((out["w"] >= lo) & (out["w"] <= hi)))


@given(st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_fedavg_mean_of_equal_weights(n):
    client = {"w": jnp.arange(float(n))[:, None] * jnp.ones((n, 3))}
    out = fedavg(client, jnp.ones((n,)))
    assert jnp.allclose(out["w"], (n - 1) / 2.0, atol=1e-5)


# ---------------------------------------------------------------------------
# optimizer invariants
# ---------------------------------------------------------------------------
@given(st.floats(1e-4, 1e-2), st.integers(0, 4))
@settings(max_examples=10, deadline=None)
def test_adamw_step_bounded(lr, seed):
    """|Δp| ≤ lr·(1 + wd·|p|)/(1−eps-ish): Adam's per-step trust region."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (16,))}
    cfg = AdamWConfig(lr=lr, weight_decay=0.1, grad_clip=0.0)
    opt = init_opt_state(params, cfg)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (16,)) * 100}
    p2, _ = adamw_update(g, opt, params, cfg)
    step = jnp.abs(p2["w"] - params["w"])
    bound = lr * (1.0 / (1 - 0.9) + 0.1 * jnp.abs(params["w"])) * 1.01
    assert bool(jnp.all(step <= bound))
