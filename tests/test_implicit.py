"""IFT custom_vjp vs the finite-difference eager oracle.

``equilibrium_implicit`` must (a) return the exact forward values of the
jitted engine, and (b) produce gradients matching central finite
differences of ``equilibrium_eager`` to ≤1e-3 relative across schemes
(proposed / ideal / wo_dt) × sic_modes (sequential / blocked), with zero
NaN cotangents and zero retraces across repeated calls.

FD oracles need x64: the equilibrium is ~1e0-scale energy built from
~1e-28-scale physics products, so f32 central differences drown in
cancellation long before the 1e-3 budget.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.channel import sample_channel_gains, sample_positions
from repro.core.implicit import equilibrium_implicit
from repro.core.stackelberg import (TRACE_COUNTS, GameConfig, equilibrium,
                                    equilibrium_eager)

N = 6
REL_TOL = 1e-3

# (label, v_max, epsilon) — the three schemes that hit the same solver
SCHEMES = [("proposed", 0.4, 20.0), ("ideal", 0.4, 0.0), ("wo_dt", 0.0, 0.0)]
SIC_MODES = ["sequential", "blocked"]


def _draw(seed=3, n=N, dtype=jnp.float64, scale=100.0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    h2 = jnp.sort(sample_channel_gains(k2, sample_positions(k1, n)))[::-1]
    # ×100 pulls the weakest client inside the deadline → feasible draws
    return (h2 * scale).astype(dtype)


def _loss_implicit(cfg, h2, D, vm, eps, sic_mode):
    al = equilibrium_implicit(cfg.physics(jnp.float64), h2, D, vm, eps,
                              inner=cfg.dinkelbach_inner, sic_mode=sic_mode)
    return al.energy + 0.1 * al.t_total


def _loss_eager(cfg, h2, D, vm, eps):
    al = equilibrium_eager(cfg, h2, D, vm, epsilon=float(eps))
    return float(al.energy + 0.1 * al.t_total)


class TestForwardParity:
    @pytest.mark.parametrize("sic_mode", SIC_MODES)
    def test_values_match_jitted_engine(self, sic_mode):
        cfg = GameConfig(sic_mode=sic_mode)
        h2 = _draw(dtype=jnp.float32)
        ref = equilibrium(cfg, h2, 500.0, 0.4, epsilon=20.0)
        imp = equilibrium_implicit(cfg, h2, 500.0, 0.4, 20.0,
                                   sic_mode=sic_mode)
        for name in ("f", "p", "q", "alpha", "energy", "t_total"):
            np.testing.assert_allclose(np.asarray(getattr(ref, name)),
                                       np.asarray(getattr(imp, name)),
                                       rtol=1e-6, err_msg=name)
        assert bool(ref.feasible) == bool(imp.feasible)


class TestGradcheck:
    @pytest.mark.parametrize("scheme,vmax,eps", SCHEMES,
                             ids=[s[0] for s in SCHEMES])
    @pytest.mark.parametrize("sic_mode", SIC_MODES)
    def test_h2_vmax_eps_gradients_vs_fd(self, scheme, vmax, eps, sic_mode):
        with enable_x64():
            cfg = GameConfig(sic_mode=sic_mode)
            h2 = _draw()
            D = jnp.full((N,), 500.0, jnp.float64)
            vm = jnp.full((N,), vmax, jnp.float64)
            eps64 = jnp.float64(eps)
            assert bool(equilibrium_eager(cfg, h2, D, vm,
                                          epsilon=eps).feasible)

            g_h2, g_vm, g_eps = jax.grad(
                lambda a, b, c: _loss_implicit(cfg, a, D, b, c, sic_mode),
                argnums=(0, 1, 2))(h2, vm, eps64)
            assert bool(jnp.all(jnp.isfinite(g_h2)))
            assert bool(jnp.all(jnp.isfinite(g_vm)))
            assert bool(jnp.isfinite(g_eps))

            # FD on h2 (relative steps keep the SIC order intact)
            fd_h2 = np.zeros(N)
            for j in range(N):
                d = 1e-5 * float(h2[j])
                fd_h2[j] = (_loss_eager(cfg, h2.at[j].add(d), D, vm, eps)
                            - _loss_eager(cfg, h2.at[j].add(-d), D, vm,
                                          eps)) / (2 * d)
            rel = np.abs(np.asarray(g_h2) - fd_h2) / np.maximum(
                np.abs(fd_h2), 1e-6)
            assert rel.max() < REL_TOL, (rel, g_h2, fd_h2)

            # FD on v_max (uniform bump — one probe for the whole vector)
            d = 1e-6
            fd_vm = (_loss_eager(cfg, h2, D, vm + d, eps)
                     - _loss_eager(cfg, h2, D, vm - d, eps)) / (2 * d)
            ad_vm = float(jnp.sum(g_vm))
            assert abs(ad_vm - fd_vm) <= REL_TOL * max(abs(fd_vm), 1e-6)

            # FD on epsilon
            d = 1e-3
            fd_eps = (_loss_eager(cfg, h2, D, vm, eps + d)
                      - _loss_eager(cfg, h2, D, vm, eps - d)) / (2 * d)
            assert abs(float(g_eps) - fd_eps) <= REL_TOL * max(
                abs(fd_eps), 1e-6)

    def test_physics_gradients_vs_fd(self):
        """t_max / model_bits enter through the fixed point only — the
        purest IFT path (no direct ``_finish`` dependence for t_max)."""
        with enable_x64():
            cfg = GameConfig()
            h2 = _draw()
            D = jnp.full((N,), 500.0, jnp.float64)
            vm = jnp.full((N,), 0.4, jnp.float64)

            def loss(tmax, mbits):
                phys = dc.replace(cfg.physics(jnp.float64), t_max=tmax,
                                  model_bits=mbits)
                al = equilibrium_implicit(phys, h2, D, vm, 20.0)
                return al.energy + 0.1 * al.t_total

            g = jax.grad(loss, argnums=(0, 1))(jnp.float64(10.0),
                                               jnp.float64(1e6))

            def eager(tmax, mbits):
                c = dc.replace(cfg, t_max=tmax, model_bits=mbits)
                return _loss_eager(c, h2, D, vm, 20.0)

            fd_t = (eager(10.0 + 1e-4, 1e6) - eager(10.0 - 1e-4, 1e6)) / 2e-4
            fd_m = (eager(10.0, 1e6 + 1.0) - eager(10.0, 1e6 - 1.0)) / 2.0
            for ad, fd in [(float(g[0]), fd_t), (float(g[1]), fd_m)]:
                assert abs(ad - fd) <= REL_TOL * max(abs(fd), 1e-8), (ad, fd)

    def test_energy_has_zero_epsilon_gradient(self):
        """ε never enters the leader fixed point: dE/dε ≡ 0 by
        construction (only latency moves)."""
        with enable_x64():
            cfg = GameConfig()
            h2 = _draw()
            g = jax.grad(lambda e: equilibrium_implicit(
                cfg.physics(jnp.float64), h2,
                jnp.full((N,), 500.0, jnp.float64),
                jnp.full((N,), 0.4, jnp.float64), e).energy)(jnp.float64(20.))
            assert float(g) == 0.0


class TestFeasibilityContract:
    def test_infeasible_solve_gets_zero_fixed_point_cotangents(self):
        """An infeasible draw (weak channel, blown deadline) must yield
        finite gradients with NO flow through the fixed point — t_max
        touches the solve only through the fixed point, so its gradient
        is exactly zero."""
        cfg = GameConfig()
        h2 = _draw(seed=0, dtype=jnp.float32, scale=1.0)   # raw gains: weak
        assert not bool(equilibrium(cfg, h2, 500.0, 0.4,
                                    epsilon=20.0).feasible)

        def loss(tmax, vm):
            phys = dc.replace(cfg.physics(jnp.float32),
                              t_max=tmax)
            al = equilibrium_implicit(phys, h2, 500.0, vm, 20.0)
            return al.energy + 0.1 * al.t_total

        g_tmax, g_vm = jax.grad(loss, argnums=(0, 1))(
            jnp.float32(10.0), jnp.full((N,), 0.4))
        assert float(g_tmax) == 0.0
        assert bool(jnp.all(jnp.isfinite(g_vm)))   # direct _finish path


class TestMaskedLanes:
    def test_masked_bucket_matches_exact_solve_and_grads_finite(self):
        """A padded bucket (zero-gain tail + mask) must match the exact-N
        solve forward and carry finite gradients on the real lanes."""
        cfg = GameConfig()
        h2 = _draw(dtype=jnp.float32)
        pad = 2
        h2_pad = jnp.concatenate([h2, jnp.zeros((pad,))])
        mask = jnp.arange(N + pad) < N
        D_pad = jnp.full((N + pad,), 500.0)
        vm_pad = jnp.full((N + pad,), 0.4)

        exact = equilibrium_implicit(cfg, h2, 500.0, 0.4, 20.0)
        padded = equilibrium_implicit(cfg, h2_pad, D_pad, vm_pad, 20.0,
                                      mask=mask)
        np.testing.assert_allclose(np.asarray(padded.p[:N]),
                                   np.asarray(exact.p), rtol=1e-6)
        np.testing.assert_allclose(float(padded.energy),
                                   float(exact.energy), rtol=1e-6)
        assert bool(padded.feasible)

        def loss(h2_, vm_):
            al = equilibrium_implicit(cfg, h2_, D_pad, vm_, 20.0, mask=mask)
            return al.energy + 0.1 * al.t_total

        g_h2, g_vm = jax.grad(loss, argnums=(0, 1))(h2_pad, vm_pad)
        assert bool(jnp.all(jnp.isfinite(g_h2)))
        assert bool(jnp.all(jnp.isfinite(g_vm)))


class TestZeroRetrace:
    def test_vjp_adds_no_new_compile_keys_across_values(self):
        """One jitted grad entry, many operand values → the custom_vjp
        forward/backward trace exactly once; swapping VALUES must not
        retrace.  Differentiate wrt h2 — an input that enters the fixed
        point — so the VJP rule is actually on the grad path (an ε-only
        grad is pruned to the primal, since ε bypasses the fixed point)."""
        cfg = GameConfig()
        h2a = _draw(seed=3, dtype=jnp.float32)
        h2b = _draw(seed=4, dtype=jnp.float32)
        D = jnp.full((N,), 500.0)
        vm = jnp.full((N,), 0.4)

        @jax.jit
        def gradfn(h2, eps):
            def loss(h2_):
                al = equilibrium_implicit(cfg.physics(jnp.float32), h2_,
                                          D, vm, eps)
                return al.energy + 0.1 * al.t_total
            return jax.grad(loss)(h2)

        before_f = TRACE_COUNTS["equilibrium_implicit_fwd"]
        before_b = TRACE_COUNTS["equilibrium_implicit_bwd"]
        g1 = gradfn(h2a, jnp.float32(20.0))
        g2 = gradfn(h2b, jnp.float32(5.0))
        g3 = gradfn(h2a, jnp.float32(0.0))
        for g in (g1, g2, g3):
            assert bool(jnp.all(jnp.isfinite(g)))
        # one compile → one fwd trace, one bwd trace; NO growth after
        assert TRACE_COUNTS["equilibrium_implicit_fwd"] - before_f == 1
        assert TRACE_COUNTS["equilibrium_implicit_bwd"] - before_b == 1
