"""``launch.serve.generate``: prefill-priming vs step-priming parity.

The serving driver has two prompt-priming paths — ``prime="prefill"`` (the
one-pass cache-collecting prefill) and ``prime="steps"`` (the token-by-token
decode_step replay).  Both must hand the decode loop last-position logits of
the SAME rank ([B, V]) so the greedy/categorical ``[:, None]`` expansion and
the token concatenate behave identically — the ISSUE-6 satellite pins this
contract with full-sequence greedy parity on tiny dense and SSM configs
(``generate`` normalizes a rank-3 [B, 1, V] defensively; see its docstring).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.launch.serve import generate
from repro.models import init_params


def _setup(arch, b=2, plen=8):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (b, plen), 0, cfg.vocab_size)
    return cfg, params, prompt


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-2.7b"])
def test_prefill_vs_steps_token_parity(arch):
    """Greedy generation must produce IDENTICAL token sequences whichever
    priming path ran (same caches, same logits rank into the decode loop)."""
    cfg, params, prompt = _setup(arch)
    gen, max_seq = 6, prompt.shape[1] + 8
    t_pf = generate(cfg, params, prompt, max_seq, gen, prime="prefill")
    t_st = generate(cfg, params, prompt, max_seq, gen, prime="steps")
    assert t_pf.shape == t_st.shape == (2, prompt.shape[1] + gen)
    assert t_pf.dtype == jnp.int32
    assert bool(jnp.all(t_pf[:, :prompt.shape[1]] == prompt))
    assert bool(jnp.all(t_pf == t_st))


def test_rank3_logits_normalized():
    """A priming path that yields [B, 1, V] logits must still decode
    correctly — generate's rank normalization squeezes the sequence axis
    before the loop (the exact failure mode the satellite describes)."""
    cfg, params, prompt = _setup("gemma2-9b")
    gen, max_seq = 4, prompt.shape[1] + 6
    ref = generate(cfg, params, prompt, max_seq, gen, prime="steps")

    import repro.launch.serve as serve_mod
    orig = serve_mod.prefill_with_caches

    def rank3_prefill(params, batch, cfg, max_seq):
        logits, caches = orig(params, batch, cfg, max_seq)
        return logits[:, None, :], caches          # [B, V] -> [B, 1, V]

    serve_mod.prefill_with_caches = rank3_prefill
    try:
        toks = generate(cfg, params, prompt, max_seq, gen, prime="prefill")
    finally:
        serve_mod.prefill_with_caches = orig
    assert toks.shape == (2, prompt.shape[1] + gen)
    assert bool(jnp.all(toks == ref))
