"""Config-axis training sweep tests (ISSUE 4 tentpole).

Three properties of ``sweep_training``:

  * parity — cell (c, s) of the swept C×S grid equals ``batched_training``
    with configs c on the same seeds (pure batching, ≤ 1e-5 rel on every
    stacked metric), for proposed + ideal schemes, with and without RONI,
    and with a per-seed data axis (fig5's attacker-fraction layout);
  * compile behavior — a C-point config grid traces the round body exactly
    ONCE per (scheme, use_roni, shape), and changing any numeric knob
    (lr, ε, RONI threshold, physics floats) across config points must not
    retrace — only scheme/use_roni/shapes are compile keys;
  * grid sharding — the C×S grid device-shards over the 2D
    ``game_mesh`` ("cfg", "draw") shard_map layout shared with the
    equilibrium sweeps (forced-4-device subprocess; single-device no-op
    elsewhere).

Parity comparisons go through ``jax.device_get``: under forced multi-device
runs the sweep output lives on the 2D grid mesh while the batched
reference lives on a 1D batch mesh, and jnp ops refuse to mix meshes.

Shapes here are deliberately unusual (M=9 pool, cap=36, hidden=28) so
earlier tests cannot have pre-warmed the jit cache and trace deltas are
real.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _multidevice import run_forced_devices

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import (FLConfig, FLState, batched_training,
                                 stack_fl_ops, stack_states, sweep_training)
from repro.core.reputation import init_reputation
from repro.core.stackelberg import GameConfig, TRACE_COUNTS
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST

M, CAP, HID, NSEL = 9, 36, 28, 3
REL = 1e-5
SCALAR_METRICS = ("val_acc", "latency", "energy", "total_cost", "mean_v")
INT_METRICS = ("round", "n_excluded_roni", "n_stragglers",
               "n_poisoned_selected")


def _setup(seed=0, poison=0.25, m=M, cap=CAP, hidden=HID):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=m, cap=cap,
                               poison_ratio=poison)
    from repro.models.classifier import make_classifier
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784,
                                        hidden=hidden)
    state = FLState(params=params, rep=init_reputation(m),
                    v_max=sample_v_max(ks[2], m, DTConfig()),
                    distances=sample_positions(ks[3], m), key=ks[4])
    return state, data, logits_fn


def _fl(**kw):
    kw.setdefault("n_selected", NSEL)
    kw.setdefault("local_steps", 4)
    kw.setdefault("server_steps", 4)
    kw.setdefault("lr", 0.1)
    return FLConfig(**kw)


def _grid(scheme, use_roni, c=2):
    fls = [_fl(scheme=scheme, use_roni=use_roni, lr=0.1 - 0.02 * i,
               epsilon=0.15 * i, roni_threshold=0.02 + 0.01 * i)
           for i in range(c)]
    games = [dataclasses.replace(GameConfig(), t_max=10.0 - i)
             for i in range(c)]
    return fls, games


def _assert_cell_parity(sw, ref, c):
    """Sweep row c against a ``batched_training`` reference (S, R, ...)."""
    for k in SCALAR_METRICS:
        got = np.asarray(jax.device_get(sw[k]))[c]
        want = np.asarray(jax.device_get(ref[k]))
        rel = float(np.max(np.abs(got - want)
                           / np.maximum(np.abs(want), 1e-12)))
        assert rel < REL, (c, k, rel)
    for k in INT_METRICS:
        assert sw[k][c].tolist() == ref[k].tolist(), (c, k)
    assert sw["selected"][c].tolist() == ref["selected"].tolist(), c


@pytest.mark.parametrize("scheme,use_roni", [("proposed", True),
                                             ("proposed", False),
                                             ("ideal", True),
                                             ("ideal", False)])
def test_sweep_matches_sequential_batched(scheme, use_roni):
    """Cell (c, s) of the C=2 × S=2 sweep equals ``batched_training`` at
    configs c on the same stacked seeds — the sweep's config axis is pure
    batching on top of the seed axis."""
    per_seed = [_setup(seed=s) for s in range(2)]
    states = stack_states([s for s, _, _ in per_seed])
    data, logits_fn = per_seed[0][1], per_seed[0][2]
    fls, games = _grid(scheme, use_roni)
    fstate, sw = sweep_training(states, data, fls, games, logits_fn,
                                rounds=3)
    assert sw["val_acc"].shape == (2, 2, 3)
    assert sw["selected"].shape == (2, 2, 3, NSEL)
    for c in range(2):
        bstate, ref = batched_training(states, data, fls[c], games[c],
                                       logits_fn, rounds=3)
        _assert_cell_parity(sw, ref, c)
        for a, b in zip(jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda x: x[c], fstate)),
                jax.tree_util.tree_leaves(bstate)):
            a, b = np.asarray(jax.device_get(a)), np.asarray(jax.device_get(b))
            rel = float(np.max(np.abs(a - b)) / max(float(np.max(np.abs(b))),
                                                    1e-12))
            assert rel < REL, (scheme, use_roni, c)


def test_sweep_per_seed_data_axis():
    """fig5's layout: the attacker-fraction axis rides the per-seed DATA
    axis while ε rides the config axis — both match per-config
    ``batched_training`` with the same stacked data."""
    a = _setup(seed=3, poison=0.0)
    b = _setup(seed=4, poison=0.4)
    states = stack_states([a[0], b[0]])
    data = jax.tree_util.tree_map(lambda x, y: jnp.stack([x, y]), a[1], b[1])
    fls = [_fl(epsilon=e) for e in (0.0, 0.3)]
    game = GameConfig()
    _, sw = sweep_training(states, data, fls, game, logits_fn=a[2], rounds=3)
    assert sw["val_acc"].shape == (2, 2, 3)
    for c in range(2):
        _, ref = batched_training(states, data, fls[c], game, a[2], rounds=3)
        _assert_cell_parity(sw, ref, c)
    # the poisoned-seed rows actually saw poisoned clients, clean rows none
    assert int(jnp.sum(sw["n_poisoned_selected"][:, 0])) == 0
    assert int(jnp.sum(sw["n_poisoned_selected"][:, 1])) >= 1


def test_sweep_c3_grid_traces_once_and_numeric_knobs_dont_retrace():
    """A C=3 config grid traces the round body exactly once, re-dispatch
    reuses it, and a grid with entirely different numeric knob VALUES
    (lr, ε, RONI threshold, t_max, bandwidth — same shapes) must hit the
    same executable: only (scheme, use_roni, shape) are compile keys."""
    state, data, logits_fn = _setup(seed=5, m=10, hidden=20, cap=32)
    states = stack_states([state])
    fls, games = _grid("oma", True, c=3)
    before = TRACE_COUNTS["run_round"]
    _, sw = sweep_training(states, data, fls, games, logits_fn, rounds=4)
    assert sw["val_acc"].shape == (3, 1, 4)
    assert TRACE_COUNTS["run_round"] - before == 1
    assert TRACE_COUNTS["sweep_training"] == 1

    sweep_training(states, data, fls, games, logits_fn, rounds=4)
    assert TRACE_COUNTS["run_round"] - before == 1, "re-dispatch retraced"

    fls2 = [dataclasses.replace(f, lr=0.21, epsilon=0.05,
                                roni_threshold=0.07) for f in fls]
    games2 = [dataclasses.replace(g, t_max=g.t_max + 1.5, bandwidth=2e6)
              for g in games]
    sweep_training(states, data, fls2, games2, logits_fn, rounds=4)
    assert TRACE_COUNTS["run_round"] - before == 1, \
        "numeric FL/game knobs must be traced operands, not compile keys"


def test_stack_fl_ops_layout_and_static_guard():
    fls = [_fl(lr=0.1 * (i + 1), epsilon=0.1 * i) for i in range(3)]
    ops = stack_fl_ops(fls)
    assert ops["lr"].shape == (3,)
    assert ops["weights"].shape == (3, 3)
    assert jnp.allclose(ops["lr"], jnp.asarray([0.1, 0.2, 0.3]))
    assert jnp.allclose(ops["epsilon"], jnp.asarray([0.0, 0.1, 0.2]))
    with pytest.raises(ValueError, match="static"):
        stack_fl_ops([_fl(), _fl(use_roni=False)])
    with pytest.raises(ValueError, match="static"):
        stack_fl_ops([_fl(), _fl(scheme="oma")])
    with pytest.raises(ValueError, match="static"):
        stack_fl_ops([_fl(), _fl(local_steps=9)])


def test_sweep_config_axis_broadcast_and_mismatch():
    """A single FLConfig broadcasts across C GameConfigs (and vice versa);
    unequal explicit lengths are an error."""
    state, data, logits_fn = _setup(seed=6, m=10, hidden=20, cap=32)
    states = stack_states([state])
    games = [dataclasses.replace(GameConfig(), t_max=t) for t in (9., 11.)]
    _, sw = sweep_training(states, data, _fl(scheme="oma"), games,
                           logits_fn, rounds=2)
    assert sw["val_acc"].shape == (2, 1, 2)
    _, sw = sweep_training(states, data,
                           [_fl(scheme="oma", epsilon=e) for e in (0., .3)],
                           GameConfig(), logits_fn, rounds=2)
    assert sw["val_acc"].shape == (2, 1, 2)
    with pytest.raises(ValueError, match="config axis"):
        sweep_training(states, data, [_fl()] * 3, games, logits_fn, 2)


# ---------------------------------------------------------------------------
# device sharding of the flattened C×S grid
# ---------------------------------------------------------------------------
_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import (FLConfig, FLState, _shard_tree,
                                 run_training_scan, stack_states,
                                 sweep_training)
from repro.core.reputation import init_reputation
from repro.core.stackelberg import GameConfig, sharding_layout
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

assert len(jax.devices()) == 4, jax.devices()
assert sharding_layout(4) == 4
sharded = _shard_tree({"a": jnp.arange(8.0).reshape(4, 2)}, 4)["a"]
assert len(sharded.sharding.device_set) == 4, sharded.sharding

def setup(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=8, cap=16,
                               poison_ratio=0.25)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784, hidden=8)
    st = FLState(params=params, rep=init_reputation(8),
                 v_max=sample_v_max(ks[2], 8, DTConfig()),
                 distances=sample_positions(ks[3], 8), key=ks[4])
    return st, data, logits_fn

cells = [setup(s) for s in range(2)]
states = stack_states([c[0] for c in cells])
data, logits_fn = cells[0][1], cells[0][2]
fls = [FLConfig(n_selected=2, local_steps=2, server_steps=2, lr=0.1,
                epsilon=e) for e in (0.0, 0.3)]
game = GameConfig()
# C=2 x S=2 -> flattened grid of 4 cells over 4 forced host devices
_, sw = sweep_training(states, data, fls, game, logits_fn, rounds=2)
assert sw["val_acc"].shape == (2, 2, 2)
for c in range(2):
    for s in range(2):
        _, ref = run_training_scan(cells[s][0], data, fls[c], game,
                                   logits_fn, 2)
        rel = float(jnp.max(jnp.abs(sw["val_acc"][c, s] - ref["val_acc"])))
        assert rel < 1e-5, (c, s, rel)
print("SWEEP_SHARDED_OK")
"""


@pytest.mark.slow
def test_grid_shards_across_forced_host_devices():
    """With 4 forced host devices the flattened C×S = 4 grid splits 4-ways
    and every sharded cell still matches its own sequential scan
    (subprocess: the device count is fixed at jax import)."""
    run_forced_devices(_SHARD_SCRIPT, marker="SWEEP_SHARDED_OK")
