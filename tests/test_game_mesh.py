"""Unified mesh layer tests (ISSUE 8 tentpole).

In-process tiers (single device, monkeypatched device counts) cover the
pure layout math: ``grid_layout`` factorization + padding minimization,
``layout_1d`` cache keying on the LIVE device count (the stale-cache bug
this PR fixes), edge-replication padding, and the env/arg override
precedence of ``device_count``.

Subprocess tiers (via ``tests/_multidevice.py`` — the device count is
fixed at jax import) cover execution: the 2D (cfg, draw) sweep mesh
matches the single-device sweep ≤1e-5 across proposed/ideal (ε>0 / ε=0)
× both ``sic_mode`` families on a NON-divisible C=3 × K=5 grid (remainder
padding sliced back off), with zero mid-sweep retraces; the serving path
matches shards=4 vs shards=1 on a mixed-N stream with zero retraces after
warmup; and an 8-forced-device smoke proves the layer is not hardwired
to 4.  Cross-mesh comparisons go through host numpy — arrays committed
to different meshes cannot mix in one jnp op.
"""
import numpy as np
import pytest

import jax

from _multidevice import run_forced_devices

from repro.sharding import game_mesh


# ---------------------------------------------------------------------------
# layout math (in-process, fake device counts)
# ---------------------------------------------------------------------------
@pytest.fixture
def fake_devices(monkeypatch):
    """Patch the visible device count (layout functions only ever take
    ``len(jax.devices())``); clears the mesh-layer caches around the test
    so nothing stale leaks in either direction."""
    def set_count(n):
        monkeypatch.setattr(jax, "devices", lambda backend=None: [None] * n)
        game_mesh.clear_cache()
    yield set_count
    monkeypatch.undo()
    game_mesh.clear_cache()


def test_layout_1d_keys_on_device_count(fake_devices):
    """The PR-1 bug: ``sharding_layout`` cached on k alone, so a device
    count change inside one process returned a stale layout."""
    fake_devices(1)
    assert game_mesh.layout_1d(8) == 1
    fake_devices(4)
    assert game_mesh.layout_1d(8) == 4       # not the stale 1
    fake_devices(3)
    assert game_mesh.layout_1d(8) == 2       # largest divisor ≤ 3
    fake_devices(1)
    assert game_mesh.layout_1d(8) == 1


def test_grid_layout_minimizes_padding(fake_devices):
    fake_devices(4)
    # C=3, K=5: (4, 1) pads to 4×5=20 cells; (2, 2) → 4×6=24; (1, 4) →
    # 3×8=24 — the minimum-padding factorization wins
    assert game_mesh.grid_layout(3, 5) == (4, 1)
    # divisible grid: ties break toward the draw axis (dk largest)
    assert game_mesh.grid_layout(4, 8) == (1, 4)
    # degenerate axes fall back to single-device
    assert game_mesh.grid_layout(0, 8) == (1, 1)
    fake_devices(1)
    assert game_mesh.grid_layout(3, 5) == (1, 1)


def test_batch_shards_and_padded_size(fake_devices):
    fake_devices(4)
    assert game_mesh.batch_shards(8) == 4
    assert game_mesh.batch_shards(3) == 3     # never an empty shard
    assert game_mesh.batch_shards(0) == 1
    assert game_mesh.padded_size(7, 4) == 8
    assert game_mesh.padded_size(8, 4) == 8


def test_device_count_override_precedence(fake_devices, monkeypatch):
    fake_devices(4)
    assert game_mesh.device_count() == 4
    monkeypatch.setenv("REPRO_MESH_DEVICES", "2")
    assert game_mesh.device_count() == 2      # env caps the default
    assert game_mesh.device_count(3) == 3     # explicit arg beats env
    monkeypatch.setenv("REPRO_MESH_DEVICES", "64")
    assert game_mesh.device_count() == 4      # clamped to what exists


def test_pad_axis_edge_replicates():
    x = np.arange(6.0).reshape(3, 2)
    out = np.asarray(game_mesh.pad_axis(x, 0, 5))
    assert out.shape == (5, 2)
    np.testing.assert_array_equal(out[:3], x)
    np.testing.assert_array_equal(out[3], x[-1])
    np.testing.assert_array_equal(out[4], x[-1])
    # already large enough: no-op
    assert game_mesh.pad_axis(x, 0, 3).shape == (3, 2)


# ---------------------------------------------------------------------------
# 2D sweep mesh == single-device sweep (forced 4 devices, subprocess)
# ---------------------------------------------------------------------------
_SWEEP_2D_SCRIPT = r"""
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import (GameConfig, TRACE_COUNTS,
                                    equilibrium, sweep_equilibrium)
from repro.sharding import game_mesh

assert len(jax.devices()) == 4, jax.devices()
C, K, N = 3, 5, 5                      # NON-divisible on both axes
assert game_mesh.grid_layout(C, K) == (4, 1)
h2 = sample_sic_channel_batch(jax.random.PRNGKey(3), K, N)
d = jnp.full((N,), 200.0); vmax = jnp.full((N,), 0.5)

for sic_mode in ("sequential", "blocked"):
    for eps in (0.0, 0.05):            # ideal / proposed DT-deviation
        base = GameConfig(sic_mode=sic_mode)
        cfgs = [dataclasses.replace(base, t_max=t) for t in (6.0, 9.0, 12.0)]
        before = TRACE_COUNTS["sweep_equilibrium"]
        out = sweep_equilibrium(cfgs, h2, d, vmax, epsilon=eps)
        # one trace per sic_mode family (ε is a traced operand: the second
        # ε of a family must hit the same executable)
        want = 1 if eps == 0.0 else 0
        assert TRACE_COUNTS["sweep_equilibrium"] - before == want, sic_mode
        en = np.asarray(jax.device_get(out.energy))
        assert en.shape == (C, K), en.shape    # remainder pad sliced off
        # re-dispatch with shifted values: zero mid-sweep retraces
        before = TRACE_COUNTS["sweep_equilibrium"]
        shifted = [dataclasses.replace(c, t_max=c.t_max + 0.5) for c in cfgs]
        sweep_equilibrium(shifted, h2, d, vmax, epsilon=eps)
        assert TRACE_COUNTS["sweep_equilibrium"] - before == 0, sic_mode
        for c in range(C):
            for k in range(K):
                ref = float(equilibrium(cfgs[c], h2[k], d, vmax,
                                        epsilon=eps).energy)
                rel = abs(float(en[c, k]) - ref) / max(abs(ref), 1e-12)
                assert rel <= 1e-5, (sic_mode, eps, c, k, rel)
print("SWEEP_2D_OK")
"""


def test_sweep_2d_mesh_matches_single_device():
    """Forced 4 devices: the padded 2D (cfg, draw) sweep equals the
    per-instance solves ≤1e-5 for proposed/ideal × both sic_mode
    families, with zero mid-sweep retraces on a value-shifted grid."""
    run_forced_devices(_SWEEP_2D_SCRIPT, marker="SWEEP_2D_OK",
                       timeout=600)


# ---------------------------------------------------------------------------
# serving: sharded buckets == unsharded buckets on a mixed-N stream
# ---------------------------------------------------------------------------
_SERVE_SCRIPT = r"""
import os
import numpy as np
import jax
from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest

assert len(jax.devices()) == 4, jax.devices()
rng = np.random.default_rng(11)
trace = []
for _ in range(12):
    n = int(rng.integers(1, 17))               # mixed-N stream
    trace.append((rng.uniform(0.2, 2.0, n).astype(np.float32),
                  float(rng.uniform(0.8, 1.5))))

def run_stream(shards_env):
    os.environ["REPRO_MESH_DEVICES"] = shards_env
    svc = AllocationService(buckets=(8, 16), max_batch=4, max_inflight=2)
    # warm the oma fallback too: infeasible cells walk the degraded-retry
    # ladder onto it, and a warmed pair keeps the stream retrace-free
    svc.warmup(schemes=("proposed", "oma"))
    before = TRACE_COUNTS["serve_allocation"]
    for h2, t_max in trace:
        svc.submit(AllocRequest(h2=h2, d=200.0, v_max=0.5,
                                cfg=GameConfig(t_max=t_max), epsilon=0.05))
    res = sorted(svc.drain(), key=lambda r: r.rid)
    retraces = TRACE_COUNTS["serve_allocation"] - before
    assert retraces == 0, f"shards={shards_env} retraced {retraces}x"
    return svc.shards, res

s1, ref = run_stream("1")
s4, got = run_stream("4")
assert s1 == 1 and s4 == 4, (s1, s4)
for a, b in zip(ref, got):
    for f in ("p", "q", "f"):
        x = np.asarray(getattr(a, f), np.float64)
        y = np.asarray(getattr(b, f), np.float64)
        rel = float(np.max(np.abs(x - y) / np.maximum(np.abs(x), 1e-12)))
        assert rel <= 1e-5, (a.rid, f, rel)
    for f in ("energy", "t_total"):
        x, y = float(getattr(a, f)), float(getattr(b, f))
        assert abs(x - y) <= 1e-5 * max(abs(x), 1e-12), (a.rid, f)
print("SERVE_SHARDED_OK")
"""


def test_serve_sharded_matches_unsharded():
    """Forced 4 devices: the service with its [B, nb] batch axis sharded
    4-ways returns the same allocations as shards=1 on a mixed-N stream,
    and neither stream retraces after warmup."""
    run_forced_devices(_SERVE_SCRIPT, marker="SERVE_SHARDED_OK",
                       timeout=600)


# ---------------------------------------------------------------------------
# 8-device smoke: the layer is not hardwired to 4
# ---------------------------------------------------------------------------
_SMOKE_8_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import GameConfig, batched_equilibrium, equilibrium
from repro.sharding import game_mesh

assert len(jax.devices()) == 8, jax.devices()
assert game_mesh.batch_shards(12) == 8       # K=12 pads to 16 over 8
cfg = GameConfig()
h2 = sample_sic_channel_batch(jax.random.PRNGKey(5), 12, 5)
d = jnp.full((5,), 200.0); vmax = jnp.full((5,), 0.5)
out = batched_equilibrium(cfg, h2, d, vmax)
en = np.asarray(jax.device_get(out.energy))
assert en.shape == (12,), en.shape           # pad sliced back off
for i in (0, 5, 11):
    ref = float(equilibrium(cfg, h2[i], d, vmax).energy)
    rel = abs(float(en[i]) - ref) / max(abs(ref), 1e-12)
    assert rel <= 1e-5, (i, rel)
print("SMOKE_8_OK")
"""


@pytest.mark.slow
def test_eight_device_smoke():
    """Forced 8 devices: non-divisible K=12 batch pads to 16, shards
    8-ways, and still matches per-instance solves."""
    run_forced_devices(_SMOKE_8_SCRIPT, devices=8, marker="SMOKE_8_OK",
                       timeout=600)
