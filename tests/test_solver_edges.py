"""Solver edge-case regressions guarding the invariants the trace-safe
engine refactor must preserve (ISSUE 1 satellite):

  * follower saturated branch (Σα > 1 → Eq. 29): Σα* = 1, equal DT finish
    times, and continuity into the slack branch;
  * ``wo_dt_allocation`` (v ≡ 0): no DT load, energy ≥ the DT-assisted
    equilibrium;
  * ``dinkelbach_power`` at the p_min/p_max box boundaries.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import noise_power, sample_channel_gains, sample_positions
from repro.core.dinkelbach import dinkelbach_power
from repro.core.stackelberg import (GameConfig, equilibrium, follower_alpha,
                                    wo_dt_allocation)

CFG = GameConfig()


def _channels(seed, n=5):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    h2 = sample_channel_gains(k2, sample_positions(k1, n))
    return jnp.sort(h2)[::-1]


# ---------------------------------------------------------------------------
# follower_alpha: saturated branch (Eq. 29)
# ---------------------------------------------------------------------------
def test_follower_saturated_sums_to_one_with_equal_finish():
    c, f_s = 1e7, 100e9
    d_hat = jnp.array([5000., 12000., 3000.])     # Σ c·D̂ / (t·f_S) > 1
    t_total = 0.1
    alpha, t_s = follower_alpha(c, d_hat, t_total, f_s)
    assert float(jnp.sum(c * d_hat / (t_total * f_s))) > 1.0  # branch taken
    assert float(jnp.sum(alpha)) == pytest.approx(1.0, abs=1e-6)  # Eq. 29
    t_n = c * d_hat / (alpha * f_s)
    assert jnp.allclose(t_n, t_n[0], rtol=1e-5)   # Theorem 1: equal finish
    assert float(t_s) == pytest.approx(float(t_n[0]), rel=1e-5)
    assert float(t_s) > t_total                    # server is the straggler


def test_follower_branch_continuity():
    """At the saturation threshold the two branches coincide (no jump)."""
    c, f_s = 1e7, 100e9
    d_hat = jnp.array([400., 600.])
    t_star = float(jnp.sum(c * d_hat) / f_s)       # Σα == 1 exactly here
    a_lo, _ = follower_alpha(c, d_hat, t_star * (1 - 1e-6), f_s)
    a_hi, _ = follower_alpha(c, d_hat, t_star * (1 + 1e-6), f_s)
    assert jnp.allclose(a_lo, a_hi, rtol=1e-4)


def test_follower_vmaps_over_batch():
    """Theorem-1 closed form is trace-safe: vmap across realizations."""
    c, f_s = 1e7, 100e9
    d_hat = jnp.array([[50., 120.], [4000., 8000.]])   # slack row, saturated row
    t_total = jnp.array([1.0, 0.5])
    alpha, t_s = jax.vmap(lambda d, t: follower_alpha(c, d, t, f_s))(d_hat,
                                                                     t_total)
    assert float(jnp.sum(alpha[0])) < 1.0              # Eq. 26 row
    assert float(jnp.sum(alpha[1])) == pytest.approx(1.0, abs=1e-6)  # Eq. 29


# ---------------------------------------------------------------------------
# wo_dt_allocation: v ≡ 0
# ---------------------------------------------------------------------------
def test_wo_dt_zero_mapping_and_zero_dt_load():
    h2 = _channels(7)
    d = jnp.array([200., 250., 300., 220., 180.])
    a = wo_dt_allocation(CFG, h2, d)
    assert bool(jnp.all(a.v == 0.0))
    assert bool(jnp.all(a.alpha == 0.0))          # no mapped data → no DT share
    assert float(jnp.max(a.t_dt)) == pytest.approx(0.0, abs=1e-9)
    # round latency is then purely the client path
    assert float(a.t_total) == pytest.approx(
        float(jnp.max(a.t_cmp + a.t_com)), rel=1e-6)


def test_wo_dt_dominated_by_dt_equilibrium():
    """v_max > 0 can only help the leader (energy ↓) — refactor must keep
    the paper's premise intact."""
    h2 = _channels(8)
    d = jnp.array([300., 350., 400., 320., 280.])
    a_dt = equilibrium(CFG, h2, d, jnp.full((5,), 0.6))
    a_wo = wo_dt_allocation(CFG, h2, d)
    assert float(a_dt.energy) < float(a_wo.energy)


# ---------------------------------------------------------------------------
# dinkelbach_power at the box boundaries
# ---------------------------------------------------------------------------
def test_dinkelbach_pmax_boundary():
    """A nearly-binding deadline pushes the rate-floor power past p_max:
    the solver must pin p = p_max (lo = min(p_floor, p_max), Eq. 43)."""
    f_eff, d, bw = 1e12, 1e6, 1e6
    g_tight = 0.02            # p_floor = (2^50−1)/1e12 ≈ 1.1e3 ≫ p_max
    p, q, _ = dinkelbach_power(d, g_tight, f_eff, bw, 0.01, 0.1)
    assert float(p) == pytest.approx(0.1, rel=1e-6)
    assert float(q) > 0


def test_dinkelbach_floor_binding_near_pmax():
    """Rate floor just inside the box: optimum sits exactly at the floor
    (R/U is decreasing in p, so the smallest feasible power wins)."""
    f_eff, d, bw = 1e12, 1e6, 1e6
    g = 0.0275                # p_floor ≈ 0.088, inside [0.01, 0.1]
    need = float((2.0 ** (d / (g * bw)) - 1.0) / f_eff)
    assert 0.01 < need < 0.1
    p, q, _ = dinkelbach_power(d, g, f_eff, bw, 0.01, 0.1)
    assert float(p) == pytest.approx(need, rel=1e-4)


def test_dinkelbach_pmin_boundary():
    """A huge effective gain makes the energy optimum interior point fall
    below p_min with a slack floor: the solver must pin p = p_min."""
    f_eff, d, bw = 1e16, 1e6, 1e6
    p, q, _ = dinkelbach_power(d, 9.0, f_eff, bw, 0.01, 0.1)
    assert float(p) == pytest.approx(0.01, rel=1e-6)
    # q must equal the ratio at the boundary point
    rate = bw * jnp.log2(1.0 + 0.01 * f_eff)
    assert float(q) == pytest.approx(float(rate / (0.01 * d)), rel=1e-4)


def test_dinkelbach_boundaries_inside_jit_and_vmap():
    """Boundary pinning survives jit+vmap (the batched-engine context)."""
    f_effs = jnp.array([1e12, 1e16])
    gs = jnp.array([0.02, 9.0])

    @jax.jit
    def solve(f_eff, g):
        p, q, _ = dinkelbach_power(1e6, g, f_eff, 1e6, 0.01, 0.1)
        return p

    ps = jax.vmap(solve)(f_effs, gs)
    assert float(ps[0]) == pytest.approx(0.1, rel=1e-6)
    assert float(ps[1]) == pytest.approx(0.01, rel=1e-6)
