"""Dry-run machinery integration test on a small host-device mesh.

Runs in a subprocess so the 8-device XLA flag doesn't leak into the main
test process (smoke tests must see 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from jax.sharding import Mesh
    import numpy as np

    from repro.configs import get_config, smoke_variant
    from repro.launch import dryrun as dr
    from repro.launch.specs import SHAPES, InputShape
    from repro.sharding.context import set_active_mesh

    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    set_active_mesh(mesh)

    # tiny shape + smoke config through the real build/lower/compile path
    import repro.launch.specs as specs
    shape = InputShape("tiny_train", seq_len=32, global_batch=8, mode="train")
    specs.SHAPES["tiny_train"] = shape
    dshape = InputShape("tiny_decode", seq_len=64, global_batch=8, mode="decode")
    specs.SHAPES["tiny_decode"] = dshape

    import repro.configs as C
    real_get = C.get_config
    def patched(arch):
        return smoke_variant(real_get(arch))
    dr.get_config = patched

    out = {}
    for arch in ("gemma2-9b", "olmoe-1b-7b", "zamba2-2.7b"):
        for shp in ("tiny_train", "tiny_decode"):
            fn, args, in_sh, out_sh, donate, meta = dr.build_lowerable(
                arch, shp, mesh)
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
            mem = compiled.memory_analysis()
            coll = dr.collective_stats(compiled.as_text())
            out[f"{arch}/{shp}"] = {
                "temp": int(mem.temp_size_in_bytes),
                "coll": int(coll["total_bytes"]),
            }
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh_lowers_and_compiles():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
                       cwd=".", timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out) == 6
    for k, v in out.items():
        assert v["temp"] > 0, k
    # sharded training must actually communicate
    assert out["gemma2-9b/tiny_train"]["coll"] > 0
