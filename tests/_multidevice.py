"""Shared forced-host-device subprocess harness for multi-device tests.

The XLA host device count is fixed when jax initializes, so any test that
wants ``len(jax.devices()) > 1`` on a CPU box must run its body in a fresh
subprocess with ``--xla_force_host_platform_device_count`` in XLA_FLAGS.
Three test files grew their own copy of that boilerplate (env assembly,
PYTHONPATH splice, returncode/marker asserts); this module is the single
copy they now share.
"""
from __future__ import annotations

import os
import subprocess
import sys

DEVICE_PREFIX = "--xla_force_host_platform_device_count"


def forced_device_env(devices: int) -> dict:
    """A subprocess env with ``devices`` forced host devices: repo ``src``
    on PYTHONPATH, any stale device-count flag/override stripped."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    keep = [f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith(DEVICE_PREFIX)]
    env["XLA_FLAGS"] = " ".join(keep + [f"{DEVICE_PREFIX}={devices}"])
    for k in ("REPRO_MESH_DEVICES", "REPRO_FORCE_DEVICES"):
        env.pop(k, None)
    return env


def run_forced_devices(script: str, devices: int = 4, marker: str = "OK",
                       timeout: int = 420) -> str:
    """Run ``script`` under ``devices`` forced host devices; assert clean
    exit and that ``marker`` was printed (the script's own success line —
    asserting on it catches scripts that die before their checks run).
    Returns stdout for extra assertions."""
    proc = subprocess.run([sys.executable, "-c", script],
                          env=forced_device_env(devices),
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert marker in proc.stdout, proc.stdout[-2000:]
    return proc.stdout
