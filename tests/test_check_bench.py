"""``scripts/check_bench.py`` gate semantics — the corrupt-JSON regression.

A half-written bench JSON (killed bench run) used to raise an unhandled
``json.JSONDecodeError`` and crash the gate; the fix reports the reason and
FAILS that bench explicitly (exit 1) — a corrupt bench must not exit 0 via
the missing-file SKIP path either.  Also pins the surrounding contract:
missing file still SKIPs, and a regressed rate still fails.
"""
import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                 "check_bench.py"))
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


@pytest.fixture
def fake_repo(tmp_path, monkeypatch):
    """Point the gate at a temp repo root with a stubbed committed
    baseline, so tests control both sides of the comparison."""
    monkeypatch.setattr(check_bench, "REPO_ROOT", str(tmp_path))
    baselines = {}
    monkeypatch.setattr(check_bench, "_load_committed",
                        lambda name: baselines.get(name))
    return tmp_path, baselines


def _write(root, name, text):
    (root / name).write_text(text)


def test_corrupt_current_json_fails_explicitly(fake_repo, capsys):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", '{"requests_per_sec": 10')  # truncated
    assert check_bench.check() == 1
    out = capsys.readouterr().out
    assert "CORRUPT" in out and "JSONDecodeError" in out
    assert "BENCH_serve.json:corrupt" in out
    assert "SKIP" not in [l.strip().split()[0] for l in out.splitlines()
                          if "BENCH_serve" in l]


def test_corrupt_fails_even_without_baseline(fake_repo):
    """No committed baseline would normally SKIP — but a corrupt current
    file must still fail (the bug was exactly this silent path)."""
    root, _ = fake_repo
    _write(root, "BENCH_equilibrium.json", "not json at all {{{")
    assert check_bench.check(verbose=False) == 1


def test_missing_file_still_skips(fake_repo, capsys):
    assert check_bench.check() == 0
    assert "SKIP" in capsys.readouterr().out


def test_regression_still_fails(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"requests_per_sec": 50.0}))
    assert check_bench.check(verbose=False) == 1


def test_within_tolerance_passes(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"requests_per_sec": 90.0}))
    assert check_bench.check(verbose=False) == 0


def test_missing_gated_metric_fails(fake_repo):
    """A rate the baseline tracks but the current file lost must gate."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"note": "no rate"}))
    assert check_bench.check(verbose=False) == 1


# ---------------------------------------------------------------------------
# ISSUE-7 satellites: per-metric tolerance + best-of-k remeasure + claims
# ---------------------------------------------------------------------------
def test_per_metric_tolerance_from_current_file(fake_repo):
    """A 35% drop fails at the default −20% but passes when the bench file
    declares a wider per-metric tolerance (this container's timing noise
    is recorded at ±30%)."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    doc = {"requests_per_sec": 65.0,
           "tolerances": {"requests_per_sec": 0.40}}
    _write(root, "BENCH_serve.json", json.dumps(doc))
    assert check_bench.check(verbose=False) == 0
    # …and the same measurement without the override fails
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 65.0}))
    assert check_bench.check(verbose=False) == 1


def test_per_metric_tolerance_from_baseline(fake_repo):
    """The committed baseline's tolerances apply when the current file
    carries none (a re-run that forgot the override stays covered)."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0,
                                     "tolerances":
                                         {"requests_per_sec": 0.40}}
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 65.0}))
    assert check_bench.check(verbose=False) == 0


def test_tolerance_does_not_leak_across_metrics(fake_repo):
    """An override on one label must not widen the gate for others."""
    root, baselines = fake_repo
    baselines["BENCH_training.json"] = {"scan_rounds_per_sec": 100.0,
                                        "vmap_rounds_per_sec": 100.0}
    doc = {"scan_rounds_per_sec": 65.0, "vmap_rounds_per_sec": 65.0,
           "tolerances": {"scan": 0.40}}      # gates use metric labels
    _write(root, "BENCH_training.json", json.dumps(doc))
    assert check_bench.check(verbose=False) == 1


def test_remeasure_best_of_k_rescues_transient_stall(fake_repo):
    """A failing first measurement re-measures through the hook; the best
    of k values is gated, so a one-off stall passes."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 50.0}))   # stalled run
    calls = []

    def remeasure(name):
        calls.append(name)
        return {"requests_per_sec": 95.0}            # healthy re-run

    assert check_bench.check(verbose=False, remeasure=remeasure, k=2) == 0
    assert calls == ["BENCH_serve.json"]


def test_remeasure_exhausted_still_fails(fake_repo):
    """k re-measures that all regress must still fail the gate."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 50.0}))
    calls = []

    def remeasure(name):
        calls.append(name)
        return {"requests_per_sec": 55.0}            # still regressed

    assert check_bench.check(verbose=False, remeasure=remeasure, k=3) == 1
    assert len(calls) == 2                           # k-1 re-measures


def test_remeasure_not_called_when_passing(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 95.0}))
    calls = []
    assert check_bench.check(verbose=False,
                             remeasure=lambda n: calls.append(n)) == 0
    assert calls == []


def test_false_claim_fails_gate(fake_repo, capsys):
    """A robustness headline recorded false must fail even when every
    throughput metric passes."""
    root, baselines = fake_repo
    baselines["BENCH_robustness.json"] = {"grid_rounds_per_sec": 100.0}
    doc = {"grid_rounds_per_sec": 110.0,
           "claims": {"defended_within_5pts_of_clean": False,
                      "margin_pts": 7.3}}            # non-bool = context
    _write(root, "BENCH_robustness.json", json.dumps(doc))
    assert check_bench.check() == 1
    out = capsys.readouterr().out
    assert "VIOLATED" in out
    assert "claim:defended_within_5pts_of_clean" in out


def test_true_claims_pass(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_robustness.json"] = {"grid_rounds_per_sec": 100.0}
    doc = {"grid_rounds_per_sec": 100.0,
           "claims": {"defended_within_5pts_of_clean": True,
                      "no_defense_degrades_more": True}}
    _write(root, "BENCH_robustness.json", json.dumps(doc))
    assert check_bench.check(verbose=False) == 0


# ---------------------------------------------------------------------------
# ISSUE-9: the serve resilience sections (overload / chaos)
# ---------------------------------------------------------------------------
def _resilience_doc(ov_rps=100.0, ch_rps=50.0, **claims):
    base = {"overload_no_lost_requests": True,
            "overload_hi_priority_p99_bounded": True,
            "chaos_no_lost_requests": True,
            "chaos_no_nan_leak": True}
    base.update(claims)
    return {"requests_per_sec": 100.0,
            "overload": {"requests_per_sec": ov_rps},
            "chaos": {"requests_per_sec": ch_rps},
            "claims": base}


def test_serve_resilience_sections_gated(fake_repo, capsys):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _resilience_doc()
    _write(root, "BENCH_serve.json", json.dumps(_resilience_doc()))
    assert check_bench.check() == 0
    out = capsys.readouterr().out
    assert "overload_rps" in out and "chaos_rps" in out
    # a collapsed overload rate regresses like any gated metric
    _write(root, "BENCH_serve.json", json.dumps(_resilience_doc(ov_rps=40)))
    assert check_bench.check(verbose=False) == 1


def test_serve_lost_request_claim_fails_gate(fake_repo, capsys):
    """The exactly-once headline is a hard gate: a chaos run that lost a
    request fails even with healthy throughput."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _resilience_doc()
    _write(root, "BENCH_serve.json",
           json.dumps(_resilience_doc(chaos_no_lost_requests=False)))
    assert check_bench.check() == 1
    assert "claim:chaos_no_lost_requests" in capsys.readouterr().out


def test_serve_hi_priority_p99_claim_fails_gate(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _resilience_doc()
    _write(root, "BENCH_serve.json", json.dumps(
        _resilience_doc(overload_hi_priority_p99_bounded=False)))
    assert check_bench.check(verbose=False) == 1


def test_serve_lost_resilience_section_fails(fake_repo, capsys):
    """Once the baseline carries overload/chaos sections, a bench that
    stops reporting them must fail (section-presence via the gated rate)."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _resilience_doc()
    _write(root, "BENCH_serve.json",
           json.dumps({"requests_per_sec": 100.0,
                       "claims": {"chaos_no_lost_requests": True}}))
    assert check_bench.check() == 1
    out = capsys.readouterr().out
    assert "overload_rps" in out and "MISSING" in out


def test_serve_resilience_tolerances_apply(fake_repo):
    """The ±35% declared window: a 30% drop on overload_rps passes with
    the override, fails without."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _resilience_doc(ov_rps=100.0)
    doc = _resilience_doc(ov_rps=70.0)
    doc["tolerances"] = {"overload_rps": 0.35}
    _write(root, "BENCH_serve.json", json.dumps(doc))
    assert check_bench.check(verbose=False) == 0
    _write(root, "BENCH_serve.json", json.dumps(_resilience_doc(ov_rps=70)))
    assert check_bench.check(verbose=False) == 1


# ---------------------------------------------------------------------------
# ISSUE-8: the multi-device scaling gate
# ---------------------------------------------------------------------------
def _scaling_doc(eff_vmap=0.9, eff_sweep=0.9, parity=1e-7, noise=0.10,
                 gate=("vmap", "sweep")):
    return {
        "requests_per_sec": 100.0,
        "scaling": {
            "devices_measured": [1, 2, 4],
            "host_cores": 1,
            "normalizer": 1,
            "efficiency_gate_tiers": list(gate),
            "min_efficiency": 0.70,
            "efficiency_noise": noise,
            "tiers": {
                "vmap": {"rates_per_s": {"1": 10.0, "2": 10.0, "4": 10.0},
                         "efficiency_at_max": eff_vmap,
                         "parity_max_rel": parity},
                "sweep": {"rates_per_s": {"1": 10.0, "2": 10.0, "4": 10.0},
                          "efficiency_at_max": eff_sweep,
                          "parity_max_rel": parity},
            },
        },
    }


def test_scaling_efficiency_gate(fake_repo, capsys):
    """A gate tier below min_efficiency − declared noise fails; one above
    the floor passes."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps(_scaling_doc(eff_vmap=0.55)))
    assert check_bench.check() == 1
    assert "scaling:vmap:efficiency" in capsys.readouterr().out
    _write(root, "BENCH_serve.json", json.dumps(_scaling_doc(eff_vmap=0.65)))
    assert check_bench.check(verbose=False) == 0   # 0.70 − 0.10 noise floor


def test_scaling_noise_margin_is_capped(fake_repo):
    """A bench cannot declare its way past the gate: efficiency_noise is
    capped, so 0.40 of declared noise still fails a 0.30 efficiency."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json",
           json.dumps(_scaling_doc(eff_sweep=0.30, noise=0.40)))
    assert check_bench.check(verbose=False) == 1


def test_scaling_parity_is_a_hard_gate(fake_repo, capsys):
    """Sharded-vs-single-device drift past 1e-5 fails even on ungated
    tiers — the numerics contract has no noise excuse."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json",
           json.dumps(_scaling_doc(parity=3e-4, gate=())))
    assert check_bench.check() == 1
    out = capsys.readouterr().out
    assert "scaling:vmap:parity" in out and "scaling:sweep:parity" in out


def test_lost_scaling_section_fails(fake_repo, capsys):
    """A bench whose baseline carries a scaling section must not silently
    drop it."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = _scaling_doc()
    _write(root, "BENCH_serve.json", json.dumps({"requests_per_sec": 100.0}))
    assert check_bench.check() == 1
    assert "scaling" in capsys.readouterr().out


def test_scaling_section_without_baseline_still_gates(fake_repo):
    """The gate reads the current file's own declared thresholds — a brand
    new scaling section is gated even before a baseline exists."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps(_scaling_doc(eff_vmap=0.10)))
    assert check_bench.check(verbose=False) == 1
