"""``scripts/check_bench.py`` gate semantics — the corrupt-JSON regression.

A half-written bench JSON (killed bench run) used to raise an unhandled
``json.JSONDecodeError`` and crash the gate; the fix reports the reason and
FAILS that bench explicitly (exit 1) — a corrupt bench must not exit 0 via
the missing-file SKIP path either.  Also pins the surrounding contract:
missing file still SKIPs, and a regressed rate still fails.
"""
import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                 "check_bench.py"))
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


@pytest.fixture
def fake_repo(tmp_path, monkeypatch):
    """Point the gate at a temp repo root with a stubbed committed
    baseline, so tests control both sides of the comparison."""
    monkeypatch.setattr(check_bench, "REPO_ROOT", str(tmp_path))
    baselines = {}
    monkeypatch.setattr(check_bench, "_load_committed",
                        lambda name: baselines.get(name))
    return tmp_path, baselines


def _write(root, name, text):
    (root / name).write_text(text)


def test_corrupt_current_json_fails_explicitly(fake_repo, capsys):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", '{"requests_per_sec": 10')  # truncated
    assert check_bench.check() == 1
    out = capsys.readouterr().out
    assert "CORRUPT" in out and "JSONDecodeError" in out
    assert "BENCH_serve.json:corrupt" in out
    assert "SKIP" not in [l.strip().split()[0] for l in out.splitlines()
                          if "BENCH_serve" in l]


def test_corrupt_fails_even_without_baseline(fake_repo):
    """No committed baseline would normally SKIP — but a corrupt current
    file must still fail (the bug was exactly this silent path)."""
    root, _ = fake_repo
    _write(root, "BENCH_equilibrium.json", "not json at all {{{")
    assert check_bench.check(verbose=False) == 1


def test_missing_file_still_skips(fake_repo, capsys):
    assert check_bench.check() == 0
    assert "SKIP" in capsys.readouterr().out


def test_regression_still_fails(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"requests_per_sec": 50.0}))
    assert check_bench.check(verbose=False) == 1


def test_within_tolerance_passes(fake_repo):
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"requests_per_sec": 90.0}))
    assert check_bench.check(verbose=False) == 0


def test_missing_gated_metric_fails(fake_repo):
    """A rate the baseline tracks but the current file lost must gate."""
    root, baselines = fake_repo
    baselines["BENCH_serve.json"] = {"requests_per_sec": 100.0}
    _write(root, "BENCH_serve.json", json.dumps({"note": "no rate"}))
    assert check_bench.check(verbose=False) == 1
