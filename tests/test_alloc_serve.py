"""Ragged-N streaming allocation service: padded-bucket parity + scheduler.

The serving contract (ISSUE 6 tentpole): a request solved inside a padded
bucket must MATCH the exact-N solve — same p/q/f/latency/energy within the
repo's 1e-5 relative budget (empirically the masked path is bitwise equal:
zero-gain tails are invisible to every suffix sum and the mask erases the
padded lanes from every reduction) — and a mixed-N stream over warm buckets
must trigger ZERO retraces (TRACE_COUNTS["serve_allocation"]).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fl_round import allocate_batched
from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import (DEFAULT_BUCKETS, AllocationService,
                                      AllocRequest)

REL = 1e-5
D_BITS, V_MAX, EPS = 200.0, 0.5, 0.05


def _rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-12)))


def _draw(n, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.2, 2.0, n).astype(np.float32)


def _exact(cfg, h2, scheme="proposed"):
    """Exact-N oracle via the batched engine (already parity-locked to the
    scalar solver in tests/test_equilibrium_batched.py)."""
    order = np.argsort(-h2, kind="stable")
    n = h2.shape[0]
    out = allocate_batched(
        scheme, cfg, jnp.asarray(h2[order])[None, :],
        jnp.full((1, n), D_BITS, jnp.float32),
        jnp.full((1, n), V_MAX, jnp.float32), epsilon=EPS)
    inv = np.empty_like(order)
    inv[order] = np.arange(n)
    per = {f: np.asarray(getattr(out, f))[0][inv]
           for f in ("p", "q", "f", "alpha", "rates")}
    return per, out


def _serve_one(h2, scheme, cfg, buckets=(8, 16), max_batch=2):
    svc = AllocationService(buckets=buckets, max_batch=max_batch)
    svc.submit(AllocRequest(h2=h2, d=D_BITS, v_max=V_MAX, cfg=cfg,
                            scheme=scheme, epsilon=EPS))
    (res,) = svc.drain()
    return res


class TestPaddedParity:
    """Padded-bucket solve == exact-N solve, across schemes and sic modes."""

    @pytest.mark.parametrize("scheme", ["proposed", "ideal", "wo_dt",
                                        "oma", "oma_tdma"])
    def test_scheme_parity(self, scheme):
        h2 = _draw(5, seed=3)                      # n=5 inside bucket 8
        cfg = GameConfig()
        res = _serve_one(h2, scheme, cfg)
        per, out = _exact(cfg, h2, scheme=scheme)
        for f in ("p", "q", "f", "alpha", "rates"):
            assert _rel(getattr(res, f), per[f]) <= REL, f
        assert _rel(res.t_total, out.t_total[0]) <= REL
        assert _rel(res.energy, out.energy[0]) <= REL
        assert res.feasible == bool(out.feasible[0])

    @pytest.mark.parametrize("sic_mode", ["sequential", "blocked",
                                          "blocked_interpret"])
    def test_sic_mode_parity(self, sic_mode):
        h2 = _draw(11, seed=7)                     # n=11 inside bucket 16
        cfg = GameConfig(sic_mode=sic_mode)
        res = _serve_one(h2, "proposed", cfg)
        per, out = _exact(cfg, h2)
        for f in ("p", "q", "f"):
            assert _rel(getattr(res, f), per[f]) <= REL, f
        assert _rel(res.t_total, out.t_total[0]) <= REL
        assert _rel(res.energy, out.energy[0]) <= REL

    def test_n1_smallest_bucket(self):
        """N=1 rides the smallest bucket with 7 padded lanes — the edge the
        service's smallest bucket surfaces (ISSUE satellite 3)."""
        h2 = _draw(1, seed=11)
        cfg = GameConfig()
        res = _serve_one(h2, "proposed", cfg)
        per, out = _exact(cfg, h2)
        assert res.bucket == 8 and res.n == 1
        assert _rel(res.p, per["p"]) <= REL
        assert _rel(res.energy, out.energy[0]) <= REL
        assert np.isfinite(res.t_total) and np.isfinite(res.energy)

    def test_original_order_restored(self):
        """h2 submitted in ascending (anti-SIC) order comes back aligned
        with the request's own client indexing."""
        h2 = np.sort(_draw(6, seed=5))             # ascending on purpose
        cfg = GameConfig()
        res = _serve_one(h2, "proposed", cfg)
        per, _ = _exact(cfg, h2)
        # per-client parity in the REQUEST's order is the proof: rates are
        # channel-dependent, so a wrong unsort permutation cannot match
        assert _rel(res.p, per["p"]) <= REL
        assert _rel(res.rates, per["rates"]) <= REL
        assert _rel(res.alpha, per["alpha"]) <= REL

    def test_heterogeneous_physics_one_batch(self):
        """Two requests with different t_max/bandwidth share one dispatch
        and each matches its own exact solve."""
        cfg_a = GameConfig(t_max=1.0)
        cfg_b = GameConfig(t_max=2.5, bandwidth=2e6)
        h2a, h2b = _draw(4, seed=21), _draw(6, seed=22)
        svc = AllocationService(buckets=(8,), max_batch=2)
        ra = svc.submit(AllocRequest(h2=h2a, cfg=cfg_a, epsilon=EPS))
        rb = svc.submit(AllocRequest(h2=h2b, cfg=cfg_b, epsilon=EPS))
        res = {r.rid: r for r in svc.drain()}
        assert svc.stats["dispatches"] == 1        # one shared batch
        for rid, cfg, h2 in ((ra, cfg_a, h2a), (rb, cfg_b, h2b)):
            per, out = _exact(cfg, h2)
            assert _rel(res[rid].p, per["p"]) <= REL
            assert _rel(res[rid].energy, out.energy[0]) <= REL

    def test_random_scheme_in_box(self):
        """The random baseline's draws stay inside the physics box even
        through the padded path (distributional scheme — no bitwise
        oracle, bucket-shaped draws differ from exact-N draws)."""
        h2 = _draw(5, seed=9)
        cfg = GameConfig()
        res = _serve_one(h2, "random", cfg)
        assert np.all(res.p >= cfg.p_min - 1e-9)
        assert np.all(res.p <= cfg.p_max + 1e-9)
        assert np.all(res.f <= cfg.f_max + 1e-6)
        assert np.isfinite(res.energy) and np.isfinite(res.t_total)


class TestScheduler:
    def test_zero_retrace_mixed_stream(self):
        """50-request mixed-N stream over warm buckets: ZERO retraces
        (the ISSUE acceptance criterion)."""
        svc = AllocationService(buckets=(8, 16), max_batch=4)
        svc.warmup(schemes=("proposed",))
        before = TRACE_COUNTS["serve_allocation"]
        rng = np.random.default_rng(0)
        for i in range(50):
            n = int(rng.integers(1, 17))
            svc.submit(AllocRequest(h2=_draw(n, seed=100 + i), epsilon=EPS))
        res = svc.drain()
        assert len(res) == 50
        assert TRACE_COUNTS["serve_allocation"] == before  # zero retraces
        assert all(np.isfinite(r.energy) and np.isfinite(r.t_total)
                   for r in res)

    def test_partial_batch_dummy_rows_finite(self):
        """A lone request padded with all-masked dummy rows must not be
        poisoned by them (the follower_alpha 0/0 guard regression)."""
        svc = AllocationService(buckets=(8,), max_batch=4)
        svc.submit(AllocRequest(h2=_draw(3, seed=1), epsilon=EPS))
        (res,) = svc.drain()
        assert svc.stats["padded_slots"] == 3
        assert np.all(np.isfinite(res.p)) and np.isfinite(res.energy)

    def test_bucket_routing_and_overflow(self):
        svc = AllocationService(buckets=DEFAULT_BUCKETS)
        assert svc.bucket_for(1) == 8
        assert svc.bucket_for(8) == 8
        assert svc.bucket_for(9) == 16
        assert svc.bucket_for(128) == 128
        with pytest.raises(ValueError, match="exceeds the largest bucket"):
            svc.bucket_for(129)
        with pytest.raises(ValueError, match="unknown scheme"):
            svc.submit(AllocRequest(h2=np.ones(3), scheme="nope"))
        with pytest.raises(ValueError, match="0 clients"):
            svc.submit(AllocRequest(h2=np.ones(0)))

    def test_full_batch_autoflush(self):
        svc = AllocationService(buckets=(8,), max_batch=2)
        svc.submit(AllocRequest(h2=_draw(3, seed=1)))
        assert svc.stats["dispatches"] == 0
        svc.submit(AllocRequest(h2=_draw(4, seed=2)))
        assert svc.stats["dispatches"] == 1        # auto-flushed when full
        assert len(svc.drain()) == 2

    def test_latency_recorded(self):
        svc = AllocationService(buckets=(8,))
        svc.submit(AllocRequest(h2=_draw(4, seed=2)))
        (res,) = svc.drain()
        assert res.latency_s > 0.0


class TestGracefulDegradation:
    """ISSUE-7 satellite: undispatchable or infeasible requests come back
    as structured per-request rows instead of exceptions that kill the
    in-flight stream."""

    def test_overflow_rejected_not_fatal(self):
        """An N > largest-bucket request mid-stream yields a
        status='rejected' NaN row; the surrounding requests still solve."""
        svc = AllocationService(buckets=(8,), max_batch=2)
        ra = svc.submit(AllocRequest(h2=_draw(4, seed=31), epsilon=EPS))
        rbad = svc.submit(AllocRequest(h2=_draw(9, seed=32), epsilon=EPS))
        rb = svc.submit(AllocRequest(h2=_draw(5, seed=33), epsilon=EPS))
        res = {r.rid: r for r in svc.drain()}
        assert len(res) == 3
        bad = res[rbad]
        assert bad.status == "rejected"
        assert "exceeds the largest bucket" in bad.error
        assert bad.n == 9 and not bad.feasible
        assert np.all(np.isnan(bad.p)) and np.isnan(bad.energy)
        assert svc.stats["rejected"] == 1
        for rid in (ra, rb):
            assert res[rid].status == "ok"
            assert np.all(np.isfinite(res[rid].p))

    def test_ok_status_on_normal_request(self):
        svc = AllocationService(buckets=(8,))
        svc.submit(AllocRequest(h2=_draw(4, seed=2), epsilon=EPS))
        (res,) = svc.drain()
        assert res.status == "ok" and res.error == "" and res.feasible

    def test_infeasible_tagged_not_fatal(self):
        """A cell whose deadline cannot be met solves to feasible=False and
        is tagged status='infeasible' — the allocation is still returned
        (the solver's best answer) and the stream keeps running."""
        svc = AllocationService(buckets=(8,), max_batch=2)
        tight = GameConfig(t_max=1e-4)             # unmeetable deadline
        r_bad = svc.submit(AllocRequest(h2=_draw(4, seed=41), cfg=tight,
                                        epsilon=EPS))
        r_ok = svc.submit(AllocRequest(h2=_draw(4, seed=42), epsilon=EPS))
        res = {r.rid: r for r in svc.drain()}
        assert res[r_bad].status == "infeasible"
        assert not res[r_bad].feasible
        assert "deadline" in res[r_bad].error
        assert svc.stats["infeasible"] == 1
        assert res[r_ok].status == "ok" and res[r_ok].feasible
