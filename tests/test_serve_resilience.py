"""ISSUE-9 resilience layer: SLA admission, priority shedding, the
degraded-retry ladder, circuit breaker transitions, watchdog, dispatch
backoff, health() — plus the satellite fixes (honest reject latency,
rid-sorted drain, bucket_for as single oversize source, warmup edge
cases) and the baseline-parity guarantee (SLA mode with default
priorities is bit-identical to the legacy blocking scheduler)."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.launch.alloc_serve as alloc_serve
from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest


def _key(svc, nb, scheme="proposed", cfg=None):
    cfg = cfg or GameConfig()
    return (nb, scheme, cfg.dinkelbach_inner, cfg.sic_mode)


def _reqs(k, seed=0, n_lo=1, n_hi=8, **kw):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(k):
        n = int(rng.integers(n_lo, n_hi + 1))
        out.append(AllocRequest(h2=rng.uniform(0.05, 2.0, n), seed=i, **kw))
    return out


def _poison(real):
    def wrapped(*a, **kw):
        out = real(*a, **kw)
        return jax.tree_util.tree_map(
            lambda x: jnp.full_like(x, jnp.nan)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, out)
    return wrapped


# ---------------------------------------------------------------------------
# per-request SLA
# ---------------------------------------------------------------------------
def test_admission_control_rejects_fast():
    svc = AllocationService(buckets=(8,), max_batch=4)
    svc._ewma[_key(svc, 8)] = 10.0          # pretend dispatches take 10 s
    rid = svc.submit(AllocRequest(h2=np.ones(4), deadline_s=0.5))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "rejected"
    assert "admission control" in res[rid].error
    assert res[rid].latency_s > 0.0         # honest reject latency
    assert svc.stats["admission_rejected"] == 1
    # a generous deadline is admitted despite the same EWMA
    rid2 = svc.submit(AllocRequest(h2=np.ones(4), deadline_s=100.0))
    res2 = {r.rid: r for r in svc.drain()}
    assert res2[rid2].status == "ok"


def test_admission_skipped_until_ewma_seeded():
    svc = AllocationService(buckets=(8,), max_batch=4)
    rid = svc.submit(AllocRequest(h2=np.ones(4), deadline_s=5.0))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"          # no EWMA yet → admit
    assert _key(svc, 8) in svc._ewma        # completion seeded it


def test_priority_shedding_lowest_youngest_first():
    svc = AllocationService(buckets=(8,), max_batch=4, max_queue=2)
    rids = [svc.submit(AllocRequest(h2=np.ones(3), priority=p, seed=i))
            for i, p in enumerate((0, 5, 0, 5))]
    res = {r.rid: r for r in svc.drain()}
    assert len(res) == 4                    # exactly once, shed included
    assert res[rids[1]].status == "ok" and res[rids[3]].status == "ok"
    assert res[rids[0]].status == "shed"    # low priority sheds ...
    assert res[rids[2]].status == "shed"    # ... youngest-low first
    shed = res[rids[2]]
    assert "max_queue" in shed.error and shed.latency_s > 0.0
    assert np.all(np.isnan(shed.p)) and shed.priority == 0
    assert svc.stats["shed"] == 2


def test_deadline_timeout_tagged_on_late_completion():
    svc = AllocationService(buckets=(8,), max_batch=4)
    real = svc._dispatch

    def slow(*a, **kw):
        out = real(*a, **kw)
        time.sleep(0.08)                    # completion lands past deadline
        return out

    svc._dispatch = slow
    rid = svc.submit(AllocRequest(h2=np.ones(4), deadline_s=0.05))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "timeout"
    assert "deadline" in res[rid].error
    assert res[rid].feasible                # arrays still usable
    assert np.all(np.isfinite(res[rid].p))
    assert svc.stats["timeout"] == 1


def test_deadline_expired_in_queue():
    svc = AllocationService(buckets=(8,), max_batch=4, max_queue=16)
    rid = svc.submit(AllocRequest(h2=np.ones(3), deadline_s=1e-4))
    time.sleep(0.01)                        # expires while queued
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "timeout"
    assert "expired while queued" in res[rid].error
    assert svc.stats["expired_in_queue"] == 1


def test_high_priority_packed_first():
    # max_batch=2 with 3 queued: the two high-priority requests must ride
    # the first dispatch even though a low-priority request arrived first
    svc = AllocationService(buckets=(8,), max_batch=2, max_queue=16)
    lo = svc.submit(AllocRequest(h2=np.ones(3), priority=0))
    hi1 = svc.submit(AllocRequest(h2=np.ones(3), priority=3))
    hi2 = svc.submit(AllocRequest(h2=np.ones(3), priority=3))
    res = {r.rid: r for r in svc.drain()}
    assert all(res[r].status == "ok" for r in (lo, hi1, hi2))
    h = svc.health()
    assert set(h["latency_by_priority_ms"]) == {"0", "3"}


# ---------------------------------------------------------------------------
# degraded retry
# ---------------------------------------------------------------------------
def test_retry_ladder_relax_tmax_recovers():
    # seed-3 n=5 draw: infeasible at t_max=0.55, feasible at 0.55*1.5
    h2 = np.random.default_rng(3).uniform(0.2, 2.0, 5)
    svc = AllocationService(buckets=(8,), max_batch=1)
    rid = svc.submit(AllocRequest(h2=h2, cfg=GameConfig(t_max=0.55)))
    res = {r.rid: r for r in svc.drain()}
    r = res[rid]
    assert r.status == "ok" and r.feasible
    assert r.degradation == ("relax_tmax:1.5",)
    assert r.scheme == "proposed"
    assert svc.stats["retries"] == 1
    assert svc.stats["degraded_ok"] == 1
    assert svc.stats["infeasible"] == 0


def test_retry_ladder_exhausts_to_infeasible():
    h2 = np.random.default_rng(3).uniform(0.2, 2.0, 5)
    svc = AllocationService(buckets=(8,), max_batch=1)
    rid = svc.submit(AllocRequest(h2=h2, cfg=GameConfig(t_max=1e-4)))
    res = {r.rid: r for r in svc.drain()}
    r = res[rid]
    assert r.status == "infeasible" and not r.feasible
    assert r.degradation == ("relax_tmax:1.5", "fallback:oma")
    assert r.scheme == "oma"                # final arrays from the fallback
    assert "deadline" in r.error
    assert svc.stats["retries"] == 2
    assert svc.stats["infeasible"] == 1


def test_allow_degraded_false_skips_ladder():
    h2 = np.random.default_rng(3).uniform(0.2, 2.0, 5)
    svc = AllocationService(buckets=(8,), max_batch=1)
    rid = svc.submit(AllocRequest(h2=h2, cfg=GameConfig(t_max=0.55),
                                  allow_degraded=False))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "infeasible"
    assert res[rid].degradation == ()
    assert svc.stats["retries"] == 0


def test_random_scheme_earns_no_retries():
    svc = AllocationService(buckets=(8,), max_batch=1)
    rid = svc.submit(AllocRequest(h2=np.ones(3), scheme="random",
                                  cfg=GameConfig(t_max=1e-6)))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "infeasible"
    assert res[rid].degradation == ()
    assert svc.stats["retries"] == 0


def test_dispatch_backoff_recovers_from_transient_failure():
    svc = AllocationService(buckets=(8,), max_batch=4,
                            backoff_base_s=0.001)
    real, calls = svc._dispatch, []

    def flaky(*a, **kw):
        calls.append(1)
        if len(calls) <= 2:
            raise RuntimeError("transient")
        return real(*a, **kw)

    svc._dispatch = flaky
    rid = svc.submit(AllocRequest(h2=np.ones(4)))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"
    assert svc.stats["dispatch_retries"] == 2
    assert svc.stats["dispatch_failures"] == 0


def test_dispatch_failure_exhausted_becomes_rejected():
    svc = AllocationService(buckets=(8,), max_batch=4,
                            dispatch_retries=1, backoff_base_s=0.001)

    def dead(*a, **kw):
        raise RuntimeError("chaos monkey ate the executable")

    svc._dispatch = dead
    rids = [svc.submit(r) for r in _reqs(3, seed=1)]
    res = {r.rid: r for r in svc.drain()}
    assert len(res) == 3                    # exactly once, never silent
    for rid in rids:
        assert res[rid].status == "rejected"
        assert "dispatch failed after 2 attempts" in res[rid].error
        assert "chaos monkey" in res[rid].error
    assert svc.stats["dispatch_failures"] == 1
    assert svc.stats["dispatch_retries"] == 1


# ---------------------------------------------------------------------------
# containment: breaker + watchdog + non-finite outputs
# ---------------------------------------------------------------------------
def test_breaker_full_cycle_open_halfopen_closed():
    svc = AllocationService(buckets=(8,), max_batch=1,
                            breaker_threshold=2, breaker_cooldown_s=0.05)
    real = svc._dispatch
    svc._dispatch = _poison(real)
    key = _key(svc, 8)
    ks = svc._key_str(key)
    # two consecutive poisoned batches trip the breaker OPEN
    for r in _reqs(2, seed=2, n_lo=3, n_hi=3):
        svc.submit(r)
    res = svc.drain()
    assert all(r.status == "rejected" for r in res)
    assert all("non-finite allocation" in r.error for r in res)
    assert svc._breakers[key].state == "open"
    assert (ks, "closed", "open") in svc.breaker_log
    # while open: fast-fail without dispatching
    d0 = svc.stats["dispatches"]
    rid = svc.submit(AllocRequest(h2=np.ones(3)))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "rejected"
    assert "circuit breaker open" in res[rid].error
    assert svc.stats["dispatches"] == d0    # no executable touched
    assert svc.stats["breaker_rejected"] == 1
    # cooldown elapses, executable healthy again → half-open probe closes
    svc._dispatch = real
    time.sleep(0.06)
    rid = svc.submit(AllocRequest(h2=np.ones(3)))
    assert svc._breakers[key].state in ("half_open", "closed")
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"
    assert svc._breakers[key].state == "closed"
    tail = [t for t in svc.breaker_log if t[0] == ks]
    assert tail == [(ks, "closed", "open"), (ks, "open", "half_open"),
                    (ks, "half_open", "closed")]


def test_breaker_reopens_on_bad_halfopen_probe():
    svc = AllocationService(buckets=(8,), max_batch=1,
                            breaker_threshold=1, breaker_cooldown_s=0.01)
    svc._dispatch = _poison(svc._dispatch)  # stays poisoned throughout
    svc.submit(AllocRequest(h2=np.ones(3)))
    svc.drain()
    key = _key(svc, 8)
    assert svc._breakers[key].state == "open"
    time.sleep(0.02)
    svc.submit(AllocRequest(h2=np.ones(3)))  # half-open probe, still bad
    svc.drain()
    assert svc._breakers[key].state == "open"
    ks = svc._key_str(key)
    assert (ks, "half_open", "open") in svc.breaker_log


def test_breaker_isolated_per_key():
    # poison only trips the (bucket, scheme) it ran on; other keys flow
    svc = AllocationService(buckets=(8, 16), max_batch=1,
                            breaker_threshold=1)
    real = svc._dispatch
    svc._dispatch = _poison(real)
    svc.submit(AllocRequest(h2=np.ones(3)))          # n8/proposed poisoned
    svc.drain()
    assert svc._breakers[_key(svc, 8)].state == "open"
    svc._dispatch = real
    rid = svc.submit(AllocRequest(h2=np.ones(12)))   # n16 unaffected
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"
    assert _key(svc, 16) not in svc._breakers or \
        svc._breakers[_key(svc, 16)].state == "closed"


def test_infeasible_batches_trip_breaker_only_when_opted_in():
    h2 = np.random.default_rng(3).uniform(0.2, 2.0, 5)
    bad_cfg = GameConfig(t_max=1e-9)        # infeasible beyond any relax
    # default: infeasibility is a valid answer, breaker stays closed
    svc = AllocationService(buckets=(8,), max_batch=1, breaker_threshold=2,
                            degraded_retry=False)
    for i in range(3):
        svc.submit(AllocRequest(h2=h2, cfg=bad_cfg, seed=i))
    res = svc.drain()
    assert all(r.status == "infeasible" for r in res)
    assert svc._breakers[_key(svc, 8)].state == "closed"
    # opted in: a known-feasible deployment treats it as executable
    # ill-health and trips after breaker_threshold consecutive batches
    svc = AllocationService(buckets=(8,), max_batch=1, breaker_threshold=2,
                            degraded_retry=False,
                            breaker_on_infeasible=True)
    for i in range(2):
        svc.submit(AllocRequest(h2=h2, cfg=bad_cfg, seed=i))
    svc.drain()
    assert svc._breakers[_key(svc, 8)].state == "open"
    rid = svc.submit(AllocRequest(h2=h2, cfg=bad_cfg))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "rejected"
    assert "circuit breaker open" in res[rid].error


def test_consecutive_fail_counter_resets_on_good_batch():
    svc = AllocationService(buckets=(8,), max_batch=1, breaker_threshold=3)
    real = svc._dispatch
    key = _key(svc, 8)
    for bad in (True, True, False, True, True):      # never 3 consecutive
        svc._dispatch = _poison(real) if bad else real
        svc.submit(AllocRequest(h2=np.ones(3)))
        svc.drain()
    assert svc._breakers[key].state == "closed"


def test_watchdog_counts_slow_batches():
    svc = AllocationService(buckets=(8,), max_batch=4, watchdog_s=1e-9)
    rid = svc.submit(AllocRequest(h2=np.ones(4)))
    res = {r.rid: r for r in svc.drain()}
    assert res[rid].status == "ok"          # slow ≠ wrong: result delivered
    assert svc.stats["watchdog_trips"] >= 1
    assert svc._breakers[_key(svc, 8)].fails >= 1   # but health noticed


def test_nonfinite_input_rejected_before_dispatch():
    svc = AllocationService(buckets=(8,), max_batch=4)
    rid = svc.submit(AllocRequest(h2=np.array([1.0, np.nan, 0.5])))
    rid2 = svc.submit(AllocRequest(h2=np.array([np.inf, 0.5])))
    res = {r.rid: r for r in svc.drain()}
    for r in (rid, rid2):
        assert res[r].status == "rejected"
        assert "non-finite channel gains" in res[r].error
    assert svc.stats["dispatches"] == 0


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------
def test_health_snapshot_shape():
    svc = AllocationService(buckets=(8,), max_batch=4, max_queue=16)
    for r in _reqs(6, seed=4, priority=1):
        svc.submit(r)
    h = svc.health()
    assert set(h) >= {"queued", "queued_total", "inflight", "breakers",
                      "breaker_transitions", "ewma_dispatch_s",
                      "counters", "latency_by_priority_ms"}
    svc.drain()
    h = svc.health()
    assert h["queued_total"] == 0 and h["inflight"] == 0
    assert h["counters"]["completed"] == 6
    lat = h["latency_by_priority_ms"]["1"]
    assert lat["n"] == 6 and 0 < lat["p50_ms"] <= lat["p99_ms"]
    assert h["ewma_dispatch_s"]                  # seeded by completions


# ---------------------------------------------------------------------------
# satellites: honest latency, sorted drain, bucket_for dedup, warmup
# ---------------------------------------------------------------------------
def test_reject_latency_is_honest():
    svc = AllocationService(buckets=(8,))
    svc.submit(AllocRequest(h2=np.ones(99)))         # oversized
    (r,) = svc.drain()
    assert r.status == "rejected" and r.latency_s > 0.0


def test_drain_sorted_by_rid():
    # mixed buckets + a shed + a reject: completion order scrambles, the
    # drain contract re-sorts
    svc = AllocationService(buckets=(8, 16), max_batch=2, max_queue=8)
    rids = []
    for i, n in enumerate((12, 3, 99, 12, 3, 11)):
        rids.append(svc.submit(AllocRequest(h2=np.ones(n), seed=i)))
    res = svc.drain()
    assert [r.rid for r in res] == sorted(rids)
    assert len(res) == len(rids)


def test_bucket_for_direct_call():
    svc = AllocationService(buckets=(8, 16, 64))
    assert svc.bucket_for(1) == 8
    assert svc.bucket_for(8) == 8
    assert svc.bucket_for(9) == 16
    assert svc.bucket_for(64) == 64
    with pytest.raises(ValueError, match="exceeds the largest bucket 64"):
        svc.bucket_for(65)


def test_oversize_submit_message_matches_bucket_for():
    svc = AllocationService(buckets=(8,))
    try:
        svc.bucket_for(9)
    except ValueError as e:
        msg = str(e)
    svc.submit(AllocRequest(h2=np.ones(9)))
    (r,) = svc.drain()
    assert r.error == msg                   # single source of truth


def test_warmup_nondefault_schemes_no_leak():
    svc = AllocationService(buckets=(8,), max_batch=4)
    svc.warmup(schemes=("oma", "random"))
    assert svc.drain() == []                # probes never surface
    assert svc.stats["completed"] == 0
    assert svc.stats.get("submitted", 0) == 0
    assert not svc._ewma                    # compile time never seeds EWMA
    # warmed pairs replay with zero retraces
    base = TRACE_COUNTS["serve_allocation"]
    rids = [svc.submit(AllocRequest(h2=np.ones(3), scheme=s, seed=i))
            for i, s in enumerate(("oma", "random", "oma", "random"))]
    res = {r.rid: r for r in svc.drain()}
    assert TRACE_COUNTS["serve_allocation"] == base
    assert all(res[r].status == "ok" for r in rids)


# ---------------------------------------------------------------------------
# baseline parity: the resilience layer must not perturb the happy path
# ---------------------------------------------------------------------------
def test_sla_mode_bit_identical_to_legacy_on_default_stream():
    reqs = _reqs(10, seed=7, n_lo=1, n_hi=8)
    legacy = AllocationService(buckets=(8,), max_batch=4)
    sla = AllocationService(buckets=(8,), max_batch=4, max_queue=1000)
    a = {r.rid: r for r in
         [legacy.submit(q) for q in reqs] and legacy.drain()}
    b = {r.rid: r for r in
         [sla.submit(q) for q in reqs] and sla.drain()}
    assert set(a) == set(b)
    for rid in a:
        assert a[rid].status == b[rid].status == "ok"
        np.testing.assert_array_equal(a[rid].p, b[rid].p)
        np.testing.assert_array_equal(a[rid].rates, b[rid].rates)
        assert a[rid].t_total == b[rid].t_total
        assert a[rid].degradation == b[rid].degradation == ()


def test_default_result_fields_on_happy_path():
    svc = AllocationService(buckets=(8,), max_batch=4)
    rid = svc.submit(AllocRequest(h2=np.ones(4)))
    res = {r.rid: r for r in svc.drain()}
    r = res[rid]
    assert (r.status, r.error, r.degradation) == ("ok", "", ())
    assert r.priority == 0 and r.deadline_s is None
