"""One-pass serving prefill (cache collection) vs reference paths.

Decoder-only archs: prefill-primed caches must agree with token-by-token
decode_step priming (ring rolls, SSM state carry, MoE dispatch included).
Enc-dec: validated against the full forward (step-priming cannot see the
encoder, so it is not a valid reference there)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import (decode_step, forward_logits, init_caches,
                          init_params, prefill_with_caches)


def _setup(arch, plen=12):
    cfg = smoke_variant(get_config(arch))
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=5.0)   # drop-free ⇒ exact equality
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, plen), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (2, plen // cfg.encoder_ratio, cfg.d_model))
    return cfg, params, toks, batch


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-2.7b", "zamba2-2.7b",
                                  "olmoe-1b-7b", "granite-3-8b"])
def test_prefill_matches_step_priming(arch):
    cfg, params, toks, batch = _setup(arch)
    total = toks.shape[1] + 8
    caches = init_caches(cfg, 2, total)
    logits_ref = None
    for t in range(toks.shape[1]):
        logits_ref, caches = decode_step(params, toks[:, t:t + 1], caches, cfg)
    logits_pf, caches_pf = prefill_with_caches(params, batch, cfg, total)
    assert float(jnp.max(jnp.abs(logits_pf - logits_ref))) < 1e-3
    nxt = jnp.argmax(logits_pf, -1)[:, None].astype(jnp.int32)
    l1, _ = decode_step(params, nxt, caches, cfg)
    l2, _ = decode_step(params, nxt, caches_pf, cfg)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-3


def test_prefill_enc_dec_matches_forward():
    cfg, params, toks, batch = _setup("seamless-m4t-large-v2")
    lf, _ = forward_logits(params, batch, cfg)
    lp, caches = prefill_with_caches(params, batch, cfg, 20)
    assert float(jnp.max(jnp.abs(lp - lf[:, -1]))) < 1e-4
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    l2, _ = decode_step(params, nxt, caches, cfg)
    assert bool(jnp.all(jnp.isfinite(l2)))


def test_prefill_windowed_ring_beyond_window():
    """Prompt longer than the sliding window: ring layout must still agree
    with step priming."""
    cfg, params, toks, batch = _setup("gemma2-9b", plen=24)
    # shrink the local window below the prompt length
    from repro.models.config import ATTN, BlockSpec
    cfg = cfg.replace(pattern=(BlockSpec(ATTN, 8), BlockSpec(ATTN, 0)))
    params = init_params(cfg, jax.random.PRNGKey(0))
    total = 32
    caches = init_caches(cfg, 2, total)
    logits_ref = None
    for t in range(24):
        logits_ref, caches = decode_step(params, toks[:, t:t + 1], caches, cfg)
    logits_pf, caches_pf = prefill_with_caches(params, batch, cfg, total)
    assert float(jnp.max(jnp.abs(logits_pf - logits_ref))) < 1e-3
