"""Batched baseline paths (ISSUE 2): OMA-FDMA / OMA-TDMA / random must be
drop-in vmapped versions of the per-instance allocations, and
``allocate_batched`` must accept every scheme the paper compares.

 (a) batched == per-instance parity (≤1e-5 relative) for each baseline;
 (b) Allocation leaves are all JAX arrays (python 0/True leaves would
     break stacking/vmap of baseline allocations);
 (c) ``allocate_batched`` covers proposed/ideal/wo_dt/oma/oma_tdma/random.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import sample_sic_channel_batch
from repro.core.fl_round import allocate_batched
from repro.core.stackelberg import (GameConfig, batched_oma_allocation,
                                    batched_oma_tdma_allocation,
                                    batched_random_allocation,
                                    oma_allocation, oma_tdma_allocation,
                                    random_allocation)

CFG = GameConfig()
N = 5
K = 8
REL = 1e-5


def _inputs(seed: int = 0):
    h2 = sample_sic_channel_batch(jax.random.PRNGKey(seed), K, N)
    d = 100.0 + 200.0 * jax.random.uniform(jax.random.PRNGKey(seed + 1),
                                           (K, N))
    vmax = 0.3 + 0.5 * jax.random.uniform(jax.random.PRNGKey(seed + 2),
                                          (K, N))
    return h2, d, vmax


def _assert_rows_match(ab, singles):
    for i, a1 in enumerate(singles):
        for name in ("energy", "t_total"):
            got, want = float(getattr(ab, name)[i]), float(getattr(a1, name))
            assert abs(got - want) / max(abs(want), 1e-12) < REL, (name, i)
        for name in ("p", "f", "v", "alpha", "rates"):
            assert jnp.allclose(getattr(ab, name)[i], getattr(a1, name),
                                rtol=REL, atol=0), (name, i)
        assert bool(ab.feasible[i]) == bool(a1.feasible), i


# ---------------------------------------------------------------------------
# (a) batched == per-instance
# ---------------------------------------------------------------------------
def test_batched_oma_matches_per_instance():
    h2, d, vmax = _inputs(10)
    ab = batched_oma_allocation(CFG, h2, d, vmax)
    assert ab.energy.shape == (K,)
    _assert_rows_match(ab, [oma_allocation(CFG, h2[i], d[i], vmax[i])
                            for i in range(K)])


def test_batched_oma_tdma_matches_per_instance():
    h2, d, vmax = _inputs(20)
    ab = batched_oma_tdma_allocation(CFG, h2, d, vmax)
    _assert_rows_match(ab, [oma_tdma_allocation(CFG, h2[i], d[i], vmax[i])
                            for i in range(K)])


def test_batched_random_matches_per_instance():
    """Row i uses key split(key, K)[i] — exactly reproducible per-instance."""
    h2, d, vmax = _inputs(30)
    key = jax.random.PRNGKey(99)
    ab = batched_random_allocation(CFG, key, h2, d, vmax)
    keys = jax.random.split(key, K)
    _assert_rows_match(ab, [random_allocation(CFG, keys[i], h2[i], d[i],
                                              vmax[i]) for i in range(K)])


def test_batched_baselines_broadcast_shared_inputs():
    """[N] data sizes / v_max broadcast across the K draws (fig9b usage)."""
    h2, _, _ = _inputs(40)
    d = jnp.full((N,), 200.0)
    vmax = jnp.full((N,), 0.5)
    ab = batched_oma_allocation(CFG, h2, d, vmax)
    a0 = oma_allocation(CFG, h2[0], d, vmax)
    rel = abs(float(ab.energy[0]) - float(a0.energy)) / float(a0.energy)
    assert rel < REL


def test_tdma_round_latency_is_sequential():
    """TDMA's round airtime is the SUM of the own-slot airtimes (the
    paper's "insufficient clients per round" mechanism), so its t_com
    dominates the FDMA variant's."""
    h2, d, vmax = _inputs(50)
    fdma = batched_oma_allocation(CFG, h2, d, vmax)
    tdma = batched_oma_tdma_allocation(CFG, h2, d, vmax)
    # every client in a TDMA row shares one round airtime
    assert bool(jnp.all(jnp.abs(tdma.t_com - tdma.t_com[:, :1]) < 1e-6))
    assert float(jnp.mean(tdma.t_com)) >= float(jnp.mean(fdma.t_com)) * 0.9


# ---------------------------------------------------------------------------
# (b) Allocation leaves are arrays — stacking/vmap safety
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda h2, d, vmax: random_allocation(CFG, jax.random.PRNGKey(0), h2, d,
                                          vmax),
    lambda h2, d, vmax: oma_allocation(CFG, h2, d, vmax),
    lambda h2, d, vmax: oma_tdma_allocation(CFG, h2, d, vmax),
], ids=["random", "oma", "oma_tdma"])
def test_baseline_allocations_stack(make):
    h2, d, vmax = _inputs(60)
    a0 = make(h2[0], d[0], vmax[0])
    a1 = make(h2[1], d[1], vmax[1])
    for leaf in jax.tree_util.tree_leaves(a0):
        assert isinstance(leaf, jax.Array), leaf   # no python 0/True leaves
    assert a0.iterations.dtype == jnp.int32
    assert a0.feasible.dtype == jnp.bool_
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), a0, a1)
    assert stacked.energy.shape == (2,)
    assert stacked.p.shape == (2, N)


# ---------------------------------------------------------------------------
# (c) allocate_batched accepts every scheme
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["proposed", "ideal", "wo_dt", "oma",
                                    "oma_tdma", "random"])
def test_allocate_batched_all_schemes(scheme):
    h2, d, vmax = _inputs(70)
    alloc = allocate_batched(scheme, CFG, h2, d, vmax,
                             key=jax.random.PRNGKey(3))
    assert alloc.energy.shape == (K,)
    assert alloc.p.shape == (K, N)
    assert bool(jnp.all(jnp.isfinite(alloc.energy)))
    assert bool(jnp.all(jnp.isfinite(alloc.t_total)))


def test_allocate_batched_unknown_scheme_raises():
    h2, d, vmax = _inputs(80)
    with pytest.raises(ValueError):
        allocate_batched("nope", CFG, h2, d, vmax)
