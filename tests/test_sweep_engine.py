"""Sweep-engine tests (ISSUE 2 tentpole): the whole benchmark grid —
config points × schemes × K realizations — must run with ZERO mid-sweep
recompiles, and the sweep axes must be pure batching (no numerical drift
vs the per-config batched path).

``TRACE_COUNTS`` counts traces of each jitted entry point: the Python body
of a jitted function only executes when XLA compiles a new specialization,
so a counter delta of 1 across a 10-point config sweep is a proof of
compile sharing.  Shapes here are deliberately unusual (N=6) so earlier
tests cannot have pre-warmed the cache and the delta-of-1 is really
observed, not vacuously 0.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from _multidevice import run_forced_devices

from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import (GameConfig, GamePhysics, TRACE_COUNTS,
                                    batched_equilibrium, sharding_layout,
                                    stack_physics, sweep_equilibrium,
                                    sweep_oma_allocation,
                                    sweep_random_allocation)

N = 6           # unusual client count → fresh jit cache entries in this file
REL = 1e-5


def _grid(n_points: int = 10):
    """fig9-style t_max × model_bits grid."""
    base = GameConfig()
    tms = (4.0, 6.0, 8.0, 10.0, 12.0)
    mbs = (0.5e6, 2.0e6)
    cfgs = [dataclasses.replace(base, t_max=tm, model_bits=mb)
            for mb in mbs for tm in tms]
    return cfgs[:n_points]


def _inputs(k: int, seed: int = 0):
    h2 = sample_sic_channel_batch(jax.random.PRNGKey(seed), k, N)
    d = jnp.full((N,), 200.0)
    vmax = jnp.full((N,), 0.5)
    return h2, d, vmax


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b) / jnp.maximum(jnp.abs(b), 1e-12)))


# ---------------------------------------------------------------------------
# recompile counting
# ---------------------------------------------------------------------------
def test_sweep_10pt_fig9_grid_compiles_once_at_k256():
    """The acceptance grid: 10 config points × K=256 draws — exactly one
    trace of the sweep engine, and a second sweep with DIFFERENT physics
    values (same shapes) reuses it."""
    cfgs = _grid(10)
    h2, d, vmax = _inputs(256)
    before = TRACE_COUNTS["sweep_equilibrium"]
    out = sweep_equilibrium(cfgs, h2, d, vmax)
    assert out.energy.shape == (10, 256)
    assert bool(jnp.all(jnp.isfinite(out.energy)))
    assert TRACE_COUNTS["sweep_equilibrium"] - before == 1

    shifted = [dataclasses.replace(c, t_max=c.t_max + 1.0,
                                   bandwidth=2e6) for c in cfgs]
    out2 = sweep_equilibrium(shifted, h2, d, vmax)
    assert bool(jnp.all(jnp.isfinite(out2.energy)))
    assert TRACE_COUNTS["sweep_equilibrium"] - before == 1, \
        "changing config VALUES must not recompile the sweep engine"


def test_batched_engine_shares_compile_across_configs():
    """Per-config ``batched_equilibrium`` calls across 10 distinct physics
    points hit ONE jit cache entry (physics are traced operands now)."""
    cfgs = _grid(10)
    h2, d, vmax = _inputs(4, seed=1)
    before = TRACE_COUNTS["batched_equilibrium"]
    for cfg in cfgs:
        out = batched_equilibrium(cfg, h2, d, vmax)
    assert bool(jnp.all(jnp.isfinite(out.energy)))
    assert TRACE_COUNTS["batched_equilibrium"] - before == 1


def test_baseline_sweeps_compile_once():
    """The OMA and random baseline sweep paths share compiles the same way."""
    cfgs = _grid(10)
    h2, d, vmax = _inputs(4, seed=2)
    before_oma = TRACE_COUNTS["sweep_oma_allocation"]
    before_rnd = TRACE_COUNTS["sweep_random_allocation"]
    oma = sweep_oma_allocation(cfgs, h2, d, vmax)
    rnd = sweep_random_allocation(cfgs, jax.random.PRNGKey(5), h2, d, vmax)
    oma2 = sweep_oma_allocation([dataclasses.replace(c, bandwidth=4e6)
                                 for c in cfgs], h2, d, vmax)
    assert oma.energy.shape == rnd.energy.shape == (10, 4)
    assert bool(jnp.all(jnp.isfinite(oma2.energy)))
    assert TRACE_COUNTS["sweep_oma_allocation"] - before_oma == 1
    assert TRACE_COUNTS["sweep_random_allocation"] - before_rnd == 1


# ---------------------------------------------------------------------------
# sweep axis is pure batching
# ---------------------------------------------------------------------------
def test_sweep_rows_match_batched_per_config():
    """Row c of the sweep == ``batched_equilibrium`` at config c (≤1e-5)."""
    cfgs = _grid(10)
    h2, d, vmax = _inputs(8, seed=3)
    sw = sweep_equilibrium(cfgs, h2, d, vmax)
    for c in (0, 4, 9):
        ref = batched_equilibrium(cfgs[c], h2, d, vmax)
        assert _rel(sw.energy[c], ref.energy) < REL, c
        assert _rel(sw.t_total[c], ref.t_total) < REL, c
        assert bool(jnp.all(sw.feasible[c] == ref.feasible)), c


def test_sweep_epsilon_axis_matches_batched():
    """ε riding the config axis (fig6's deviation sweep) == per-ε batched
    calls; Σα grows with ε (the server commits more DT frequency)."""
    cfg = GameConfig()
    h2, d, vmax = _inputs(8, seed=4)
    epsilons = (0.0, 0.3, 0.6)
    sw = sweep_equilibrium([cfg] * 3, h2, d, vmax,
                           epsilon=jnp.asarray(epsilons))
    shares = []
    for i, eps in enumerate(epsilons):
        ref = batched_equilibrium(cfg, h2, d, vmax, epsilon=eps)
        assert _rel(sw.energy[i], ref.energy) < REL, eps
        assert _rel(jnp.sum(sw.alpha[i], -1), jnp.sum(ref.alpha, -1)) < REL
        shares.append(float(jnp.mean(jnp.sum(sw.alpha[i], -1))))
    assert shares[0] < shares[1] < shares[2]


def test_stack_physics_layout():
    cfgs = _grid(4)
    phys = stack_physics(cfgs)
    assert isinstance(phys, GamePhysics)
    assert phys.t_max.shape == (4,)
    assert jnp.allclose(phys.t_max, jnp.asarray([c.t_max for c in cfgs]))
    leaves = jax.tree_util.tree_leaves(phys)
    assert all(leaf.shape == (4,) for leaf in leaves)


def test_stack_physics_rejects_mixed_inner():
    cfgs = [GameConfig(), GameConfig(dinkelbach_inner="kkt")]
    with pytest.raises(ValueError):
        stack_physics(cfgs)
    with pytest.raises(ValueError):
        sweep_equilibrium(cfgs, _inputs(2)[0], jnp.full((N,), 200.0),
                          jnp.full((N,), 0.5))


# ---------------------------------------------------------------------------
# device sharding of the K axis
# ---------------------------------------------------------------------------
def test_sharding_layout_single_device_fallback():
    """On this host the layout degrades to 1 shard and the sharded path is
    a no-op (the engine must not require multiple devices)."""
    assert sharding_layout(256) >= 1
    if len(jax.devices()) == 1:
        assert sharding_layout(256) == 1


_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import (GameConfig, batched_equilibrium,
                                    equilibrium, sharding_layout)
assert len(jax.devices()) == 4, jax.devices()
assert sharding_layout(8) == 4
cfg = GameConfig()
h2 = sample_sic_channel_batch(jax.random.PRNGKey(0), 8, 5)
d = jnp.full((5,), 200.0); vmax = jnp.full((5,), 0.5)
ab = batched_equilibrium(cfg, h2, d, vmax)
assert len(ab.energy.sharding.device_set) == 4, ab.energy.sharding
for i in (0, 3, 7):
    a1 = equilibrium(cfg, h2[i], d, vmax)
    rel = abs(float(ab.energy[i]) - float(a1.energy)) / abs(float(a1.energy))
    assert rel < 1e-5, (i, rel)
print("SHARDED_OK")
"""


def test_k_axis_shards_across_forced_host_devices():
    """With 4 forced host devices the K axis splits 4-ways and the sharded
    batched solve still matches per-instance solves (subprocess via
    tests/_multidevice.py: the device count is fixed at jax import)."""
    run_forced_devices(_SHARD_SCRIPT, marker="SHARDED_OK")
