"""End-to-end behaviour tests for the paper's system: a full DT-FL training
run reproduces the paper's headline claims on the synthetic proxies."""
import jax
import jax.numpy as jnp

from repro.core.channel import sample_positions
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.core.fl_round import FLConfig, FLState, run_training
from repro.core.reputation import (BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS,
                                   init_reputation)
from repro.core.stackelberg import GameConfig
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier


def _run(scheme="proposed", poison=0.0, weights=PROPOSED_WEIGHTS,
         use_roni=True, rounds=12, seed=21):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=16, cap=96,
                               poison_ratio=poison)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784, hidden=64)
    fl = FLConfig(n_selected=5, local_steps=30, server_steps=30, lr=0.1,
                  scheme=scheme, weights=weights, use_roni=use_roni)
    state = FLState(params=params, rep=init_reputation(16),
                    v_max=sample_v_max(ks[2], 16, DTConfig()),
                    distances=sample_positions(ks[3], 16), key=ks[4])
    state, hist = run_training(state, data, fl, GameConfig(), logits_fn,
                               rounds)
    return hist


def test_system_fl_converges():
    """The full pipeline (selection → Stackelberg → NOMA → DT split →
    RONI → aggregation) trains to high accuracy."""
    hist = _run()
    assert max(h["val_acc"] for h in hist[-3:]) > 0.85
    assert all(h["energy"] > 0 and h["latency"] > 0 for h in hist)


def test_system_poisoning_defense():
    """Paper's central claim: reputation+RONI keeps accuracy high under 30%
    poisoners, and beats the PI-blind benchmark selection."""
    prop = _run(poison=0.3)
    bench = _run(poison=0.3, weights=BENCHMARK_WEIGHTS, use_roni=False)
    p = max(h["val_acc"] for h in prop[-3:])
    b = max(h["val_acc"] for h in bench[-3:])
    assert p > 0.8
    assert p >= b - 0.02


def test_system_stackelberg_cheaper_than_random():
    """Paper Fig. 9: the equilibrium allocation costs less than random."""
    prop = _run(rounds=6)
    rand = _run(rounds=6, scheme="random")
    cp = sum(h["total_cost"] for h in prop) / len(prop)
    cr = sum(h["total_cost"] for h in rand) / len(rand)
    assert cp < cr
