"""Stackelberg game + Dinkelbach unit & property tests (paper §IV–V)."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline: seeded example replay (tests/_prop.py)
    from _prop import given, settings, strategies as st

from repro.core.channel import noise_power, sample_channel_gains, sample_positions
from repro.core.dinkelbach import dinkelbach_power, successive_power
from repro.core.stackelberg import (GameConfig, equilibrium, follower_alpha,
                                    leader_f, local_compute_energy,
                                    local_compute_latency)

CFG = GameConfig()


def _channels(seed, n=5):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    h2 = sample_channel_gains(k2, sample_positions(k1, n))
    return jnp.sort(h2)[::-1]


# ---------------------------------------------------------------------------
# Theorem 1 (follower)
# ---------------------------------------------------------------------------
def test_follower_equal_finish_times():
    """Theorem 1: optimal alpha equalizes DT compute times."""
    c, f_s = 1e7, 100e9
    d_hat = jnp.array([50., 120., 300., 10., 77.])
    for t_total in (0.001, 0.01, 1.0):
        alpha, t_s = follower_alpha(c, d_hat, t_total, f_s)
        t_n = c * d_hat / (alpha * f_s)
        assert jnp.allclose(t_n, t_n[0], rtol=1e-5), t_n
        assert float(jnp.sum(alpha)) <= 1.0 + 1e-6


def test_follower_case1_no_waste():
    """Server slack ⇒ t_S = t_total exactly (Eq. 26), Σα < 1."""
    alpha, t_s = follower_alpha(1e7, jnp.array([10., 20.]), 1.0, 100e9)
    assert abs(float(t_s) - 1.0) < 1e-9
    t_n = 1e7 * jnp.array([10., 20.]) / (alpha * 100e9)
    assert jnp.allclose(t_n, 1.0)
    assert float(jnp.sum(alpha)) < 1.0


def test_follower_case2_saturated():
    """Overload ⇒ Σα = 1 (Eq. 29) and t_S > t_total."""
    d_hat = jnp.array([4000., 8000.])
    alpha, t_s = follower_alpha(1e7, d_hat, 0.5, 100e9)
    assert abs(float(jnp.sum(alpha)) - 1.0) < 1e-6
    assert float(t_s) > 0.5


@given(st.lists(st.floats(1.0, 1e4), min_size=2, max_size=8),
       st.floats(1e-3, 10.0))
@settings(max_examples=30, deadline=None)
def test_follower_alpha_properties(d_hat_list, t_total):
    """Property: α ≥ 0, Σα ≤ 1, equal finish times — for any loads."""
    d_hat = jnp.array(d_hat_list)
    alpha, t_s = follower_alpha(1e7, d_hat, t_total, 100e9)
    assert bool(jnp.all(alpha >= 0))
    assert float(jnp.sum(alpha)) <= 1.0 + 1e-5
    t_n = 1e7 * d_hat / (jnp.maximum(alpha, 1e-12) * 100e9)
    assert float(jnp.max(t_n) - jnp.min(t_n)) < 1e-4 * float(jnp.max(t_n)) + 1e-9


# ---------------------------------------------------------------------------
# leader closed forms
# ---------------------------------------------------------------------------
def test_leader_f_runs_to_deadline():
    """f̃ hits the latency budget exactly when above f_min (§V-B-2)."""
    c, v, d = 1e7, 0.4, 1000.0
    a_n = 2.0
    f = leader_f(c, v, d, a_n, 1e9, 10e9)
    t = local_compute_latency(c, v, d, f)
    assert abs(float(t) - a_n) < 1e-6 or float(f) in (1e9, 10e9)


def test_leader_f_floor():
    f = leader_f(1e7, 0.9, 10.0, 5.0, 1e9, 10e9)
    assert float(f) == pytest.approx(1e9)   # f̃ tiny ⇒ floor at f_min


def test_energy_monotone_in_v():
    """Eq. (6): larger DT mapping ratio ⇒ lower local-compute energy —
    the reason v* = v_max."""
    es = [float(local_compute_energy(1e7, v, 500.0, 2e9)) for v in
          (0.0, 0.3, 0.6, 0.9)]
    assert es == sorted(es, reverse=True)


# ---------------------------------------------------------------------------
# Dinkelbach (Algorithm 1)
# ---------------------------------------------------------------------------
def test_dinkelbach_converges_and_is_optimal():
    """q* matches a dense grid search of R/U (global optimum)."""
    f_eff, d, g, bw = 1e13, 1e6, 5.0, 1e6
    p, q, it = dinkelbach_power(d, g, f_eff, bw, 0.01, 0.1)
    grid = jnp.linspace(0.01, 0.1, 20001)
    rate = bw * jnp.log2(1 + grid * f_eff)
    feas = rate >= d / g
    ratio = jnp.where(feas, rate / (grid * d), -jnp.inf)
    q_grid = float(jnp.max(ratio))
    assert float(q) == pytest.approx(q_grid, rel=1e-3)
    assert int(it) <= 20


def test_dinkelbach_kkt_matches_projected():
    """Paper-faithful subgradient inner solver ≡ projected closed form."""
    f_eff, d, g, bw = 3e12, 1e6, 4.0, 1e6
    p1, q1, _ = dinkelbach_power(d, g, f_eff, bw, 0.01, 0.1, inner="projected")
    p2, q2, _ = dinkelbach_power(d, g, f_eff, bw, 0.01, 0.1, inner="kkt")
    assert float(p1) == pytest.approx(float(p2), rel=1e-2)
    assert float(q1) == pytest.approx(float(q2), rel=1e-2)


@given(st.floats(1e11, 1e14), st.floats(0.5, 9.0))
@settings(max_examples=25, deadline=None)
def test_dinkelbach_respects_box(f_eff, g):
    p, q, _ = dinkelbach_power(1e6, g, f_eff, 1e6, 0.01, 0.1)
    assert 0.01 - 1e-9 <= float(p) <= 0.1 + 1e-9
    assert float(q) > 0


def test_successive_order_q_monotone_with_decoding_order():
    """Fig. 4 structure: earlier-decoded clients see interference ⇒ their
    rate-per-energy optimum q is (weakly) below the interference-free tail
    client with comparable gain."""
    h2 = jnp.array([1e-11, 1e-11, 1e-11])   # equal gains isolate SIC position
    p, q = successive_power(h2, 1e6, 5.0, 1e6, noise_power(), 0.01, 0.1)
    assert float(q[0]) <= float(q[-1]) + 1e-6


# ---------------------------------------------------------------------------
# equilibrium (Algorithm 2)
# ---------------------------------------------------------------------------
def test_equilibrium_feasible_and_stable():
    h2s = _channels(0)
    d = jnp.array([100., 150., 200., 120., 80.])
    vmax = jnp.full((5,), 0.5)
    alloc = equilibrium(CFG, h2s, d, vmax)
    assert bool(jnp.all(alloc.v == vmax))                      # v* = v_max
    assert bool(jnp.all((alloc.p >= CFG.p_min - 1e-9)
                        & (alloc.p <= CFG.p_max + 1e-9)))
    assert bool(jnp.all((alloc.f >= CFG.f_min - 1) & (alloc.f <= CFG.f_max + 1)))
    assert float(jnp.sum(alloc.alpha)) <= 1.0 + 1e-6
    assert alloc.iterations <= 20


def test_equilibrium_leader_optimality_vs_perturbation():
    """Stackelberg condition (21): perturbing the leader's strategy (with the
    follower's best response fixed) cannot reduce total energy."""
    h2s = _channels(1)
    d = jnp.array([100., 150., 200., 120., 80.])
    vmax = jnp.full((5,), 0.5)
    alloc = equilibrium(CFG, h2s, d, vmax)
    from repro.core.stackelberg import round_metrics
    _, t_cmp, t_com, e_cmp, e_com = round_metrics(CFG, d, alloc.v, alloc.f,
                                                  alloc.p, h2s)
    e_star = float(jnp.sum(e_cmp + e_com))
    key = jax.random.PRNGKey(0)
    feas_viol_allowed = float(jnp.max(t_cmp + t_com)) + 1e-3
    for i in range(20):
        kk = jax.random.fold_in(key, i)
        dp = jax.random.uniform(kk, (5,), minval=-.02, maxval=.02)
        p2 = jnp.clip(alloc.p + dp, CFG.p_min, CFG.p_max)
        f2 = jnp.clip(alloc.f * (1 + jax.random.uniform(
            jax.random.fold_in(kk, 1), (5,), minval=0.0, maxval=0.3)),
            CFG.f_min, CFG.f_max)
        _, t_cmp2, t_com2, e_cmp2, e_com2 = round_metrics(CFG, d, alloc.v, f2,
                                                          p2, h2s)
        if float(jnp.max(t_cmp2 + t_com2)) > min(CFG.t_max, feas_viol_allowed):
            continue   # infeasible perturbation
        e2 = float(jnp.sum(e_cmp2 + e_com2))
        assert e2 >= e_star - 0.05 * abs(e_star), (i, e2, e_star)


def test_wo_dt_consumes_more_energy():
    """DT mapping strictly reduces client energy (the paper's premise)."""
    from repro.core.stackelberg import wo_dt_allocation
    h2s = _channels(2)
    d = jnp.array([300., 350., 400., 320., 280.])
    vmax = jnp.full((5,), 0.6)
    a_dt = equilibrium(CFG, h2s, d, vmax)
    a_wo = wo_dt_allocation(CFG, h2s, d)
    assert float(a_dt.energy) < float(a_wo.energy)
