"""Large-N SIC power engine (ISSUE 5 tentpole): the blocked Jacobi
fixed-point solver, the Pallas suffix-scan kernel, and the ``sic_mode``
static key threaded through every engine tier.

Parity ladder: eager host loop (most literal §V-B-3 reading) == sequential
reverse scan == blocked fixed point ≤1e-5 on (p, q), for every tested N —
including N=1 (no interference at all) and a non-power-of-two N=257 that
exercises the kernel's padded tail block.  Mode ``blocked_interpret``
additionally routes the suffix scan through the Pallas kernel in CPU
interpret mode, validating the kernel body itself on every sweep.

Plus the ISSUE's satellite suites: ``dinkelbach_power`` invariants as
property tests (box membership, rate floor, inner-solver agreement), the
host-loop Fig. 4 trace path vs the jitted ``while_loop`` path, trace-count
proofs for the new entry points, and the forced-4-device sharding check
with the blocked solver.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # offline: seeded example replay (tests/_prop.py)
    from _prop import given, settings, strategies as st

from _multidevice import run_forced_devices

from repro.core.channel import noise_power, sample_sic_channel_batch
from repro.core.dinkelbach import _p_floor, dinkelbach_power, successive_power
from repro.core.sic import (SIC_MODES, successive_power_any,
                            successive_power_blocked, successive_power_eager,
                            suffix_interference)
from repro.core.stackelberg import (GameConfig, TRACE_COUNTS,
                                    batched_equilibrium, equilibrium,
                                    stack_physics, sweep_equilibrium)
from repro.kernels.ops import sic_suffix_sum
from repro.kernels.ref import sic_suffix_ref
from repro.kernels.sic_suffix import sic_suffix_pallas

BW = 1e6
SIGMA2 = noise_power()
P_MIN, P_MAX = 0.01, 0.1
REL = 1e-5


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b) / jnp.maximum(jnp.abs(b), 1e-12)))


def _sic_inputs(n: int, seed: int = 0):
    h2 = sample_sic_channel_batch(jax.random.PRNGKey(seed), 1, n)[0]
    g = 0.5 + 5.0 * jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,))
    return h2, g


# ---------------------------------------------------------------------------
# cross-mode parity: blocked == sequential == eager
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("inner", ["projected", "kkt"])
@pytest.mark.parametrize("n", [1, 2, 5, 64, 257])
def test_blocked_matches_sequential(n, inner):
    """The Jacobi fixed point IS the sequential SIC solution (≤1e-5 on p
    and q) — incl. the N=1 no-interference edge and a non-power-of-two N."""
    h2, g = _sic_inputs(n, seed=n)
    p_s, q_s = successive_power(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX,
                                inner=inner)
    p_b, q_b = successive_power_blocked(h2, 1e6, g, BW, SIGMA2, P_MIN,
                                        P_MAX, inner=inner)
    assert _rel(p_b, p_s) < REL, (n, inner)
    assert _rel(q_b, q_s) < REL, (n, inner)


@pytest.mark.parametrize("n", [1, 2, 5, 64, 257])
def test_blocked_interpret_kernel_path_matches(n):
    """suffix_mode="interpret" runs the Pallas kernel (CPU interpreter)
    inside every sweep — same fixed point as the jnp suffix path."""
    h2, g = _sic_inputs(n, seed=100 + n)
    p_s, q_s = successive_power(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX)
    p_k, q_k = successive_power_blocked(h2, 1e6, g, BW, SIGMA2, P_MIN,
                                        P_MAX, suffix_mode="interpret")
    assert _rel(p_k, p_s) < REL, n
    assert _rel(q_k, q_s) < REL, n


@pytest.mark.parametrize("n", [1, 2, 5])
def test_eager_host_reference_matches(n):
    """The host-side python loop (the most literal reading of §V-B-3)
    agrees with both traced engines."""
    h2, g = _sic_inputs(n, seed=200 + n)
    p_e, q_e = successive_power_eager(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX)
    p_s, q_s = successive_power(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX)
    p_b, q_b = successive_power_blocked(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX)
    assert _rel(p_s, p_e) < REL and _rel(q_s, q_e) < REL
    assert _rel(p_b, p_e) < REL and _rel(q_b, q_e) < REL


def test_blocked_sweep_backstop_is_exact():
    """The N-sweep backstop itself: with the stationarity early-exit
    DISABLED the loop runs all N Jacobi sweeps, and the triangular
    dependency (p_n ← {p_j : j>n}) makes the result the sequential
    solution up to f32 roundoff — the guarantee the while-bound rests on."""
    n = 33
    h2, g = _sic_inputs(n, seed=300)
    p_s, q_s = successive_power(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX)
    p_b, q_b, sweeps = successive_power_blocked(
        h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX, return_sweeps=True,
        early_exit=False)
    assert int(sweeps) == n       # every sweep actually ran
    assert _rel(p_b, p_s) < REL
    assert _rel(q_b, q_s) < REL


def test_blocked_converges_in_few_sweeps():
    """The contraction is strong: the while_loop exits far before the
    N-sweep backstop (the whole point of the blocked engine at large N)."""
    n = 257
    h2, g = _sic_inputs(n, seed=400)
    _p, _q, sweeps = successive_power_blocked(
        h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX, return_sweeps=True)
    assert int(sweeps) <= 16, f"expected geometric convergence, got {sweeps}"


def test_successive_power_any_dispatch_and_validation():
    h2, g = _sic_inputs(5, seed=500)
    p_s, _ = successive_power_any(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX,
                                  sic_mode="sequential")
    p_b, _ = successive_power_any(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX,
                                  sic_mode="blocked")
    assert _rel(p_b, p_s) < REL
    with pytest.raises(ValueError):
        successive_power_any(h2, 1e6, g, BW, SIGMA2, P_MIN, P_MAX,
                             sic_mode="bogus")
    assert "sequential" in SIC_MODES and "blocked" in SIC_MODES


# ---------------------------------------------------------------------------
# suffix kernel: ref / interpret agreement on CPU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,block", [
    ((1, 1), 128),          # single element, whole-pad block
    ((2, 5), 4),            # tail padding
    ((3, 64), 32),          # exact multiple
    ((2, 257), 128),        # non-power-of-two tail
    ((1, 512), 128),        # multi-block carry chain
])
def test_suffix_kernel_matches_ref(shape, block):
    w = jax.random.uniform(jax.random.PRNGKey(shape[1]), shape) * 1e-3
    ref = sic_suffix_ref(w)
    out = sic_suffix_pallas(w, block=block, interpret=True)
    assert out.shape == ref.shape
    # matmul vs cumsum accumulation order: f32 roundoff, scaled by the sum
    scale = float(jnp.max(jnp.abs(ref))) + 1e-12
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5 * scale


def test_suffix_kernel_under_vmap_and_modes():
    """The ops.py mode switch: ``ref`` == ``interpret`` (≤f32 roundoff),
    and the kernel batches under vmap (the batched-engine context)."""
    w = jax.random.uniform(jax.random.PRNGKey(9), (4, 130)) * 1e-2
    ref = sic_suffix_sum(w, mode="ref")
    tol = 1e-5 * float(jnp.max(jnp.abs(ref)))
    out = sic_suffix_sum(w, block=64, mode="interpret")
    assert float(jnp.max(jnp.abs(out - ref))) < tol
    per_row = jax.vmap(lambda x: sic_suffix_sum(x, block=64,
                                                mode="interpret"))(w)
    assert float(jnp.max(jnp.abs(per_row - ref))) < tol
    assert float(jnp.max(jnp.abs(suffix_interference(w, mode="interpret",
                                                     block=64) - ref))) < tol
    # exclusive: last element sees zero interference
    assert float(jnp.max(jnp.abs(ref[:, -1]))) == 0.0


# ---------------------------------------------------------------------------
# sic_mode through every engine tier
# ---------------------------------------------------------------------------
def test_equilibrium_tiers_blocked_parity():
    """single / batched / sweep equilibria with sic_mode="blocked" match
    the sequential engine ≤1e-5 on the full Allocation."""
    n, k = 11, 6
    cfg_s, cfg_b = GameConfig(), GameConfig(sic_mode="blocked")
    h2 = sample_sic_channel_batch(jax.random.PRNGKey(3), k, n)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    a_s = batched_equilibrium(cfg_s, h2, d, vmax)
    a_b = batched_equilibrium(cfg_b, h2, d, vmax)
    for field in ("p", "f", "energy", "t_total", "alpha"):
        assert _rel(getattr(a_b, field), getattr(a_s, field)) < REL, field
    one_s = equilibrium(cfg_s, h2[0], d, vmax)
    one_b = equilibrium(cfg_b, h2[0], d, vmax)
    assert _rel(one_b.energy, one_s.energy) < REL
    cfgs_b = [dataclasses.replace(cfg_b, t_max=t) for t in (8.0, 10.0)]
    cfgs_s = [dataclasses.replace(cfg_s, t_max=t) for t in (8.0, 10.0)]
    sw_b = sweep_equilibrium(cfgs_b, h2, d, vmax)
    sw_s = sweep_equilibrium(cfgs_s, h2, d, vmax)
    assert _rel(sw_b.energy, sw_s.energy) < REL
    assert sw_b.energy.shape == (2, k)


def test_stack_physics_rejects_mixed_sic_mode():
    cfgs = [GameConfig(), GameConfig(sic_mode="blocked")]
    with pytest.raises(ValueError):
        stack_physics(cfgs)


# ---------------------------------------------------------------------------
# trace counting: the blocked paths compile once per sweep grid
# ---------------------------------------------------------------------------
def test_blocked_sweep_traces_each_entry_once():
    """A fig9-style grid with sic_mode="blocked" traces the sweep engine
    and the blocked SIC solver exactly once, and re-dispatching with
    different physics VALUES retraces neither.  N=9 is unique to this test
    so the jit cache is genuinely cold."""
    n, k = 9, 4
    base = GameConfig(sic_mode="blocked")
    cfgs = [dataclasses.replace(base, t_max=tm, model_bits=mb)
            for mb in (0.5e6, 2.0e6) for tm in (6.0, 8.0, 10.0)]
    h2 = sample_sic_channel_batch(jax.random.PRNGKey(4), k, n)
    d = jnp.full((n,), 200.0)
    vmax = jnp.full((n,), 0.5)
    before_sweep = TRACE_COUNTS["sweep_equilibrium"]
    before_blocked = TRACE_COUNTS["successive_power_blocked"]
    out = sweep_equilibrium(cfgs, h2, d, vmax)
    assert out.energy.shape == (6, k)
    assert bool(jnp.all(jnp.isfinite(out.energy)))
    assert TRACE_COUNTS["sweep_equilibrium"] - before_sweep == 1
    assert TRACE_COUNTS["successive_power_blocked"] - before_blocked == 1
    shifted = [dataclasses.replace(c, t_max=c.t_max + 1.0) for c in cfgs]
    sweep_equilibrium(shifted, h2, d, vmax)
    assert TRACE_COUNTS["sweep_equilibrium"] - before_sweep == 1, \
        "changing config VALUES must not recompile the blocked sweep"


_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core.channel import sample_sic_channel_batch
from repro.core.stackelberg import (GameConfig, batched_equilibrium,
                                    equilibrium, sharding_layout)
assert len(jax.devices()) == 4, jax.devices()
cfg = GameConfig(sic_mode="blocked")
h2 = sample_sic_channel_batch(jax.random.PRNGKey(0), 8, 16)
d = jnp.full((16,), 200.0); vmax = jnp.full((16,), 0.5)
ab = batched_equilibrium(cfg, h2, d, vmax)
assert len(ab.energy.sharding.device_set) == 4, ab.energy.sharding
for i in (0, 7):
    a1 = equilibrium(cfg, h2[i], d, vmax)
    rel = abs(float(ab.energy[i]) - float(a1.energy)) / abs(float(a1.energy))
    assert rel < 1e-5, (i, rel)
print("SHARDED_BLOCKED_OK")
"""


def test_k_axis_shards_with_blocked_solver():
    """The K axis still device-shards when the blocked SIC engine is the
    solver core (subprocess: device count is fixed at jax import)."""
    run_forced_devices(_SHARD_SCRIPT, marker="SHARDED_BLOCKED_OK")


# ---------------------------------------------------------------------------
# dinkelbach_power invariants (property-based, ISSUE 5 satellite)
# ---------------------------------------------------------------------------
@given(st.floats(1e5, 2e6), st.floats(0.5, 9.0), st.floats(-4.0, -1.0),
       st.floats(-15.0, -13.0))
@settings(max_examples=25, deadline=None)
def test_dinkelbach_box_invariant(d, g, log_h2, log_s2):
    """p* always lies in [min(p_floor, p_max), p_max] — the Eq. 43 box with
    the rate-floor lower bound, whatever the (d, g, h², σ²) draw."""
    f_eff = (10.0 ** log_h2) / (10.0 ** log_s2)
    p, q, _ = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX)
    lo = min(float(_p_floor(d, g, f_eff, BW, P_MIN)), P_MAX)
    assert lo - 1e-9 <= float(p) <= P_MAX + 1e-9
    assert float(q) > 0.0


@given(st.floats(1e5, 2e6), st.floats(0.5, 9.0), st.floats(-4.0, -1.0),
       st.floats(-15.0, -13.0))
@settings(max_examples=25, deadline=None)
def test_dinkelbach_rate_floor_when_admissible(d, g, log_h2, log_s2):
    """Whenever the box admits the rate floor (p_floor ≤ p_max), the
    optimum satisfies R(p*) ≥ d/G — the (35b)/(40) deadline constraint."""
    f_eff = (10.0 ** log_h2) / (10.0 ** log_s2)
    floor_p = float(_p_floor(d, g, f_eff, BW, P_MIN))
    p, _q, _ = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX)
    if floor_p <= P_MAX:
        rate = BW * jnp.log2(1.0 + p * f_eff)
        assert float(rate) >= (d / g) * (1.0 - 1e-5)


@given(st.floats(1e5, 2e6), st.floats(0.5, 9.0), st.floats(-4.0, -1.0),
       st.floats(-15.0, -13.0))
@settings(max_examples=20, deadline=None)
def test_dinkelbach_q_inner_invariant(d, g, log_h2, log_s2):
    """q* is a property of the PROBLEM, not the inner solver: projected
    closed form vs paper-faithful KKT subgradient agree ≤1e-4."""
    f_eff = (10.0 ** log_h2) / (10.0 ** log_s2)
    _p1, q1, _ = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX,
                                  inner="projected")
    _p2, q2, _ = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX,
                                  inner="kkt")
    assert abs(float(q1) - float(q2)) <= 1e-4 * max(abs(float(q1)), 1e-12)


# ---------------------------------------------------------------------------
# Fig. 4 trace path == jitted while_loop path (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
@given(st.floats(1e5, 2e6), st.floats(0.5, 9.0), st.floats(9.0, 14.0))
@settings(max_examples=15, deadline=None)
def test_dinkelbach_trace_path_matches_while_loop(d, g, log_f):
    """``return_trace=True`` (the host loop Fig. 4 plots) and the jitted
    ``lax.while_loop`` path are the same algorithm — same (p*, q*), same
    iteration count, and the trace ends at q*."""
    f_eff = 10.0 ** log_f
    p_w, q_w, it_w = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX)
    p_t, q_t, it_t, trace = dinkelbach_power(d, g, f_eff, BW, P_MIN, P_MAX,
                                             return_trace=True)
    assert abs(float(p_w) - float(p_t)) <= 1e-6 * max(float(p_w), 1e-12)
    assert abs(float(q_w) - float(q_t)) <= 1e-6 * max(abs(float(q_w)), 1e-12)
    assert int(it_w) == int(it_t)
    assert trace[0] == 0.0 and len(trace) == it_t + 1
    assert abs(trace[-1] - float(q_t)) <= 1e-6 * max(abs(float(q_t)), 1e-12)


# ---------------------------------------------------------------------------
# padded (masked) tails — the ragged-N serving contract (ISSUE 6)
# ---------------------------------------------------------------------------
class TestPaddedTail:
    """The allocation service pads variable-N cells with ZERO channel gains
    at the SIC-order tail; both power engines and the suffix kernel must be
    invariant to such tails (see the contract in repro/core/sic.py)."""

    @pytest.mark.parametrize("pad", [1, 3, 11])
    def test_sequential_zero_tail_parity(self, pad):
        h2, g = _sic_inputs(5, seed=2)
        p, q = successive_power(h2, 200.0, g, BW, SIGMA2, P_MIN, P_MAX)
        h2p = jnp.concatenate([h2, jnp.zeros(pad)])
        gp = jnp.concatenate([g, jnp.zeros(pad)])
        pp, qp = successive_power(h2p, 200.0, gp, BW, SIGMA2, P_MIN, P_MAX)
        assert _rel(pp[:5], p) <= REL and _rel(qp[:5], q) <= REL
        # padded lanes themselves stay finite: F=0 -> rate-floor power hits
        # +inf and clips to the box top, q collapses to 0
        assert bool(jnp.all(pp[5:] == P_MAX)) and bool(jnp.all(qp[5:] == 0.0))

    @pytest.mark.parametrize("suffix_mode", ["ref", "interpret"])
    def test_blocked_zero_tail_parity(self, suffix_mode):
        h2, g = _sic_inputs(6, seed=4)
        p, q = successive_power_blocked(h2, 200.0, g, BW, SIGMA2, P_MIN,
                                        P_MAX, suffix_mode=suffix_mode)
        h2p = jnp.concatenate([h2, jnp.zeros(10)])
        gp = jnp.concatenate([g, jnp.zeros(10)])
        pp, qp = successive_power_blocked(h2p, 200.0, gp, BW, SIGMA2, P_MIN,
                                          P_MAX, suffix_mode=suffix_mode)
        assert _rel(pp[:6], p) <= REL and _rel(qp[:6], q) <= REL
        assert bool(jnp.all(jnp.isfinite(pp))) and \
            bool(jnp.all(jnp.isfinite(qp)))

    @pytest.mark.parametrize("mode", ["ref", "interpret"])
    def test_suffix_scan_zero_tail_parity(self, mode):
        """A zero tail must not perturb any real element's suffix sum.
        The Pallas kernel walks blocks sequentially with a scalar carry, so
        zero blocks add exactly 0.0 (bitwise); the jnp oracle's cumsum is
        an XLA associative tree whose shape changes with padding, so it
        gets the repo's 1e-5 relative budget instead."""
        w = jax.random.uniform(jax.random.PRNGKey(3), (4, 37))
        wp = jnp.pad(w, ((0, 0), (0, 91)))         # 37 -> 128 (block edge)
        s = sic_suffix_sum(w, mode=mode, block=32)
        sp = sic_suffix_sum(wp, mode=mode, block=32)
        if mode == "interpret":
            assert bool(jnp.all(sp[:, :37] == s))   # bitwise, not approx
        else:
            assert _rel(sp[:, :37], s) <= REL
        assert bool(jnp.all(sp[:, 37:] == 0.0))

    def test_n1_both_engines(self):
        """N=1 — the service's smallest-bucket edge: no later-decoded
        clients, interference 0, both engines finite and equal."""
        h2, g = _sic_inputs(1, seed=8)
        p_s, q_s = successive_power(h2, 200.0, g, BW, SIGMA2, P_MIN, P_MAX)
        p_b, q_b = successive_power_blocked(h2, 200.0, g, BW, SIGMA2,
                                            P_MIN, P_MAX)
        assert _rel(p_b, p_s) <= REL and _rel(q_b, q_s) <= REL
        assert bool(jnp.all(jnp.isfinite(p_s))) and \
            bool(jnp.all(jnp.isfinite(q_s)))

    def test_all_zero_gains_finite(self):
        """Degenerate all-masked lane set (a dummy batch-padding row):
        every power pins at the box top, q at 0, nothing NaN."""
        z = jnp.zeros(8)
        for fn in (successive_power,
                   lambda *a, **k: successive_power_blocked(*a, **k)):
            p, q = fn(z, 200.0, jnp.zeros(8), BW, SIGMA2, P_MIN, P_MAX)
            assert bool(jnp.all(p == P_MAX)) and bool(jnp.all(q == 0.0))
