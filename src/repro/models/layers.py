"""Shared neural-net layers: norms, RoPE, MLPs, embedding, softcap."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import dense_init, embed_init, ones_init, split_tree


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(key, d, dtype):
    return {"scale": ones_init(key, (d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# softcap (gemma2)
# ---------------------------------------------------------------------------
def softcap(x, cap: float):
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    ang = ang[..., None, :]                             # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int = 0):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = cfg.storage_dtype
    ks = split_tree(key, 3)
    p = {"w_in": dense_init(ks[0], (d, f), dt),
         "w_out": dense_init(ks[1], (f, d), dt)}
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, f), dt)
    return p


def _act(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "squared_relu":          # nemotron-4
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp(p, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.activation == "swiglu":
        h = _act(x @ p["w_gate"].astype(dt), "gelu") * (x @ p["w_in"].astype(dt))
    else:
        h = _act(x @ p["w_in"].astype(dt), cfg.activation)
    return h @ p["w_out"].astype(dt)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    dt = cfg.storage_dtype
    ks = split_tree(key, 2)
    v = cfg.padded_vocab_size
    p = {"embedding": embed_init(ks[0], (v, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, v), dt)
    return p


def embed(p, tokens, cfg: ModelConfig):
    # one-hot matmul embeds cleanly under SPMD vocab sharding (no gather).
    e = jnp.take(p["embedding"].astype(cfg.compute_dtype), tokens, axis=0)
    return e * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.compute_dtype))


def unembed(p, x, cfg: ModelConfig):
    dt = cfg.compute_dtype
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].astype(dt).T
    else:
        logits = x @ p["unembed"].astype(dt)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab_size != cfg.vocab_size:   # mask padded vocab rows
        pad_mask = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
