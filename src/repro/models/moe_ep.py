"""Expert-parallel MoE dispatch via shard_map + all-to-all (beyond-paper
§Perf path).

The baseline (`moe.py`) dispatches with gather/scatter under plain SPMD,
leaving XLA to reshard the [E, C, ·] buffers — which it does with all-gathers
sized by the whole dispatch buffer.  This path makes the communication
pattern explicit and minimal, the GShard/DeepSpeed-MoE way:

  * tokens are sharded over EVERY mesh axis (data × model jointly) for the
    MoE block — each device routes only its local tokens;
  * each model column owns E/TP experts; one ``all_to_all`` over the model
    axis sends each device's per-expert slots to the owning column, one
    reverse ``all_to_all`` brings the outputs back;
  * combine is local (scatter-add into the local token block).

Requires E % TP == 0 (olmoe: 64/16 ✓).  Archs with fewer experts than the
TP width (grok: 8) keep the baseline expert-TP path.

Validated against the baseline dispatch in tests/test_moe_ep.py on a host
mesh (outputs match exactly when capacity admits every routed token).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .config import ModelConfig


def _local_dispatch(xt, p_router, cfg: ModelConfig, cap: int):
    """Route T_loc local tokens; returns (idx [E,C], gates [E,C], aux)."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = (xt @ p_router.astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, _ = jax.lax.top_k(probs, k)
    is_topk = probs >= gate_k[:, -1:]
    gates = jnp.where(is_topk, probs, 0.0)
    gates = gates / (jnp.sum(gates, -1, keepdims=True) + 1e-9)
    frac = jnp.mean(is_topk.astype(jnp.float32), axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
    score_et = jnp.where(is_topk, probs, -1.0).T          # [E, T_loc]
    top_scores, idx = jax.lax.top_k(score_et, cap)        # [E, C]
    valid = (top_scores > 0.0).astype(jnp.float32)
    gsel = jnp.take_along_axis(gates.T, idx, axis=1) * valid
    return idx, gsel, aux


def moe_forward_ep(p, x, cfg: ModelConfig, mesh: Mesh):
    """Expert-parallel forward. x: [B,S,D] -> (y, aux). Requires a mesh with
    a "model" axis dividing num_experts."""
    b, s, d = x.shape
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    t = b * s
    assert t % n_shards == 0, (t, n_shards)
    tp = mesh.shape["model"]
    e = cfg.num_experts
    assert e % tp == 0, (e, tp)
    t_loc = t // n_shards
    cap = max(1, min(t_loc, int(cfg.num_experts_per_tok * t_loc
                                * cfg.capacity_factor) // e))
    dt = cfg.compute_dtype

    def body(xt, router, w_in, w_out, w_gate):
        # xt: [T_loc, d]; w_*: [E_loc, ...] (expert shards of this column)
        idx, gsel, aux = _local_dispatch(xt, router, cfg, cap)
        xe = jnp.take(xt, idx.reshape(-1), axis=0).reshape(e, cap, d)
        # send each expert's slots to the owning model column
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)                # [E/TP, TP*C, d]
        if w_gate is not None:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt))) \
                * jnp.einsum("ecd,edf->ecf", xe, w_in.astype(dt))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, w_in.astype(dt)))
        ye = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))
        # bring outputs back to the token-owning devices
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)                # [E, C, d]
        ye = ye * gsel[..., None].astype(dt)
        out = jnp.zeros((t_loc, d), dt).at[idx.reshape(-1)].add(
            ye.reshape(e * cap, d), mode="drop")
        # aux is a per-shard mean over local tokens → average across shards
        aux = jax.lax.pmean(aux, "data") if "data" in mesh.shape else aux
        aux = jax.lax.pmean(aux, "model")
        if "pod" in mesh.shape:
            aux = jax.lax.pmean(aux, "pod")
        return out, aux

    tok_spec = P(axes, None)
    has_gate = "w_gate" in p
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None), P("model", None, None),
                  P("model", None, None),
                  P("model", None, None) if has_gate else None),
        out_specs=(tok_spec, P()),
        check_rep=False)
    xt = x.reshape(t, d)
    out, aux = fn(xt, p["router"], p["w_in"], p["w_out"],
                  p.get("w_gate"))
    return out.reshape(b, s, d), aux
