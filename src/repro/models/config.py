"""Model configuration for the architecture zoo.

A single ``ModelConfig`` dataclass covers every assigned architecture family
(dense / moe / ssm / hybrid / audio enc-dec / vlm).  The layer stack is
described by a *block pattern* that is repeated ``num_groups`` times and
scanned over with ``jax.lax.scan`` (plus an unrolled remainder), which keeps
the lowered HLO small enough to compile 96-layer/340B configurations on the
dry-run host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# Block types appearing in a pattern entry.
ATTN = "attn"            # self-attention + MLP block (window controls locality)
MOE = "moe"              # self-attention + MoE-MLP block
MAMBA = "mamba"          # Mamba2 / SSD block
SHARED_ATTN = "shared_attn"  # Zamba2-style shared attention block (params shared across groups)
CROSS = "cross"          # enc-dec decoder block: self-attn + cross-attn + MLP


@dataclass(frozen=True)
class BlockSpec:
    """One entry of the repeated layer pattern."""
    kind: str            # ATTN | MOE | MAMBA | SHARED_ATTN | CROSS
    window: int = 0      # 0 = full (causal) attention; >0 = sliding window


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[BlockSpec, ...]   # repeated to cover num_layers

    # --- attention extras ---
    attn_softcap: float = 0.0        # gemma2-style tanh cap on attn logits
    logit_softcap: float = 0.0       # cap on final logits
    rope_theta: float = 10000.0
    qk_norm: bool = False

    # --- mlp ---
    activation: str = "swiglu"       # swiglu | gelu | squared_relu

    # --- moe ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1

    # --- enc-dec / multimodal frontends (stubs per assignment) ---
    encoder_layers: int = 0
    encoder_ratio: int = 4           # src frames = seq_len // encoder_ratio
    num_patch_tokens: int = 0        # vlm: patch-embedding prefix length

    # --- numerics / runtime ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # storage dtype
    remat: bool = True
    use_pallas: bool = False         # TPU path; jnp path used for CPU dry-run

    # --- per-shape runtime knobs (overridable from the launcher) ---
    train_microbatches: int = 1      # grad-accumulation steps inside train_step
    decode_window: int = 0           # >0 forces sliding-window decode variant (long_500k)
    seq_shard_activations: bool = False  # Megatron-SP residual carry (§Perf)
    grad_accum_dtype: str = "float32"    # bf16 halves the accumulator (§Perf)
    chunked_optimizer: bool = False      # scan AdamW over the layer stack (§Perf iter-3: refuted)
    optimizer_lowp_update: bool = False  # AdamW math in moment dtype (§Perf iter-4)
    moe_chunk_tokens: int = 65_536       # MoE dispatch token-chunk bound (§Perf)
    moe_impl: str = "gather"             # gather | ep (shard_map all-to-all, §Perf)
    kv_cache_dtype: str = "bfloat16"     # bfloat16 | int8 (quantized KV, §Perf)

    # ------------------------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.pattern_len

    @property
    def remainder(self) -> Tuple[BlockSpec, ...]:
        r = self.num_layers - self.num_groups * self.pattern_len
        return self.pattern[:r]

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to a multiple of 256 so embeddings/logits shard
        cleanly over the model axis (standard Megatron-style padding).
        Padded logit rows are masked to −∞ in ``unembed``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def storage_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def effective_window(self, spec: BlockSpec, for_decode: bool = False) -> int:
        """Window for a block, honouring the long-context decode override."""
        w = spec.window
        if for_decode and self.decode_window > 0:
            w = self.decode_window if w <= 0 else min(w, self.decode_window)
        return w

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), used for roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.activation == "swiglu":
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        per_moe = self.num_experts * per_mlp + d * self.num_experts
        din, ns, nh = self.d_inner, self.ssm_state, self.ssm_nheads
        g = self.ssm_ngroups
        per_mamba = (d * (2 * din + 2 * g * self.ssm_state + nh)   # in_proj
                     + self.ssm_conv_width * (din + 2 * g * ns)    # conv
                     + din * d                                     # out_proj
                     + 3 * nh)                                     # A, D, dt_bias
        counts = {ATTN: per_attn + per_mlp, MOE: per_attn + per_moe,
                  MAMBA: per_mamba, CROSS: 2 * per_attn + per_mlp,
                  SHARED_ATTN: 0}
        for i in range(self.num_layers):
            spec = self.pattern[i % self.pattern_len]
            n += counts[spec.kind] + 2 * d  # + norms
        if any(s.kind == SHARED_ATTN for s in self.pattern):
            n += 2 * (per_attn + per_mlp)  # two alternating shared blocks
        if self.encoder_layers:
            n += self.encoder_layers * (per_attn + per_mlp + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        d, f = self.d_model, self.d_ff
        per_mlp = (3 if self.activation == "swiglu" else 2) * d * f
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if self.pattern[i % self.pattern_len].kind == MOE)
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) * per_mlp
        return full - inactive


def uniform_pattern(kind: str, window: int = 0) -> Tuple[BlockSpec, ...]:
    return (BlockSpec(kind=kind, window=window),)
