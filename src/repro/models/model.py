"""Unified model API over the architecture zoo.

Every architecture exposes four pure functions driven by ``ModelConfig``:

  * ``init_params(cfg, key)``
  * ``loss_fn(params, batch, cfg) -> (loss, metrics)``      (train_4k)
  * ``prefill(params, batch, cfg) -> (logits, caches)``     (prefill_32k)
  * ``decode_step(params, token, caches, cfg) -> (logits, caches)``  [logits are padded_vocab_size wide; padded rows are -inf]
                                                            (decode_32k / long_500k)

Batch conventions (all ShapeDtypeStruct-compatible for the dry-run):
  dense/moe/ssm/hybrid : tokens [B,S] i32, targets [B,S] i32
  vlm                  : + patches [B,P,d_model]  (stub ViT output)
  audio (enc-dec)      : frames [B,S_enc,d_model] (stub codec output),
                         tokens/targets [B,S]
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ATTN, BlockSpec, ModelConfig
from .layers import embed, init_embed, init_rmsnorm, rmsnorm, unembed
from .params import split_tree
from .transformer import (init_stack, init_stack_cache, stack_decode,
                          stack_forward)


def encoder_pattern(cfg: ModelConfig) -> Tuple[BlockSpec, ...]:
    return (BlockSpec(kind=ATTN, window=0),)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = split_tree(key, 4)
    p = {"embed": init_embed(ks[0], cfg),
         "decoder": init_stack(ks[1], cfg),
         "final_norm": init_rmsnorm(ks[2], cfg.d_model, cfg.storage_dtype)}
    if cfg.encoder_layers:
        p["encoder"] = init_stack(ks[3], cfg, pattern=encoder_pattern(cfg),
                                  num_layers=cfg.encoder_layers)
        p["enc_norm"] = init_rmsnorm(ks[3], cfg.d_model, cfg.storage_dtype)
    return p


def _encode(params, frames, cfg: ModelConfig):
    pos = jnp.arange(frames.shape[1])
    h, _ = stack_forward(params["encoder"], frames.astype(cfg.compute_dtype),
                         pos, cfg, pattern=encoder_pattern(cfg), causal=False)
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _embed_inputs(params, batch, cfg: ModelConfig):
    x = embed(params["embed"], batch["tokens"], cfg)
    if cfg.num_patch_tokens:                      # vlm: patch prefix
        patches = batch["patches"].astype(cfg.compute_dtype)
        x = jnp.concatenate([patches, x], axis=1)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(params, batch["frames"], cfg)
    return x, enc_out


def forward_logits(params, batch, cfg: ModelConfig, last_only: bool = False):
    from ..sharding.context import constrain_batch
    x, enc_out = _embed_inputs(params, batch, cfg)
    x = constrain_batch(x)
    positions = jnp.arange(x.shape[1])
    h, aux = stack_forward(params["decoder"], x, positions, cfg, enc_out=enc_out)
    if cfg.num_patch_tokens:                      # loss only over text region
        h = h[:, cfg.num_patch_tokens:, :]
    if last_only:                                 # prefill: only last logits
        h = h[:, -1:, :]
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg)
    return constrain_batch(logits, vocab_dim=2), aux


def cross_entropy(logits, targets, mask=None):
    """Vocab-sharding-safe CE: one-hot einsum instead of gather."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (targets[..., None] == jnp.arange(v)[None, None, :]).astype(jnp.float32)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - tgt
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = forward_logits(params, batch, cfg)
    ce = cross_entropy(logits, batch["targets"], batch.get("loss_mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, seq_len: int):
    enc_len = seq_len // cfg.encoder_ratio if cfg.encoder_layers else 0
    return init_stack_cache(cfg, batch, seq_len, enc_len)


def prefill(params, batch, cfg: ModelConfig):
    """Full-sequence forward returning last-position logits (the full-seq
    hidden states are computed; only the final position is unembedded —
    full-vocab logits for 32k positions would be a logits-sized whale)."""
    logits, _ = forward_logits(params, batch, cfg, last_only=True)
    return logits[:, -1, :]


def prefill_with_caches(params, batch, cfg: ModelConfig, max_seq: int):
    """One-pass serving prefill: full forward that also PRIMES the decode
    caches (K/V collected per layer, windowed layers ring-rolled, SSM states
    carried out of the chunk scan).  Returns (last_logits [B,V], caches)
    ready for ``decode_step`` at position S.

    ``max_seq`` sizes the full-attention caches for the generation budget.
    """
    assert cfg.kv_cache_dtype != "int8", \
        "cache-collecting prefill supports bf16 caches; int8 is a decode-path option"
    from ..sharding.context import constrain_batch
    x, enc_out = _embed_inputs(params, batch, cfg)
    x = constrain_batch(x)
    s = x.shape[1]
    positions = jnp.arange(s)
    h, _, caches = stack_forward(params["decoder"], x, positions, cfg,
                                 enc_out=enc_out, collect_caches=True)
    h = rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg)

    def pad_entry(cache, spec):
        w = cfg.effective_window(spec, for_decode=True)
        target = min(max_seq, w) if w > 0 else max_seq
        out = dict(cache)
        for key in ("k", "v"):
            if key in cache:
                cur = cache[key].shape[-3]
                if cur < target:
                    padw = [(0, 0)] * cache[key].ndim
                    padw[-3] = (0, target - cur)
                    out[key] = jnp.pad(cache[key], padw)
                elif cur > target:   # S > max_seq budget: keep ring tail
                    out[key] = cache[key][..., -target:, :, :]
        return out

    caches = {
        "entries": [pad_entry(c, spec)
                    for c, spec in zip(caches["entries"], cfg.pattern)],
        "rem": [pad_entry(c, spec)
                for c, spec in zip(caches["rem"], cfg.remainder)],
        "pos": caches["pos"],
    }
    return logits[:, 0, :], caches


def decode_step(params, token, caches, cfg: ModelConfig):
    """token: [B,1] i32. Returns (logits [B,V], new caches)."""
    pos = caches["pos"]
    x = embed(params["embed"], token, cfg)
    h, new_caches = stack_decode(params["decoder"], x, caches, pos, cfg)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg)
    return logits[:, 0, :], new_caches
