"""Minimal pure-pytree parameter system (no flax dependency).

Parameters are nested dicts of ``jnp`` arrays.  Initializers are explicit
functions taking a PRNG key; every module exposes ``init_*`` and a pure
``apply``-style function.  Compute casts storage-dtype params to the config's
compute dtype at use sites.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """LeCun-normal style init with fan-in along ``in_axis``."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


def split_tree(key, n: int):
    return list(jax.random.split(key, n))


def cast(tree, dtype):
    """Cast all floating arrays in a pytree to ``dtype``."""
    def _c(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_c, tree)


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))
