"""Small classifiers for the paper's FL experiments (§VI).

The paper trains on MNIST and CIFAR-10; offline we use synthetic proxies
(see ``repro.data.synthetic``).  Two model families mirror the paper's setup:
an MLP for the MNIST proxy and a small conv net for the CIFAR proxy.
All pure-pytree, SGD-trainable per Eq. (2).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .params import dense_init, split_tree


def init_mlp_classifier(key, in_dim: int = 784, hidden: int = 128,
                        num_classes: int = 10, dtype=jnp.float32):
    ks = split_tree(key, 3)
    return {
        "w1": dense_init(ks[0], (in_dim, hidden), dtype),
        "b1": jnp.zeros((hidden,), dtype),
        "w2": dense_init(ks[1], (hidden, hidden), dtype),
        "b2": jnp.zeros((hidden,), dtype),
        "w3": dense_init(ks[2], (hidden, num_classes), dtype),
        "b3": jnp.zeros((num_classes,), dtype),
    }


def mlp_classifier_logits(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def init_cnn_classifier(key, side: int = 16, channels: int = 3,
                        num_classes: int = 10, dtype=jnp.float32):
    """Small conv net for the CIFAR proxy (images reshaped [B,side,side,C])."""
    ks = split_tree(key, 4)
    return {
        "c1": dense_init(ks[0], (3, 3, channels, 16), dtype, in_axis=2),
        "c2": dense_init(ks[1], (3, 3, 16, 32), dtype, in_axis=2),
        "w1": dense_init(ks[2], ((side // 4) ** 2 * 32, 64), dtype),
        "b1": jnp.zeros((64,), dtype),
        "w2": dense_init(ks[3], (64, num_classes), dtype),
        "b2": jnp.zeros((num_classes,), dtype),
    }


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def cnn_classifier_logits(p, x):
    b = x.shape[0]
    side = int(round((x.shape[-1] / 3) ** 0.5))
    img = x.reshape(b, side, side, 3)
    h = jax.nn.relu(_conv(img, p["c1"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = jax.nn.relu(_conv(h, p["c2"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(b, -1)
    h = jax.nn.relu(h @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def classifier_loss(logits_fn, p, x, y, num_classes: int = 10):
    logits = logits_fn(p, x)
    onehot = jax.nn.one_hot(y, num_classes)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def classifier_accuracy(logits_fn, p, x, y):
    return jnp.mean((jnp.argmax(logits_fn(p, x), axis=-1) == y).astype(jnp.float32))


def make_classifier(kind: str, key, **kw) -> Tuple[Dict, callable]:
    if kind == "mlp":
        return init_mlp_classifier(key, **kw), mlp_classifier_logits
    if kind == "cnn":
        return init_cnn_classifier(key, **kw), cnn_classifier_logits
    raise ValueError(kind)
