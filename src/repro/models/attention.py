"""Grouped-query attention with sliding-window support and KV caches.

Three execution paths:
  * ``attn_full``    — full score matrix; used for short sequences (train_4k,
                       smoke tests) and for the encoder.
  * ``attn_blocked`` — ``lax.scan`` over query chunks, with static key-window
                       slicing for local layers; used for 32k prefill.  This
                       is the jnp twin of ``kernels/swa_attention.py``.
  * ``attn_decode``  — one query against a (possibly ring-buffer) KV cache.

Caches store *RoPE-rotated* keys, so ring-buffer slots need no absolute
position bookkeeping: softmax only needs a validity mask.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, softcap
from .params import dense_init, ones_init, split_tree

NEG_INF = -2.0e38


def init_attention(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.storage_dtype
    ks = split_tree(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dt),
        "wk": dense_init(ks[1], (d, kv, hd), dt),
        "wv": dense_init(ks[2], (d, kv, hd), dt),
        "wo": dense_init(ks[3], (h, hd, d), dt, in_axis=0),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init(ks[4], (hd,), dt)
        p["k_norm"] = ones_init(ks[5], (hd,), dt)
    return p


def _qk_norm(x, scale, eps):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def qkv(p, x, positions, cfg: ModelConfig, rope: bool = True):
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: [B,Sq,H,hd]  k: [B,Sk,KV,hd]  ->  [B,KV,rep,Sq,Sk] (f32)."""
    b, sq, h, hd = q.shape
    kv = cfg.num_kv_heads
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    s = jnp.einsum("bskrd,btkd->bkrst", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    return softcap(s, cfg.attn_softcap)


def _gqa_out(probs, v, p, cfg: ModelConfig):
    """probs: [B,KV,rep,Sq,Sk]  v: [B,Sk,KV,hd]  -> [B,Sq,D]."""
    dt = cfg.compute_dtype
    o = jnp.einsum("bkrst,btkd->bskrd", probs.astype(dt), v)
    b, sq = o.shape[0], o.shape[1]
    o = o.reshape(b, sq, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


def _mask_bias(q_pos, k_pos, window, causal: bool):
    """Additive bias [..., Sq, Sk] from positions; window<=0 = unbounded."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if isinstance(window, int):
        if window > 0:
            ok &= d < window
    else:  # traced per-layer window scalar: 0 means full
        ok &= (window <= 0) | (d < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# full path
# ---------------------------------------------------------------------------
def attn_full(p, x, positions, window, cfg: ModelConfig, causal: bool = True,
              kv_override=None):
    q, k, v = qkv(p, x, positions, cfg)
    if kv_override is not None:                    # cross-attention
        k, v = kv_override
        kpos = jnp.arange(k.shape[1])
    else:
        kpos = positions
    s = _gqa_scores(q, k, cfg)
    bias = _mask_bias(positions, kpos, window, causal)  # [Sq,Sk] (+batch dims broadcast)
    s = s + bias
    probs = jax.nn.softmax(s, axis=-1)
    return _gqa_out(probs, v, p, cfg), (k, v)


# ---------------------------------------------------------------------------
# blocked path (long prefill)
# ---------------------------------------------------------------------------
def attn_blocked(p, x, positions, window, cfg: ModelConfig, chunk: int = 512,
                 causal: bool = True, kv_override=None):
    """Attention scanning over query chunks (memory-bounded).

    For causal windowed layers the key range per chunk is a *static-length*
    slice (window + chunk), giving true O(S·W) work; otherwise keys span the
    full (causal or bidirectional / cross) range one query chunk at a time.
    """
    b, s, _ = x.shape
    if s % chunk:
        chunk = max(1, s // max(1, s // chunk))
        while s % chunk:
            chunk //= 2
    q, k_self, v_self = qkv(p, x, positions, cfg)
    if kv_override is not None:
        k, v = kv_override
        kpos_full = jnp.arange(k.shape[1])
    else:
        k, v = k_self, v_self
        kpos_full = positions
    n = s // chunk
    static_win = causal and isinstance(window, int) and window > 0 \
        and kv_override is None
    klen = min(s, window + chunk) if static_win else k.shape[1]

    def body(_, ci):
        qs = ci * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, qs, chunk, axis=1)
        qp = qs + jnp.arange(chunk)
        if static_win:
            ks = jnp.maximum(0, qs + chunk - klen)
            kc = jax.lax.dynamic_slice_in_dim(k, ks, klen, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ks, klen, axis=1)
            kp = ks + jnp.arange(klen)
        else:
            kc, vc, kp = k, v, kpos_full
        sc = _gqa_scores(qc, kc, cfg) + _mask_bias(qp, kp, window, causal)
        probs = jax.nn.softmax(sc, axis=-1)
        return None, _gqa_out(probs, vc, p, cfg)

    _, out = jax.lax.scan(body, None, jnp.arange(n))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.d_model)
    return out, (k_self, v_self)


def attention(p, x, positions, window, cfg: ModelConfig, causal: bool = True,
              kv_override=None, blocked_threshold: int = 2048):
    s = x.shape[1]
    if s > blocked_threshold:
        return attn_blocked(p, x, positions, window, cfg, causal=causal,
                            kv_override=kv_override)
    return attn_full(p, x, positions, window, cfg, causal, kv_override)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, window: int,
                  prefix_shape=()):
    """Cache length = window when the layer is windowed (ring buffer).

    ``kv_cache_dtype="int8"`` stores K/V as int8 with per-(token, head) f32
    scales — 2× residency reduction vs bf16 (beyond-paper §Perf; opt-in)."""
    c = min(seq_len, window) if window and window > 0 else seq_len
    shape = prefix_shape + (batch, c, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    dt = cfg.compute_dtype
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _quantize_kv(x):
    """x: [B,1,KV,hd] → (int8 values, f32 scales [B,1,KV])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_cache(cache, dtype):
    """Returns (k, v) in compute dtype regardless of storage format."""
    if "k_scale" in cache:
        k = (cache["k"].astype(jnp.float32)
             * cache["k_scale"][..., None]).astype(dtype)
        v = (cache["v"].astype(jnp.float32)
             * cache["v_scale"][..., None]).astype(dtype)
        return k, v
    return cache["k"], cache["v"]


def cache_write(cache, k_new, v_new, pos):
    """Write one token (k_new/v_new: [B,1,KV,hd]) at ring slot pos % C."""
    c = cache["k"].shape[-3]
    slot = jnp.mod(pos, c)
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        return {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=-3),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=-3),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, slot, axis=-2),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, slot, axis=-2),
        }
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=-3)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=-3)
    return {"k": k, "v": v}


def attn_decode(p, x, cache, pos, cfg: ModelConfig, kv_override=None):
    """x: [B,1,D]; returns (out [B,1,D], new_cache)."""
    positions = jnp.full((x.shape[0], 1), pos)
    q, k_new, v_new = qkv(p, x, positions, cfg)
    if kv_override is not None:
        k, v = kv_override
        s = _gqa_scores(q, k, cfg)
        probs = jax.nn.softmax(s, axis=-1)
        return _gqa_out(probs, v, p, cfg), cache
    cache = cache_write(cache, k_new, v_new, pos)
    c = cache["k"].shape[-3]
    k_all, v_all = dequantize_cache(cache, cfg.compute_dtype)
    s = _gqa_scores(q, k_all, cfg)                        # [B,KV,rep,1,C]
    valid = jnp.arange(c) <= pos                          # ring validity
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    return _gqa_out(probs, v_all, p, cfg), cache
