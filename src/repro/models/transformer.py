"""Layer-stack assembly: pattern-group scan over heterogeneous blocks.

The layer stack is ``num_groups`` repetitions of ``cfg.pattern`` plus an
unrolled remainder.  Per-entry parameters are stacked over the group axis and
consumed by a single ``lax.scan``; within a group the (≤6) pattern entries are
unrolled.  This keeps lowered-HLO size O(pattern) instead of O(num_layers) —
essential for compiling 96-layer / 340B configs on the 1-core dry-run host.

Zamba2-style ``SHARED_ATTN`` entries use two alternating parameter sets shared
across groups (selected by group parity inside the scan).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ssm
from .config import ATTN, CROSS, MAMBA, MOE, SHARED_ATTN, BlockSpec, ModelConfig
from .layers import init_mlp, init_rmsnorm, mlp, rmsnorm
from .moe import init_moe, moe_forward
from .params import split_tree


# ---------------------------------------------------------------------------
# single-block init / forward
# ---------------------------------------------------------------------------
def init_block(key, spec: BlockSpec, cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.storage_dtype
    ks = split_tree(key, 6)
    if spec.kind == SHARED_ATTN:
        return {}  # params live in the shared slot
    if spec.kind == MAMBA:
        return {"ln1": init_rmsnorm(ks[0], d, dt),
                "mamba": ssm.init_mamba(ks[1], cfg)}
    p = {"ln1": init_rmsnorm(ks[0], d, dt),
         "attn": attn.init_attention(ks[1], cfg),
         "ln2": init_rmsnorm(ks[2], d, dt)}
    if spec.kind == MOE:
        p["ffn"] = init_moe(ks[3], cfg)
    else:
        p["ffn"] = init_mlp(ks[3], cfg)
    if spec.kind == CROSS:
        p["lnx"] = init_rmsnorm(ks[4], d, dt)
        p["xattn"] = attn.init_attention(ks[5], cfg)
    return p


def init_shared_block(key, cfg: ModelConfig):
    """Two alternating Zamba2 shared attention+MLP blocks, stacked on axis 0."""
    ks = split_tree(key, 2)
    spec = BlockSpec(kind=ATTN, window=0)
    both = [init_block(k, spec, cfg) for k in ks]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *both)


def _ffn_apply(p, spec, h, cfg):
    if spec.kind == MOE:
        return moe_forward(p["ffn"], h, cfg)
    return mlp(p["ffn"], h, cfg), jnp.float32(0.0)


def _ring_cache(k, v, window: int):
    """Convert full-sequence K/V into the decode ring-buffer layout:
    last ``window`` entries rolled so slot = pos % window."""
    s = k.shape[1]
    if window <= 0 or s <= window:
        return k, v
    shift = s % window
    k = jnp.roll(k[:, -window:], shift, axis=1)
    v = jnp.roll(v[:, -window:], shift, axis=1)
    return k, v


def block_forward(p, spec: BlockSpec, x, positions, cfg: ModelConfig,
                  shared=None, group_idx=None, enc_out=None, causal=True,
                  collect=False):
    """Full-sequence block application. Returns (x, aux_loss[, cache])."""
    if spec.kind == SHARED_ATTN:
        p = jax.tree_util.tree_map(lambda a: a[group_idx % 2], shared)
        spec = BlockSpec(kind=ATTN, window=spec.window)
    if spec.kind == MAMBA:
        out = ssm.mamba_forward(p["mamba"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                                cfg, return_cache=collect)
        if collect:
            out, cache = out
            return x + out, jnp.float32(0.0), cache
        return x + out, jnp.float32(0.0)
    h, (k_self, v_self) = attn.attention(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), positions, spec.window,
        cfg, causal=causal)
    x = x + h
    cache = None
    if collect:
        w = cfg.effective_window(spec, for_decode=True)
        kc, vc = _ring_cache(k_self, v_self, w)
        cache = {"k": kc, "v": vc}
    if spec.kind == CROSS:
        q_in = rmsnorm(p["lnx"], x, cfg.norm_eps)
        kx, vx = _cross_kv(p["xattn"], enc_out, cfg)
        h, _ = attn.attention(p["xattn"], q_in, positions, 0, cfg,
                              causal=False, kv_override=(kx, vx))
        x = x + h
        if collect:
            cache["xk"], cache["xv"] = kx, vx
    f, aux = _ffn_apply(p, spec, rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    if collect:
        return x + f, aux, cache
    return x + f, aux


def _cross_kv(p, enc_out, cfg):
    dt = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return k, v


def block_decode(p, spec: BlockSpec, x, cache, pos, cfg: ModelConfig,
                 shared=None, group_idx=None):
    """One-token block step. Returns (x, new_cache)."""
    if spec.kind == SHARED_ATTN:
        p = jax.tree_util.tree_map(lambda a: a[group_idx % 2], shared)
        spec = BlockSpec(kind=ATTN, window=spec.window)
    if spec.kind == MAMBA:
        h, new = ssm.mamba_decode_step(p["mamba"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                                       cache, cfg)
        return x + h, new
    self_cache = {k: v for k, v in cache.items() if k not in ("xk", "xv")}
    h, new_self = attn.attn_decode(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                                   self_cache, pos, cfg)
    x = x + h
    new = dict(cache)
    new.update(new_self)
    if spec.kind == CROSS:
        q_in = rmsnorm(p["lnx"], x, cfg.norm_eps)
        h, _ = attn.attn_decode(p["xattn"], q_in, None, pos, cfg,
                                kv_override=(cache["xk"], cache["xv"]))
        x = x + h
    f, _ = _ffn_apply(p, spec, rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + f, new


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int, seq_len: int,
                     enc_len: int = 0, prefix=()):
    if spec.kind == MAMBA:
        return ssm.init_ssm_cache(cfg, batch, prefix_shape=prefix)
    w = cfg.effective_window(spec, for_decode=True)
    c = attn.init_kv_cache(cfg, batch, seq_len, w, prefix_shape=prefix)
    if spec.kind == CROSS:
        dt = cfg.compute_dtype
        c["xk"] = jnp.zeros(prefix + (batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dt)
        c["xv"] = jnp.zeros_like(c["xk"])
    return c


def init_stack_cache(cfg: ModelConfig, batch: int, seq_len: int, enc_len: int = 0):
    g = cfg.num_groups
    return {
        "entries": [init_block_cache(cfg, s, batch, seq_len, enc_len, prefix=(g,))
                    for s in cfg.pattern],
        "rem": [init_block_cache(cfg, s, batch, seq_len, enc_len)
                for s in cfg.remainder],
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# stack init / forward
# ---------------------------------------------------------------------------
def init_stack(key, cfg: ModelConfig, pattern=None, num_layers=None):
    pattern = pattern or cfg.pattern
    nl = num_layers or cfg.num_layers
    g, p_len = nl // len(pattern), len(pattern)
    rem = pattern[:nl - g * p_len]
    ks = split_tree(key, p_len + len(rem) + 1)
    entries = []
    for i, spec in enumerate(pattern):
        gk = split_tree(ks[i], g)
        per = [init_block(k, spec, cfg) for k in gk]
        entries.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
                       if per[0] else {})
    params = {"entries": entries,
              "rem": [init_block(ks[p_len + i], s, cfg) for i, s in enumerate(rem)]}
    if any(s.kind == SHARED_ATTN for s in pattern):
        params["shared"] = init_shared_block(ks[-1], cfg)
    return params


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def stack_forward(params, x, positions, cfg: ModelConfig, pattern=None,
                  enc_out=None, causal=True, collect_caches=False):
    """Full-sequence stack. Returns (x, total_aux) or, with
    ``collect_caches``, (x, total_aux, caches) where caches matches
    ``init_stack_cache`` layout primed at position S."""
    pattern = pattern or cfg.pattern
    shared = params.get("shared")
    # group count derives from stacked leading dim (robust to custom stacks)
    leaves = jax.tree_util.tree_leaves(params["entries"])
    g = leaves[0].shape[0] if leaves else 0

    from ..sharding.context import constrain_batch

    def group_body(carry, xs):
        xc, aux = carry
        gi, entry_params = xs
        caches = []
        for i, spec in enumerate(pattern):
            out = block_forward(entry_params[i], spec, xc, positions, cfg,
                                shared=shared, group_idx=gi, enc_out=enc_out,
                                causal=causal, collect=collect_caches)
            if collect_caches:
                xc, a, cache = out
                caches.append(cache)
            else:
                xc, a = out
            aux = aux + a
        # pin the residual-carry sharding at the scan boundary (where the
        # remat residual is saved) — SPMD otherwise drops batch sharding.
        # seq_shard_activations additionally shards the carry's seq dim over
        # the model axis (Megatron sequence parallelism): residuals shrink
        # by model_size at the cost of per-group all-gather/reduce-scatter.
        sd = 1 if cfg.seq_shard_activations else None
        return (constrain_batch(xc, seq_dim=sd), aux), \
            (caches if collect_caches else None)

    body = jax.checkpoint(group_body) if (cfg.remat and not collect_caches) \
        else group_body
    entry_caches = []
    if g:
        (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                    (jnp.arange(g), params["entries"]))
        if collect_caches:
            entry_caches = ys
    else:
        aux = jnp.float32(0.0)
    rem_specs = pattern[:len(params["rem"])]
    rem_caches = []
    for i, spec in enumerate(rem_specs):
        out = block_forward(params["rem"][i], spec, x, positions, cfg,
                            shared=shared, group_idx=g, enc_out=enc_out,
                            causal=causal, collect=collect_caches)
        if collect_caches:
            x, a, cache = out
            rem_caches.append(cache)
        else:
            x, a = out
        aux = aux + a
    if collect_caches:
        caches = {"entries": entry_caches, "rem": rem_caches,
                  "pos": jnp.asarray(x.shape[1], jnp.int32)}
        return x, aux, caches
    return x, aux


def stack_decode(params, x, caches, pos, cfg: ModelConfig, pattern=None):
    """One-token step through the whole stack. Returns (x, new_caches)."""
    pattern = pattern or cfg.pattern
    shared = params.get("shared")
    leaves = jax.tree_util.tree_leaves(params["entries"])
    g = leaves[0].shape[0] if leaves else 0

    def group_body(xc, xs):
        gi, entry_params, entry_caches = xs
        new_caches = []
        for i, spec in enumerate(pattern):
            xc, nc = block_decode(entry_params[i], spec, xc, entry_caches[i],
                                  pos, cfg, shared=shared, group_idx=gi)
            new_caches.append(nc)
        return xc, new_caches

    if g:
        x, new_entries = jax.lax.scan(
            group_body, x, (jnp.arange(g), params["entries"], caches["entries"]))
    else:
        new_entries = caches["entries"]
    new_rem = []
    rem_specs = pattern[:len(params["rem"])]
    for i, spec in enumerate(rem_specs):
        x, nc = block_decode(params["rem"][i], spec, x, caches["rem"][i], pos,
                             cfg, shared=shared, group_idx=g)
        new_rem.append(nc)
    return x, {"entries": new_entries, "rem": new_rem, "pos": pos + 1}
