from .config import (ATTN, CROSS, MAMBA, MOE, SHARED_ATTN, BlockSpec,
                     ModelConfig, uniform_pattern)
from .model import (cross_entropy, decode_step, forward_logits, init_caches,
                    init_params, loss_fn, prefill, prefill_with_caches)

__all__ = [
    "ATTN", "CROSS", "MAMBA", "MOE", "SHARED_ATTN", "BlockSpec", "ModelConfig",
    "uniform_pattern", "cross_entropy", "decode_step", "forward_logits",
    "init_caches", "init_params", "loss_fn", "prefill", "prefill_with_caches",
]
