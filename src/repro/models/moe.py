"""Top-k Mixture-of-Experts layer (OLMoE 64e/top-8, Grok-1 8e/top-2).

Baseline dispatch = capacity-bounded *expert-choice gather*: each expert
takes its top-C tokens by router score (C = k·T/E·capacity_factor), gathered
with a batched ``take``, processed with a batched matmul, and combined with a
scatter-add.  This is pure SPMD-friendly (no shard_map) and is the
paper-faithful baseline; the §Perf hillclimb replaces it with a shard_map
all-to-all dispatch for expert parallelism.

Sharding: experts over the ``model`` mesh axis when E % model_size == 0
(olmoe), expert-tensor-parallel (d_ff over ``model``) otherwise (grok).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import dense_init, split_tree


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.storage_dtype
    ks = split_tree(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dt),
        "w_in": dense_init(ks[1], (e, d, f), dt, in_axis=1),
        "w_out": dense_init(ks[2], (e, f, d), dt, in_axis=1),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(ks[3], (e, d, f), dt, in_axis=1)
    return p


def _capacity(cfg: ModelConfig, t: int) -> int:
    c = int(cfg.num_experts_per_tok * t * cfg.capacity_factor) // cfg.num_experts
    # keep MXU-aligned and positive, never above the token count
    return max(1, min(t, max(8, (c // 8) * 8)))


def moe_forward(p, x, cfg: ModelConfig):
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar f32).

    Long sequences are processed in token chunks via ``lax.scan`` so the
    [E, C, ·] dispatch/hidden buffers stay bounded (per-chunk capacity —
    the [E, 327k, d_ff] f32 hidden buffer at 1M-token prefill was the
    largest allocation in the grok-1 baseline)."""
    if cfg.moe_impl == "ep":
        from ..sharding.context import get_active_mesh
        mesh = get_active_mesh()
        if mesh is not None and "model" in mesh.shape \
                and cfg.num_experts % mesh.shape["model"] == 0:
            n_shards = 1
            for v in mesh.shape.values():
                n_shards *= v
            if (x.shape[0] * x.shape[1]) % n_shards == 0:
                from .moe_ep import moe_forward_ep
                return moe_forward_ep(p, x, cfg, mesh)
            # too few tokens to shard over every axis (decode) — fall back
    b, s, d = x.shape
    t = b * s
    chunk = cfg.moe_chunk_tokens
    if chunk and t > chunk and t % chunk == 0:
        n = t // chunk
        xc = x.reshape(n, chunk, d)

        def body(_, xt_chunk):
            y, aux = _moe_tokens(p, xt_chunk, cfg)
            return None, (y, aux)

        _, (ys, auxs) = jax.lax.scan(body, None, xc)
        return ys.reshape(b, s, d), jnp.mean(auxs)
    y, aux = _moe_tokens(p, x.reshape(t, d), cfg)
    return y.reshape(b, s, d), aux


def _moe_tokens(p, xt, cfg: ModelConfig):
    """xt: [T,D] -> (y [T,D], aux scalar)."""
    dt = cfg.compute_dtype
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T,E]
    gate_k, _ = jax.lax.top_k(probs, k)                         # [T,k]
    thresh = gate_k[:, -1:]                                     # k-th largest
    is_topk = probs >= thresh                                   # [T,E]
    gates = jnp.where(is_topk, probs, 0.0)
    gates = gates / (jnp.sum(gates, -1, keepdims=True) + 1e-9)  # renormalize

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(is_topk.astype(jnp.float32), axis=0)  # [E]
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * mean_prob)

    # expert-choice gather: every expert takes its top-C tokens
    cap = _capacity(cfg, t)
    score_et = jnp.where(is_topk, probs, -1.0).T                # [E,T]
    top_scores, idx = jax.lax.top_k(score_et, cap)              # [E,C]
    valid = (top_scores > 0.0).astype(jnp.float32)              # dropped slots
    gsel = jnp.take_along_axis(gates.T, idx, axis=1) * valid    # [E,C]

    xe = jnp.take(xt, idx.reshape(-1), axis=0).reshape(e, cap, d)  # [E,C,D]
    if cfg.activation == "swiglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))) \
            * jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(dt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(dt)))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))   # [E,C,D]
    ye = ye * gsel[..., None].astype(dt)

    out = jnp.zeros((t, d), dt).at[idx.reshape(-1)].add(
        ye.reshape(e * cap, d), mode="drop")
    return out, aux
