"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block, pure JAX.

Train/prefill use the chunked SSD algorithm (quadratic intra-chunk term +
linear inter-chunk state recurrence via ``lax.scan``); decode uses the O(1)
recurrent step.  ``kernels/ssd_scan.py`` provides the Pallas TPU kernel for
the intra-chunk term; this module is the jnp reference path used under SPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import dense_init, split_tree


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    din, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    g, cw = cfg.ssm_ngroups, cfg.ssm_conv_width
    dt = cfg.storage_dtype
    ks = split_tree(key, 5)
    conv_ch = din + 2 * g * ns
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * g * ns + nh), dt),
        "conv_w": dense_init(ks[1], (cw, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((nh,), dt),                      # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "out_norm": jnp.ones((din,), dt),
        "out_proj": dense_init(ks[2], (din, d), dt),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    din, ns, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * g * ns]
    dt = zxbcdt[..., din + din + 2 * g * ns:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(xbc, w, b, cfg: ModelConfig):
    """Depthwise causal conv1d, width cfg.ssm_conv_width. xbc: [B,S,C]."""
    cw = cfg.ssm_conv_width
    pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(cw))
    return jax.nn.silu(out + b[None, None, :])


def _split_xbc(xbc, cfg: ModelConfig):
    din, ns, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    b_, s_ = xbc.shape[0], xbc.shape[1]
    x = xbc[..., :din].reshape(b_, s_, cfg.ssm_nheads, cfg.ssm_head_dim)
    B = xbc[..., din:din + g * ns].reshape(b_, s_, g, ns)
    C = xbc[..., din + g * ns:].reshape(b_, s_, g, ns)
    return x, B, C


def _expand_groups(bc, nh, g):
    """[b,...,g,n] -> [b,...,h,n] by repeating each group nh//g times."""
    return jnp.repeat(bc, nh // g, axis=-2)


def ssd_chunked(x, dt, A, B, C, chunk: int, return_state: bool = False):
    """SSD scan.  x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,h,n] -> y:[b,s,h,p].

    Implemented as ONE ``lax.scan`` over chunks carrying the SSM state —
    the quadratic intra-chunk term is materialized for a single chunk at a
    time ([b,l,l,h], a few MB), matching what the Pallas kernel holds in
    VMEM.  All decay math in f32.
    """
    b, s, h, p = x.shape
    pad = (-s) % chunk
    if pad:  # zero-pad to a chunk multiple (dt=0 ⇒ identity dynamics)
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        x = jnp.pad(x, padw)
        dt = jnp.pad(dt, padw[:3])
        B = jnp.pad(B, padw)
        C = jnp.pad(C, padw)
        s = s + pad
    c, l = s // chunk, chunk
    causal = jnp.tril(jnp.ones((l, l), bool))

    def per_chunk(state, inp):
        xr, dtr, Br, Cr = inp            # [b,l,h,p], [b,l,h], [b,l,h,n] ×2
        dtr = dtr.astype(jnp.float32)
        dA = dtr * A[None, None, :]                         # [b,l,h]
        dA_cs = jnp.cumsum(dA, axis=1)

        # intra-chunk (quadratic) term
        Lmat = dA_cs[:, :, None, :] - dA_cs[:, None, :, :]  # [b,l,m,h]
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(Lmat), 0.0)
        CB = jnp.einsum("blhn,bmhn->blmh", Cr.astype(jnp.float32),
                        Br.astype(jnp.float32))
        gate = CB * Lmat * dtr[:, None, :, :]
        y = jnp.einsum("blmh,bmhp->blhp", gate, xr.astype(jnp.float32))

        # inter-chunk contribution from the carried state
        y += jnp.einsum("blhn,bhpn,blh->blhp", Cr.astype(jnp.float32),
                        state, jnp.exp(dA_cs))

        # state update
        decay = jnp.exp(dA_cs[:, -1:, :] - dA_cs)           # [b,l,h]
        new_state = state * jnp.exp(dA_cs[:, -1, :])[:, :, None, None] + \
            jnp.einsum("blhn,blh,blhp->bhpn", Br.astype(jnp.float32),
                       decay * dtr, xr.astype(jnp.float32))
        return new_state, y.astype(x.dtype)

    to_chunks = lambda a: jnp.moveaxis(
        a.reshape((b, c, l) + a.shape[2:]), 1, 0)
    init = jnp.zeros((b, h, p, B.shape[-1]), jnp.float32)
    final_state, ys = jax.lax.scan(
        per_chunk, init, (to_chunks(x), to_chunks(dt), to_chunks(B),
                          to_chunks(C)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    if pad:
        y = y[:, :s - pad]
    if return_state:
        return y, final_state
    return y


def _out(z, y, p, cfg: ModelConfig):
    dt = cfg.compute_dtype
    b, s = y.shape[0], y.shape[1]
    y = y.reshape(b, s, cfg.d_inner) * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    y32 = y32 * jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True) + cfg.norm_eps)
    y = (y32 * (1.0 + p["out_norm"].astype(jnp.float32))).astype(dt)
    return y @ p["out_proj"].astype(dt)


def mamba_forward(p, xin, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence forward (train / prefill). xin: [B,S,D] -> [B,S,D].

    With ``return_cache`` also returns the decode cache primed at position S
    (final SSM state + last conv_width−1 raw xbc inputs)."""
    dt_c = cfg.compute_dtype
    zxbcdt = xin @ p["in_proj"].astype(dt_c)
    z, xbc_raw, dtv = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(dt_c),
                       p["conv_b"].astype(dt_c), cfg)
    x, B, C = _split_xbc(xbc, cfg)
    B = _expand_groups(B, cfg.ssm_nheads, cfg.ssm_ngroups)
    C = _expand_groups(C, cfg.ssm_nheads, cfg.ssm_ngroups)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if return_cache:
        y, state = ssd_chunked(x, dtv, A, B, C, cfg.ssm_chunk,
                               return_state=True)
    else:
        y = ssd_chunked(x, dtv, A, B, C, cfg.ssm_chunk)
    y = y + x * p["D"].astype(dt_c)[None, None, :, None]
    out = _out(z, y, p, cfg)
    if return_cache:
        cw = cfg.ssm_conv_width
        conv_tail = xbc_raw[:, -(cw - 1):, :]
        return out, {"state": state, "conv": conv_tail}
    return out


# ---------------------------------------------------------------------------
# decode (recurrent step)
# ---------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, prefix_shape=()):
    nh, pdim, ns = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * ns
    return {
        "state": jnp.zeros(prefix_shape + (batch, nh, pdim, ns), jnp.float32),
        "conv": jnp.zeros(prefix_shape + (batch, cfg.ssm_conv_width - 1, conv_ch),
                          cfg.compute_dtype),
    }


def mamba_decode_step(p, xin, cache, cfg: ModelConfig):
    """One-token step. xin: [B,1,D] -> (out [B,1,D], new cache)."""
    dt_c = cfg.compute_dtype
    zxbcdt = xin @ p["in_proj"].astype(dt_c)
    z, xbc, dtv = _split_proj(zxbcdt, cfg)                  # xbc: [B,1,C]
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)    # [B,cw,C]
    w = p["conv_w"].astype(dt_c)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(dt_c)
    xbc_t = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]

    x, B, C = _split_xbc(xbc_t, cfg)
    B = _expand_groups(B, cfg.ssm_nheads, cfg.ssm_ngroups)[:, 0]   # [B,h,n]
    C = _expand_groups(C, cfg.ssm_nheads, cfg.ssm_ngroups)[:, 0]
    x = x[:, 0]                                                     # [B,h,p]
    dtv = jax.nn.softplus(dtv[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))       # [B,h]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dtv * A[None, :])                                  # [B,h]
    st = cache["state"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtv, x.astype(jnp.float32), B.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", C.astype(jnp.float32), st).astype(dt_c)
    y = y + x * p["D"].astype(dt_c)[None, :, None]
    out = _out(z, y[:, None], p, cfg)
    return out, {"state": st, "conv": new_conv}
