"""Active-mesh context for intra-jit sharding constraints.

XLA SPMD propagation loses the batch sharding through the microbatch
reshape and the layer-scan carry (observed in the dry-run HLO: fully
replicated [B,S,·] activations).  Model/step code calls ``constrain`` at the
seams; outside a mesh context (unit tests, single-device runs) it is a
no-op, so the model code stays backend-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]):
    global _ACTIVE
    _ACTIVE = mesh


def get_active_mesh() -> Optional[Mesh]:
    return _ACTIVE


def batch_axis_names():
    if _ACTIVE is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in _ACTIVE.shape)


def _axes_size(axes) -> int:
    n = 1
    for a in axes:
        n *= _ACTIVE.shape[a]
    return n


def constrain(x, spec: P):
    """with_sharding_constraint against the active mesh (no-op without one)."""
    if _ACTIVE is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_ACTIVE, spec))


def constrain_batch(x, batch_dim: int = 0, vocab_dim: Optional[int] = None,
                    seq_dim: Optional[int] = None):
    """Shard ``batch_dim`` over (pod, data) when divisible; optionally shard
    ``vocab_dim`` (logits) or ``seq_dim`` (Megatron-style sequence-parallel
    residual stream) over model."""
    if _ACTIVE is None:
        return x
    ax = batch_axis_names()
    if not ax or x.shape[batch_dim] % _axes_size(ax):
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = ax if len(ax) > 1 else ax[0]
    for extra in (vocab_dim, seq_dim):
        if extra is not None and "model" in _ACTIVE.shape \
                and x.shape[extra] % _ACTIVE.shape["model"] == 0:
            spec[extra] = "model"
    return constrain(x, P(*spec))
