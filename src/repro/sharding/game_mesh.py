"""Unified multi-device mesh layer for the game engines.

Every data-parallel axis the repo vmaps over — K Monte-Carlo channel
draws, S seeds, and the C×S / C×K benchmark grids — is embarrassingly
parallel: no lane ever reads another lane's state.  This module owns the
single decision of how those axes map onto host devices, replacing the
three ad-hoc helpers that grew in place (``stackelberg.sharding_layout``
/ ``_shard_axis`` and ``fl_round._shard_tree``):

  * ``mesh_1d(d)``          — cached ``("draw",)`` mesh for batch axes
    (K draws, S seeds, serving batches).
  * ``mesh_2d(dc, dk)``     — cached ``("cfg", "draw")`` mesh for sweep
    grids; ``grid_layout`` picks the device factorization that minimizes
    padded cells.
  * ``pad_axis``/``padded_size`` — remainder padding by edge replication:
    a non-divisible axis is padded with copies of its last valid lane
    (real, well-posed solves) and the caller slices the pad back off.
    Serving buckets instead reuse the PR-6 masked dummy-row fill — there
    the pad is *masked*, not sliced, because the batch shape is fixed.
  * ``put_batch``/``put_grid`` — ``device_put`` placement with the
    matching ``NamedSharding`` so hot dispatch loops skip the implicit
    host→mesh reshard.

Execution uses ``jax.experimental.shard_map`` (wrapped at the engine jit
sites), NOT bare GSPMD sharding hints: the Alg.-2 alternation is a
vmapped ``lax.while_loop``, and under GSPMD its convergence predicate
becomes a *global* reduction — every iteration synchronizes all devices
(measured 4.3x SLOWER at 4 forced host devices).  ``shard_map`` runs an
independent while_loop per device over its local lanes, which is the
collective-free program the workload actually is.

Single-device processes (``device_count() == 1``) take none of these
paths: ``batch_shards``/``grid_layout`` return 1 / (1, 1) and the engines
run the exact pre-existing program.  Device count can be overridden per
call (arg) or per process (``REPRO_MESH_DEVICES``); forcing more than
one *host* device needs ``--xla_force_host_platform_device_count`` in
``XLA_FLAGS`` before jax import (``benchmarks/common.py --devices N``
re-execs with it set).

Caches are keyed on the live ``len(jax.devices())`` so a device-count
change inside one process (monkeypatched tests, forced-device harness)
never returns a stale mesh; ``clear_cache()`` drops them explicitly.
"""
from __future__ import annotations

import os
from functools import lru_cache
from typing import Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

CFG_AXIS = "cfg"    # config axis of sweep grids (C points)
DRAW_AXIS = "draw"  # Monte-Carlo / seed / batch axis (K, S, B)


# ---------------------------------------------------------------------------
# device count + cached meshes
# ---------------------------------------------------------------------------
def device_count(override: int | None = None) -> int:
    """Devices to shard over: explicit arg > ``REPRO_MESH_DEVICES`` env >
    all visible devices (clamped to [1, len(jax.devices())])."""
    if override is not None:
        n = int(override)
    else:
        n = int(os.environ.get("REPRO_MESH_DEVICES", "0") or "0")
    avail = len(jax.devices())
    if n <= 0:
        n = avail
    return max(1, min(n, avail))


@lru_cache(maxsize=32)
def _mesh_1d(n_dev: int, avail: int) -> Mesh:
    del avail  # cache key only — guards against device-count changes
    return Mesh(np.asarray(jax.devices()[:n_dev]), (DRAW_AXIS,))


def mesh_1d(n_dev: int) -> Mesh:
    """Cached ``("draw",)`` mesh over the first ``n_dev`` devices."""
    return _mesh_1d(n_dev, len(jax.devices()))


@lru_cache(maxsize=32)
def _mesh_2d(dc: int, dk: int, avail: int) -> Mesh:
    del avail
    devs = np.asarray(jax.devices()[:dc * dk]).reshape(dc, dk)
    return Mesh(devs, (CFG_AXIS, DRAW_AXIS))


def mesh_2d(dc: int, dk: int) -> Mesh:
    """Cached ``("cfg", "draw")`` mesh: dc × dk devices."""
    return _mesh_2d(dc, dk, len(jax.devices()))


def clear_cache() -> None:
    """Drop every cached mesh/layout (forced-device harness hook)."""
    _mesh_1d.cache_clear()
    _mesh_2d.cache_clear()
    _layout_1d.cache_clear()


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------
@lru_cache(maxsize=256)
def _layout_1d(k: int, n_dev: int) -> int:
    if n_dev <= 1 or k <= 0:
        return 1
    return max(d for d in range(1, n_dev + 1) if k % d == 0)


def layout_1d(k: int) -> int:
    """Largest divisor of ``k`` within the available device count (1 ⇒
    single-device fallback) — the legacy no-padding layout, kept for the
    placement helpers and bench reporting.  Keyed on the live device
    count, so an in-process device change never hits a stale entry."""
    return _layout_1d(int(k), len(jax.devices()))


def batch_shards(k: int, n_dev: int | None = None) -> int:
    """Shard count for a padded batch axis of logical size ``k``: all
    devices, clamped so no shard is empty (k < devices ⇒ k shards)."""
    if k <= 0:
        return 1
    return max(1, min(device_count(n_dev), int(k)))


def grid_layout(c: int, k: int, n_dev: int | None = None) -> Tuple[int, int]:
    """Factor the device count into ``(dc, dk)`` over a C×K grid,
    minimizing padded cells (``ceil(c/dc)·dc × ceil(k/dk)·dk``); ties
    break toward larger ``dk`` (draw-axis sharding first, matching the
    1D Monte-Carlo layout).  (1, 1) ⇒ single-device fallback."""
    n = device_count(n_dev)
    if n <= 1 or c <= 0 or k <= 0:
        return (1, 1)
    best_key, best = None, (1, 1)
    for dc in range(1, n + 1):
        if n % dc:
            continue
        dk = n // dc
        cells = (-(-c // dc) * dc) * (-(-k // dk) * dk)
        key = (cells, -dk)
        if best_key is None or key < best_key:
            best_key, best = key, (dc, dk)
    return best


def padded_size(k: int, shards: int) -> int:
    """Smallest multiple of ``shards`` ≥ ``k``."""
    return -(-int(k) // int(shards)) * int(shards)


# ---------------------------------------------------------------------------
# remainder padding (edge replication)
# ---------------------------------------------------------------------------
def pad_axis(x, axis: int, to_size: int):
    """Pad ``axis`` up to ``to_size`` by replicating the last valid slice
    (padded lanes are real, well-posed problem instances; callers slice
    them off the output).  No-op when already large enough."""
    x = jnp.asarray(x)
    pad = to_size - x.shape[axis]
    if pad <= 0:
        return x
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(-1, None)
    edge = jnp.repeat(x[tuple(idx)], pad, axis=axis)
    return jnp.concatenate([x, edge], axis=axis)


def pad_tree(tree, axis: int, to_size: int):
    """``pad_axis`` over every leaf of a pytree."""
    return jax.tree_util.tree_map(lambda x: pad_axis(x, axis, to_size), tree)


# ---------------------------------------------------------------------------
# device placement
# ---------------------------------------------------------------------------
def put_axis(arrays: Sequence, axis: int, size: int) -> tuple:
    """Legacy GSPMD placement: device_put each array with its size-``size``
    axis ``axis`` split over ``layout_1d(size)`` devices (no padding —
    only exact divisors shard; no-op on one device).  The shard_map
    engines use ``put_batch``/``put_grid`` instead."""
    n_dev = layout_1d(size)
    if n_dev <= 1:
        return tuple(arrays)
    ns = NamedSharding(mesh_1d(n_dev),
                       PartitionSpec(*([None] * axis), DRAW_AXIS))
    return tuple(jax.device_put(a, ns)
                 if a.ndim > axis and a.shape[axis] == size else a
                 for a in arrays)


def put_batch(arrays: Sequence, axis: int, shards: int) -> tuple:
    """device_put each array with axis ``axis`` (already padded to a
    multiple of ``shards``) split over the 1D draw mesh."""
    if shards <= 1:
        return tuple(arrays)
    ns = NamedSharding(mesh_1d(shards),
                       PartitionSpec(*([None] * axis), DRAW_AXIS))
    return tuple(jax.device_put(a, ns) for a in arrays)


def put_tree(tree, axis: int, shards: int):
    """``put_batch`` over every leaf of a pytree (leaves lacking the axis
    pass through untouched)."""
    if shards <= 1:
        return tree
    ns = NamedSharding(mesh_1d(shards),
                       PartitionSpec(*([None] * axis), DRAW_AXIS))
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, ns)
        if getattr(x, "ndim", 0) > axis and x.shape[axis] % shards == 0
        else x, tree)


def put_grid(arrays: Sequence, shards: Tuple[int, int]) -> tuple:
    """device_put each ``[C, K, ...]`` array over the 2D (cfg, draw) mesh
    (axes already padded to multiples of ``shards``)."""
    dc, dk = shards
    if dc * dk <= 1:
        return tuple(arrays)
    ns = NamedSharding(mesh_2d(dc, dk), PartitionSpec(CFG_AXIS, DRAW_AXIS))
    return tuple(jax.device_put(a, ns) for a in arrays)


def put_grid_tree(tree, shards: Tuple[int, int], cfg_only: bool = False):
    """Grid placement for pytrees: leaves get ``P(cfg, draw)`` on their
    two leading axes, or ``P(cfg)`` when ``cfg_only`` (per-config stacks
    such as ``GamePhysics``/``FLOps`` whose leaves are [C]-leading)."""
    dc, dk = shards
    if dc * dk <= 1:
        return tree
    mesh = mesh_2d(dc, dk)
    spec = (PartitionSpec(CFG_AXIS) if cfg_only
            else PartitionSpec(CFG_AXIS, DRAW_AXIS))

    def put(x):
        if getattr(x, "ndim", 0) < len(spec):
            return x
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree)
