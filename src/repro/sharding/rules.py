"""Logical-axis → mesh-axis sharding rules.

Scheme (DESIGN.md §5): 2D "FSDP × TP" — weights sharded over BOTH the
``data`` axis (FSDP dim) and the ``model`` axis (TP dim); batch over
(``pod``, ``data``).  XLA SPMD inserts the per-layer all-gathers.

Rules are name-based on the *trailing* dims of each leaf; extra leading dims
(the scan group axis G, the 2-stack of Zamba2 shared blocks) are padded with
``None``.  Dims not divisible by the mesh-axis size fall back per rule (e.g.
KV heads < model size shard head_dim instead — GQA fallback).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    axes = axis if isinstance(axis, tuple) else (axis,)
    total = 1
    for a in axes:
        if a not in mesh.shape:
            return False
        total *= mesh.shape[a]
    return n % total == 0


def default_fsdp_axis(mesh: Mesh):
    """FSDP dim spans (pod, data) when a pod axis exists — sharding the
    340B-class parameter/optimizer state across pods instead of
    replicating it (§Perf iter-5)."""
    return ("pod", "data") if "pod" in mesh.shape else "data"


def _attn_kv_spec(shape, mesh, fsdp) -> P:
    """wk/wv [D, KV, hd]: shard KV if divisible, else head_dim."""
    if _div(shape[1], mesh, "model"):
        return P(fsdp, "model", None)
    if _div(shape[2], mesh, "model"):
        return P(fsdp, None, "model")
    return P(fsdp, None, None)


def _rule(name: str, shape, mesh: Mesh, fsdp: Optional[str]) -> P:
    md = "model" if "model" in mesh.shape else None
    if name in ("embedding", "unembed"):
        # [V, D] / [D, V] — vocab over model
        big = 0 if shape[0] > shape[1] else 1
        spec = [fsdp, fsdp]
        spec[big] = md if _div(shape[big], mesh, "model") else None
        return P(*spec)
    if name == "wq":
        return P(fsdp, md if _div(shape[1], mesh, "model") else None, None)
    if name in ("wk", "wv"):
        return _attn_kv_spec(shape, mesh, fsdp)
    if name == "wo":
        return P(md if _div(shape[0], mesh, "model") else None, None, fsdp)
    if name in ("w_in", "w_gate"):
        if len(shape) == 3:   # moe [E, D, F]
            if _div(shape[0], mesh, "model"):
                return P("model", fsdp, None)
            return P(None, fsdp, md if _div(shape[2], mesh, "model") else None)
        return P(fsdp, md if _div(shape[1], mesh, "model") else None)
    if name == "w_out":
        if len(shape) == 3:   # moe [E, F, D]
            if _div(shape[0], mesh, "model"):
                return P("model", None, fsdp)
            return P(None, md if _div(shape[1], mesh, "model") else None, fsdp)
        return P(md if _div(shape[0], mesh, "model") else None, fsdp)
    if name == "router":
        return P(fsdp, None)
    if name == "in_proj":      # mamba [D, Z]
        return P(fsdp, md if _div(shape[1], mesh, "model") else None)
    if name == "out_proj":     # mamba [din, D]
        return P(md if _div(shape[0], mesh, "model") else None, fsdp)
    if name in ("conv_w", "conv_b"):
        return P(*([None] * (len(shape) - 1)
                   + [md if _div(shape[-1], mesh, "model") else None]))
    if name in ("A_log", "D", "dt_bias", "out_norm"):
        return P(md if _div(shape[-1], mesh, "model") else None)
    # norms / scales / biases / classifier leaves: replicate
    return P(*([None] * len(shape)))


def _path_keys(path):
    out = []
    for k in path:
        kk = getattr(k, "key", None)
        if kk is None:
            kk = getattr(k, "idx", k)
        out.append(kk)
    return out


def param_spec(path, leaf, mesh: Mesh, fsdp_axis: Optional[str] = "data") -> P:
    """Spec for one leaf given its tree path (tuple of keys).

    Leaves under "entries"/"shared"/"encoder" stacks carry one leading
    group/stack dim which the name-based rules must not see — it is stripped
    before rule lookup and re-padded with ``None``."""
    keys = _path_keys(path)
    name = next((k for k in reversed(keys) if isinstance(k, str)), "")
    stacked = any(k in ("entries", "shared") for k in keys)
    shape = leaf.shape
    core = shape[1:] if (stacked and len(shape) > 1) else shape
    base = _rule(name, core, mesh, fsdp_axis)
    pad = len(shape) - len(base)
    if pad >= 0:
        base = P(*([None] * pad + list(base)))
    else:  # rule longer than actual rank (e.g. scalar) — replicate
        base = P(*([None] * len(shape)))
    # divisibility guard: drop axes that don't divide the dim evenly
    fixed = [ax if _div(dim, mesh, ax) else None
             for dim, ax in zip(shape, tuple(base))]
    return P(*fixed)


def params_shardings(params, mesh: Mesh, fsdp_axis="auto"):
    """NamedSharding tree matching ``params``.  fsdp_axis: "auto" (span
    (pod, data)), an explicit axis/tuple, or None for TP-only layouts."""
    if fsdp_axis == "auto":
        fsdp_axis = default_fsdp_axis(mesh)
    def spec(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf, mesh, fsdp_axis))
    return jax.tree_util.tree_map_with_path(spec, params)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    """Mesh axes carrying the batch dim."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, ndim: int = 2) -> P:
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def batch_shardings(batch, mesh: Mesh):
    def spec(leaf):
        b = leaf.shape[0]
        ax = batch_axes(mesh)
        total = 1
        for a in ax:
            total *= mesh.shape[a]
        if b % total == 0:
            return NamedSharding(mesh, P(ax, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))
    return jax.tree_util.tree_map(spec, batch)


def cache_spec(path, leaf, mesh: Mesh) -> P:
    """KV/SSM cache sharding: batch over data(+pod), heads/channels over model.

    Trailing-dim layouts:
      attn k/v   [..., B, C, KV, hd]
      ssm state  [..., B, H, P, N]
      ssm conv   [..., B, cw-1, channels]
    """
    name = None
    for k in reversed(path):
        kk = getattr(k, "key", getattr(k, "idx", k))
        if isinstance(kk, str):
            name = kk
            break
    ax = batch_axes(mesh)
    shape = leaf.shape
    if name in ("k_scale", "v_scale") and len(shape) >= 3:
        # int8-KV scales [..., B, C, KV]: batch over data, else seq
        b_ax = _bd(shape[-3], mesh, ax)
        seq_ax = None if b_ax is not None else _bd(shape[-2], mesh, ax)
        base = [b_ax, seq_ax, None]
    elif name in ("k", "v", "xk", "xv") and len(shape) >= 4:
        kv, hd = shape[-2], shape[-1]
        head_ax = "model" if _div(kv, mesh, "model") else None
        b_ax = _bd(shape[-4], mesh, ax)
        # batch=1 long-context decode: shard the cache SEQ dim over the data
        # axes instead (§Perf: gemma2/zamba2 long_500k KV residency)
        seq_ax = None if b_ax is not None else _bd(shape[-3], mesh, ax)
        if head_ax is None and seq_ax is None and _div(shape[-3], mesh, "model"):
            # GQA kv-heads don't divide TP: flash-decoding-style seq-sharding
            # over model beats hd-sharding (which all-reduces the scores)
            seq_ax = "model"
        model_used = (head_ax == "model") or (seq_ax == "model")
        hd_ax = "model" if (not model_used and _div(hd, mesh, "model")) \
            else None
        base = [b_ax, seq_ax, head_ax, hd_ax]
    elif name == "state" and len(shape) >= 4:
        base = [_bd(shape[-4], mesh, ax),
                "model" if _div(shape[-3], mesh, "model") else None, None, None]
    elif name == "conv" and len(shape) >= 3:
        base = [_bd(shape[-3], mesh, ax), None,
                "model" if _div(shape[-1], mesh, "model") else None]
    else:
        base = [None] * len(shape)
    pad = len(shape) - len(base)
    return P(*([None] * pad + base))


def _bd(b: int, mesh: Mesh, ax):
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    return ax if (total and b % total == 0) else None


def cache_shardings(caches, mesh: Mesh):
    def spec(path, leaf):
        return NamedSharding(mesh, cache_spec(path, leaf, mesh))
    return jax.tree_util.tree_map_with_path(spec, caches)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
