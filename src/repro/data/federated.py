"""Federated data partitioning: IID / non-IID splits + label-flip poisoning
(paper §VI protocol).

IID    : labels identically distributed across clients, sizes vary.
non-IID: each client holds ``labels_per_client`` classes (paper: 1 for MNIST,
         5 for CIFAR-10).
Poison : a fraction of clients flip labels y → (9 − y) on their LOCAL
         training data (attack on model updates; the DT-mapped copies carry
         true labels, since DT mapping reflects raw insensitive data).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .synthetic import NUM_CLASSES, ImageProxySpec, class_means


@dataclass
class FedData:
    x: jax.Array            # [M, cap, dim]
    y: jax.Array            # [M, cap] true labels
    y_train: jax.Array      # [M, cap] labels used for local training (may be flipped)
    mask: jax.Array         # [M, cap] bool — valid sample slots
    sizes: jax.Array        # [M] float — D_n
    poisoned: jax.Array     # [M] bool
    x_val: jax.Array        # [V, dim] clean validation set (server-held)
    y_val: jax.Array        # [V]

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]


# pytree registration: the dataset crosses the jit boundary of the scanned
# FL trajectory (fl_round.run_training_scan) as a traced operand, and
# batched_training may carry a leading seed axis on every leaf.
jax.tree_util.register_dataclass(
    FedData, data_fields=tuple(f.name for f in dataclasses.fields(FedData)),
    meta_fields=())


def make_federated_data(key, spec: ImageProxySpec, m: int = 20,
                        cap: int = 256, min_frac: float = 0.4,
                        iid: bool = True, labels_per_client: int = 1,
                        poison_ratio: float = 0.0, val_size: int = 512) -> FedData:
    ks = jax.random.split(key, 8)
    mu = class_means(ks[0], spec)

    sizes = (min_frac + (1 - min_frac) * jax.random.uniform(ks[1], (m,)))
    sizes = jnp.floor(sizes * cap).astype(jnp.int32)
    slot = jnp.arange(cap)[None, :]
    mask = slot < sizes[:, None]

    if iid:
        y = jax.random.randint(ks[2], (m, cap), 0, NUM_CLASSES)
    else:
        # each client draws labels from its own small class subset
        base = jax.random.randint(ks[2], (m, labels_per_client), 0, NUM_CLASSES)
        pick = jax.random.randint(ks[3], (m, cap), 0, labels_per_client)
        y = jnp.take_along_axis(base, pick, axis=1)

    noise = spec.noise * jax.random.normal(ks[4], (m, cap, spec.dim))
    x = mu[y] + noise

    n_poison = int(round(poison_ratio * m))
    poisoned = jnp.zeros((m,), bool)
    if n_poison:
        idx = jax.random.permutation(ks[5], m)[:n_poison]
        poisoned = poisoned.at[idx].set(True)
    y_train = jnp.where(poisoned[:, None], (NUM_CLASSES - 1) - y, y)

    yv = jax.random.randint(ks[6], (val_size,), 0, NUM_CLASSES)
    xv = mu[yv] + spec.noise * jax.random.normal(ks[7], (val_size, spec.dim))
    return FedData(x=x, y=y, y_train=y_train, mask=mask,
                   sizes=sizes.astype(jnp.float32), poisoned=poisoned,
                   x_val=xv, y_val=yv)
