"""Federated data partitioning: IID / non-IID splits + label-flip poisoning
(paper §VI protocol).

IID    : labels identically distributed across clients, sizes vary.
non-IID: each client holds ``labels_per_client`` classes (paper: 1 for MNIST,
         5 for CIFAR-10).
Poison : a fraction of clients flip labels y → (9 − y) on their LOCAL
         training data (attack on model updates; the DT-mapped copies carry
         true labels, since DT mapping reflects raw insensitive data).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .synthetic import NUM_CLASSES, ImageProxySpec, class_means


@dataclass
class FedData:
    x: jax.Array            # [M, cap, dim]
    y: jax.Array            # [M, cap] true labels
    y_train: jax.Array      # [M, cap] labels used for local training (may be flipped)
    mask: jax.Array         # [M, cap] bool — valid sample slots
    sizes: jax.Array        # [M] float — D_n
    poisoned: jax.Array     # [M] bool
    x_val: jax.Array        # [V, dim] clean validation set (server-held)
    y_val: jax.Array        # [V]

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]


# pytree registration: the dataset crosses the jit boundary of the scanned
# FL trajectory (fl_round.run_training_scan) as a traced operand, and
# batched_training may carry a leading seed axis on every leaf.
jax.tree_util.register_dataclass(
    FedData, data_fields=tuple(f.name for f in dataclasses.fields(FedData)),
    meta_fields=())


def make_federated_data(key, spec: ImageProxySpec, m: int = 20,
                        cap: int = 256, min_frac: float = 0.4,
                        iid: bool = True, labels_per_client: int = 1,
                        poison_ratio: float = 0.0, val_size: int = 512) -> FedData:
    ks = jax.random.split(key, 8)
    mu = class_means(ks[0], spec)

    sizes = (min_frac + (1 - min_frac) * jax.random.uniform(ks[1], (m,)))
    sizes = jnp.floor(sizes * cap).astype(jnp.int32)
    slot = jnp.arange(cap)[None, :]
    mask = slot < sizes[:, None]

    if iid:
        y = jax.random.randint(ks[2], (m, cap), 0, NUM_CLASSES)
    else:
        # each client draws labels from its own small class subset
        base = jax.random.randint(ks[2], (m, labels_per_client), 0, NUM_CLASSES)
        pick = jax.random.randint(ks[3], (m, cap), 0, labels_per_client)
        y = jnp.take_along_axis(base, pick, axis=1)

    noise = spec.noise * jax.random.normal(ks[4], (m, cap, spec.dim))
    x = mu[y] + noise

    n_poison = int(round(poison_ratio * m))
    poisoned = jnp.zeros((m,), bool)
    if n_poison:
        idx = jax.random.permutation(ks[5], m)[:n_poison]
        poisoned = poisoned.at[idx].set(True)
    y_train = jnp.where(poisoned[:, None], (NUM_CLASSES - 1) - y, y)

    yv = jax.random.randint(ks[6], (val_size,), 0, NUM_CLASSES)
    xv = mu[yv] + spec.noise * jax.random.normal(ks[7], (val_size, spec.dim))
    return FedData(x=x, y=y, y_train=y_train, mask=mask,
                   sizes=sizes.astype(jnp.float32), poisoned=poisoned,
                   x_val=xv, y_val=yv)


def make_sybil_data(key, data: FedData, pool: int) -> FedData:
    """Plant a sybil pool: ONE attacker's dataset split across ``pool``
    colluding client identities (fault-engine taxonomy, `repro.core.faults`).

    The adversary controls one data hoard but registers ``pool`` client
    IDs, giving each an equal 1/pool slice with flipped training labels.
    Each identity is individually small (low AC term in Eq. 16) and RONI
    NI verdicts land on ONE identity at a time, so the PI bookkeeping that
    sinks a monolithic attacker is diluted across the pool.

    The sybils replace the first ``pool`` client slots of ``data`` (which
    should be a CLEAN dataset — existing poisoned flags elsewhere are
    kept).  Returns a new ``FedData``; shapes are unchanged, so it batches
    against clean datasets on the config axis of ``sweep_training``.
    """
    m, cap, dim = data.x.shape
    if not 1 <= pool <= m:
        raise ValueError(f"sybil pool size {pool} must be in [1, {m}]")
    n_classes = int(jnp.max(data.y_val)) + 1

    # the adversary's hoard: one client-sized dataset, drawn fresh so the
    # slices are IID copies of the same source distribution
    share = cap // pool
    k_y, k_n = jax.random.split(key)
    y_hoard = jax.random.randint(k_y, (pool, cap), 0, n_classes)
    # rebuild features around the validation-set geometry: per-class means
    # estimated from the clean val split (the hoard mimics honest data)
    mu = jnp.stack([
        jnp.sum(jnp.where((data.y_val == c)[:, None], data.x_val, 0.0),
                axis=0)
        / jnp.maximum(jnp.sum(data.y_val == c), 1)
        for c in range(n_classes)])
    sigma = jnp.std(data.x_val - mu[data.y_val])
    x_hoard = mu[y_hoard] + sigma * jax.random.normal(k_n, (pool, cap, dim))

    slot = jnp.arange(cap)[None, :]
    sybil_mask = slot < share                        # [1, cap] → broadcasts
    idx = jnp.arange(pool)
    x = data.x.at[idx].set(x_hoard)
    y = data.y.at[idx].set(y_hoard)
    y_train = data.y_train.at[idx].set((n_classes - 1) - y_hoard)
    mask = data.mask.at[idx].set(jnp.broadcast_to(sybil_mask, (pool, cap)))
    sizes = data.sizes.at[idx].set(float(share))
    poisoned = data.poisoned.at[idx].set(True)
    return dataclasses.replace(data, x=x, y=y, y_train=y_train, mask=mask,
                               sizes=sizes, poisoned=poisoned)
