"""Deterministic synthetic datasets.

Offline container ⇒ MNIST/CIFAR-10 are replaced by *synthetic proxies* with
matched metadata (10 classes, comparable dimensionality, controllable
difficulty).  The FL phenomena the paper measures — poisoning damage,
selection-scheme separation, IID/non-IID gaps, DT-deviation sensitivity —
are distribution-level effects that reproduce on these proxies (DESIGN.md §6).

Also provides the synthetic LM token stream used by the training examples
and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NUM_CLASSES = 10


@dataclass(frozen=True)
class ImageProxySpec:
    name: str
    dim: int
    class_sep: float       # distance between class means (difficulty knob)
    noise: float


SYNTHETIC_MNIST = ImageProxySpec("synthetic-mnist", dim=784, class_sep=6.0,
                                 noise=1.0)
SYNTHETIC_CIFAR = ImageProxySpec("synthetic-cifar", dim=768, class_sep=2.5,
                                 noise=1.0)


def class_means(key, spec: ImageProxySpec):
    mu = jax.random.normal(key, (NUM_CLASSES, spec.dim))
    return spec.class_sep * mu / jnp.linalg.norm(mu, axis=1, keepdims=True)


def sample_images(key, spec: ImageProxySpec, n: int, labels=None):
    """Class-conditional Gaussians: x = μ_y + noise·g."""
    k1, k2, k3 = jax.random.split(key, 3)
    mu = class_means(k1, spec)
    if labels is None:
        labels = jax.random.randint(k2, (n,), 0, NUM_CLASSES)
    x = mu[labels] + spec.noise * jax.random.normal(k3, (n, spec.dim))
    return x, labels


# ---------------------------------------------------------------------------
# synthetic LM stream
# ---------------------------------------------------------------------------
def lm_token_batch(key, batch: int, seq_len: int, vocab: int):
    """Deterministic pseudo-text: Zipf-ish marginals + local repetition
    structure so a model can actually reduce loss."""
    k1, k2, k3 = jax.random.split(key, 3)
    # zipf via exponential quantization
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6, maxval=1.0)
    zipf = jnp.minimum((1.0 / u ** 0.7).astype(jnp.int32), vocab - 1)
    # structure: with prob .5 copy the token 2 positions back
    copy = jax.random.bernoulli(k2, 0.5, (batch, seq_len))
    toks = zipf
    rolled = jnp.roll(toks, 2, axis=1)
    toks = jnp.where(copy, rolled, toks)
    return toks


def lm_example_stream(key, batch: int, seq_len: int, vocab: int):
    """Infinite generator of (tokens, targets) next-token batches."""
    i = 0
    while True:
        k = jax.random.fold_in(key, i)
        toks = lm_token_batch(k, batch, seq_len + 1, vocab)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        i += 1
