"""Host-sharded batching pipeline for LM training.

Single-host here, but structured the way a multi-host input pipeline is:
each host draws the deterministic per-step key, generates/loads only its
``process_index`` slice of the global batch, and the arrays are laid out to
match the (pod, data) batch sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp

from .synthetic import lm_token_batch


@dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def host_batch_slice(cfg: PipelineConfig) -> tuple[int, int]:
    """(start, size) of this host's slice of the global batch."""
    n = jax.process_count()
    i = jax.process_index()
    per = cfg.global_batch // n
    return i * per, per


def lm_batches(cfg: PipelineConfig) -> Iterator[dict]:
    key = jax.random.PRNGKey(cfg.seed)
    start, per = host_batch_slice(cfg)
    step = 0
    while True:
        k = jax.random.fold_in(jax.random.fold_in(key, step), start)
        toks = lm_token_batch(k, per, cfg.seq_len + 1, cfg.vocab_size)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        step += 1
