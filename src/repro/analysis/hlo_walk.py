"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — see EXPERIMENTS.md §Measurement notes), which under-counts the
layer-group and microbatch ``lax.scan`` loops by their trip counts.  This
walker parses the optimized post-SPMD HLO, builds the computation call graph,
and multiplies each while body's costs by its ``known_trip_count``
backend-config annotation, producing corrected per-device totals:

  * ``flops``            — dot/convolution FLOPs (elementwise excluded; the
                           models here are matmul-dominated)
  * ``hbm_bytes``        — Σ over fusions/instructions of operand+result
                           bytes (a standard HBM-traffic model: each fused
                           kernel reads its operands and writes its result)
  * ``collective_bytes`` — per-device operand bytes of all-gather /
                           all-reduce / reduce-scatter / all-to-all /
                           collective-permute, by type
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# instruction kinds that move no HBM bytes on their own
_FREE = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
         "iota", "after-all", "partition-id", "replica-id", "custom-call"}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_list_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        eq = s.find(" = ")
        if eq < 0:
            continue
        name = s[:eq].strip().lstrip("%")
        rhs = s[eq + 3:]
        # rhs: "<type> <op>(<args...>), attrs..."
        m = re.match(r"((?:\([^)]*\)|[\w\[\],{}]+)+)\s+([\w\-]+)", rhs)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        cur.instrs.append(Instr(name, type_str, op, rhs))
    return comps


def _dot_flops(instr: Instr, types: Dict[str, str]) -> int:
    """2 × prod(result dims) × prod(contracted lhs dims)."""
    res_dims = _shape_dims(instr.type_str) or []
    # operand lists print as "f32[64,64]{1,0} %name" — strip the type prefix
    # via _operand_names, else the types lookup misses and the contracted
    # dim silently degrades to 1 (8192 instead of 524288 flops per 64³ dot)
    operands = _operand_names(instr)
    lhs = operands[0] if operands else None
    lhs_type = types.get(lhs, "")
    lhs_dims = _shape_dims(lhs_type) or []
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contract = 1
    if cm and lhs_dims:
        for d in cm.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    n = 1
    for d in res_dims:
        n *= d
    return 2 * n * contract


def _conv_flops(instr: Instr, types: Dict[str, str]) -> int:
    res_dims = _shape_dims(instr.type_str) or []
    operands = _operand_names(instr)
    if len(operands) < 2:
        return 0
    k_dims = _shape_dims(types.get(operands[1], "")) or []
    n = 1
    for d in res_dims:
        n *= d
    kn = 1
    for d in k_dims[:-1]:
        kn *= d
    return 2 * n * kn


def _operand_names(instr: Instr) -> List[str]:
    m = re.search(r"\(([^)]*)\)", instr.rest)
    if not m:
        return []
    # operands print as "f32[64,64]{1,0} %name": the dims commas break a
    # naive split(","), so pull the %-prefixed references directly
    names = re.findall(r"%([\w.\-]+)", m.group(1))
    if names:
        return names
    # printers that omit the '%' sigil: drop dims/layout groups first so the
    # remaining commas are real operand separators, then take the name token
    bare = re.sub(r"\[[^\]]*\]|\{[^}]*\}", "", m.group(1))
    return [a.strip().split(" ")[-1] for a in bare.split(",") if a.strip()]


def _trip_count(instr: Instr) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.rest)
    return int(m.group(1)) if m else 1


def _called(instr: Instr) -> List[str]:
    out = []
    for key in ("body", "condition", "to_apply", "calls",
                "true_computation", "false_computation"):
        for m in re.finditer(rf"{key}=%?([\w.\-]+)", instr.rest):
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", instr.rest)
    if m:
        out += [c.strip().lstrip("%") for c in m.group(1).split(",")]
    return out


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[str, dict] = {}

    def _comp_types(self, comp: Computation) -> Dict[str, str]:
        return {i.name: i.type_str for i in comp.instrs}

    def comp_cost(self, name: str, skip_fusion_interior: bool = True) -> dict:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return {"flops": 0, "hbm_bytes": 0,
                    "collectives": defaultdict(int)}
        types = self._comp_types(comp)
        flops = 0
        hbm = 0
        coll: Dict[str, int] = defaultdict(int)
        self._memo[name] = {"flops": 0, "hbm_bytes": 0, "collectives": coll}
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += _dot_flops(ins, types)
            elif ins.op == "convolution":
                flops += _conv_flops(ins, types)
            base_op = ins.op.replace("-start", "")
            if base_op in COLLECTIVES:
                ob = sum(_shape_list_bytes(types.get(o, ""))
                         for o in _operand_names(ins))
                coll[base_op] += ob
            if ins.op not in _FREE and not ins.op.endswith("-done"):
                hbm += _shape_list_bytes(ins.type_str)
                hbm += sum(_shape_list_bytes(types.get(o, ""))
                           for o in _operand_names(ins))
            # recurse into called computations (fusion interiors excluded
            # from HBM but dots inside fusions still count as flops)
            mult = _trip_count(ins) if ins.op == "while" else 1
            for sub_name in _called(ins):
                sub = self.comp_cost(sub_name)
                flops += mult * sub["flops"]
                hbm += mult * sub["hbm_bytes"] if ins.op != "fusion" else 0
                for k, v in sub["collectives"].items():
                    coll[k] += mult * v
        out = {"flops": flops, "hbm_bytes": hbm, "collectives": dict(coll)}
        self._memo[name] = out
        return out

    def entry_cost(self) -> dict:
        return self.comp_cost(self.comps["__entry__"].name)
