"""Pallas TPU kernel: Mamba2 SSD chunked scan (arXiv:2405.21060 §6).

TPU adaptation (DESIGN.md §3): the GPU reference splits the SSD into
chunk-parallel matmuls + an inter-chunk recurrence launched as separate
kernels.  On TPU we fuse both into ONE kernel using the sequential-grid
property of Pallas/Mosaic: the grid's last dimension iterates chunks in
order ("arbitrary" dimension semantics), carrying the running SSM state in a
VMEM scratch accumulator — no HBM round-trip for the recurrence, and every
matmul is MXU-shaped ([L×N]·[N×P] with L,P,N multiples of 64/128).

Per (batch·head, chunk) block:
    dA       = dt ⊙ A                       [L]
    y_diag   = ((C Bᵀ) ∘ L(decay)) (dt ⊙ x) [L,P]   (intra-chunk, MXU)
    y_off    = (C ⊙ exp(cumsum dA)) · state [L,P]   (inter-chunk read)
    state    = state·exp(Σ dA) + Bᵀ·(decay dt x)    (carried in VMEM scratch)

Layouts: x [BH, S, P], dt [BH, S], B/C [BH, S, N]  (heads pre-flattened into
the leading dim; ngroups expanded by the wrapper).  f32 accumulation
throughout; inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, o_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    l = chunk
    x = x_ref[0].astype(jnp.float32)          # [L, P]
    dt = dt_ref[0].astype(jnp.float32)        # [L]
    b = b_ref[0].astype(jnp.float32)          # [L, N]
    c = c_ref[0].astype(jnp.float32)          # [L, N]
    a = a_ref[0]                              # scalar A (negative)

    da = dt * a                               # [L]
    da_cs = jnp.cumsum(da)                    # [L]

    # intra-chunk: gate[i,j] = exp(cs_i - cs_j)·dt_j for i ≥ j
    seg = da_cs[:, None] - da_cs[None, :]     # [L, L]
    causal = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    gate = jnp.where(causal, jnp.exp(seg) * dt[None, :], 0.0)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # [L, L]
    y = jnp.dot(cb * gate, x, preferred_element_type=jnp.float32)

    # inter-chunk: read carried state
    state = state_ref[...]                    # [N, P]
    y += jnp.dot(c * jnp.exp(da_cs)[:, None], state,
                 preferred_element_type=jnp.float32)

    # state update: state' = state·exp(Σda) + Σ_j decay_j dt_j B_j x_jᵀ
    decay = jnp.exp(da_cs[-1] - da_cs)        # [L]
    state_ref[...] = state * jnp.exp(da_cs[-1]) + jnp.dot(
        (b * (decay * dt)[:, None]).T, x, preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, b, c, chunk: int = 128, interpret: bool = True):
    """x: [BH, S, P], dt: [BH, S], a: [BH], b/c: [BH, S, N] -> y like x.

    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (bh, nc)

    kern = functools.partial(_ssd_kernel, chunk=chunk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),                    # a
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),      # x
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),            # dt
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),      # b
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),      # c
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, x, dt, b, c)
