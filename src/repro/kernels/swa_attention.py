"""Pallas TPU kernel: causal flash attention with sliding-window support.

Used by the gemma2/gemma3 local layers and the long-context decode variants
(DESIGN.md §4).  TPU adaptation of flash attention:

  * grid = (batch·heads, q_blocks, k_blocks) — the k dimension is the
    innermost sequential ("arbitrary") dimension; online-softmax statistics
    (m, l) and the output accumulator live in VMEM scratch across k steps.
  * sliding window: for window W the k grid has only (W + Lq)/Lk blocks per
    q block, and the k BlockSpec index-map slides with the q index —
    true O(S·W) work instead of O(S²) (GPU implementations get this by
    early-exiting thread blocks; on TPU we shape the grid instead).
  * blocks are 128×128 — MXU-aligned; VMEM per step ≈ q,k,v,acc blocks
    = 4·128·head_dim·4B ≲ 0.5 MB, well under the ~16 MB VMEM budget.

Validated in interpret mode against ``ref.swa_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK = -1.0e30
M_INIT = -0.5e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                lq: int, lk: int, nk: int, window: int, softcap: float,
                scale: float):
    qi = pl.program_id(1)
    kr = pl.program_id(2)

    @pl.when(kr == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [Lq, D]
    k = k_ref[0].astype(jnp.float32)          # [Lk, D]
    v = v_ref[0].astype(jnp.float32)          # [Lk, D]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    if window > 0:
        kb = qi - (nk - 1) + kr               # true (unclamped) k block
    else:
        kb = kr
    qpos = qi * lq + jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 0)
    kpos = kb * lk + jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 1)
    ok = (kpos <= qpos) & (kb >= 0)
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, MASK)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(kr == nk - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "softcap", "block", "interpret"))
def swa_attention_pallas(q, k, v, window: int = 0, softcap: float = 0.0,
                         block: int = 128, interpret: bool = True):
    """q/k/v: [BH, S, D] -> [BH, S, D]; causal, optional sliding window.

    window must be a multiple of ``block`` (or 0 = global causal).
    """
    bh, s, d = q.shape
    assert s % block == 0, (s, block)
    nq = s // block
    if window > 0:
        assert window % block == 0, (window, block)
        nk = min(nq, window // block + 1)
    else:
        nk = nq

    def k_index(i, qi, kr):
        if window > 0:
            return (i, jnp.maximum(qi - (nk - 1) + kr, 0), 0)
        return (i, kr, 0)

    kern = functools.partial(_swa_kernel, lq=block, lk=block, nk=nk,
                             window=window, softcap=softcap,
                             scale=1.0 / (d ** 0.5))
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block, d), lambda i, qi, kr: (i, qi, 0)),
            pl.BlockSpec((1, block, d), k_index),
            pl.BlockSpec((1, block, d), k_index),
        ],
        out_specs=pl.BlockSpec((1, block, d), lambda i, qi, kr: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block,), jnp.float32),      # m
            pltpu.VMEM((block,), jnp.float32),      # l
            pltpu.VMEM((block, d), jnp.float32),    # acc
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
