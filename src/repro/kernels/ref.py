"""Pure-jnp oracles for the Pallas kernels (the ground truth the kernels are
validated against, shape-for-shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def ssd_scan_ref(x, dt, a, b, c):
    """Sequential SSM recurrence.  x: [BH,S,P], dt: [BH,S], a: [BH],
    b/c: [BH,S,N] -> y [BH,S,P].  O(S) scan — slow but exact."""
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)

    def per_t(state, inp):
        xt, dtt, bt, ct = inp               # [BH,P],[BH],[BH,N],[BH,N]
        da = jnp.exp(dtt * a)               # [BH]
        state = state * da[:, None, None] + jnp.einsum(
            "g,gn,gp->gnp", dtt, bt, xt)
        y = jnp.einsum("gn,gnp->gp", ct, state)
        return state, y

    bh, s, p = x.shape
    n = b.shape[-1]
    init = jnp.zeros((bh, n, p), jnp.float32)
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    _, ys = jax.lax.scan(per_t, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def sic_suffix_ref(w):
    """Exclusive suffix sum along the last axis: s[..., n] = Σ_{j>n} w[..., j]
    — the SIC interference each client sees from later-decoded clients.
    Shift-then-cumsum (NOT inclusive-minus-self, which cancels
    catastrophically when a small w[j] follows a large one — exactly the
    near/far-user power spread SIC ordering produces); any leading dims."""
    rev = jnp.flip(w, -1)
    shifted = jnp.concatenate([jnp.zeros_like(rev[..., :1]), rev[..., :-1]],
                              -1)
    return jnp.flip(jnp.cumsum(shifted, -1), -1)


def swa_attention_ref(q, k, v, window: int = 0, softcap: float = 0.0):
    """Causal (optionally sliding-window) attention.
    q/k/v: [BH, S, D] -> [BH, S, D]."""
    s = q.shape[1]
    scores = jnp.einsum("gqd,gkd->gqk", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = ki <= qi
    if window > 0:
        ok &= (qi - ki) < window
    scores = jnp.where(ok[None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("gqk,gkd->gqd", probs.astype(v.dtype), v)
