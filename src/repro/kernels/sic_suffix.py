"""Pallas kernel: blocked exclusive suffix-sum — the SIC interference scan.

The NOMA SIC power engine (``repro.core.sic``) refreshes, once per Jacobi
sweep, the suffix interference every client sees from later-decoded clients
(paper Eq. 36 denominator):

    s[n] = Σ_{j>n} w[j],         w[j] = p_j · |h_j|²

i.e. an EXCLUSIVE suffix sum along the client axis.  Same fusion idea as
``ssd_scan``: the grid's last dimension walks the N axis in blocks using the
sequential-grid property ("arbitrary" dimension semantics), carrying the
running suffix total in a VMEM scratch accumulator — blocks are visited
right-to-left via a reversed ``index_map``, so the carry entering block b is
exactly the sum of all blocks after it.

Within a block the exclusive suffix sum is one MXU-shaped matmul against a
strictly-lower-triangular ones matrix ([L]·[L×L]: row k contributes to
column i iff k > i) — no flips or cumsums inside the kernel, so the same
body lowers on TPU and runs under interpret mode on CPU.

Layout: w [B, N] → s [B, N]; f32 accumulation; N is zero-padded up to a
block multiple by the wrapper (trailing zeros contribute nothing to any
real element's suffix).

Masked-tail contract (ragged-N serving): the allocation service pads
variable-N requests with zero-gain clients, so w = p·|h|² carries an
all-zero tail BEFORE this wrapper adds its own block padding.  Both tails
compose: a zero element adds exactly 0.0 to the carry and to every
in-block matmul row, so s over the real prefix is bit-identical to the
kernel run on the truncated exact-N input — in f32 this is exact
(x + 0.0 == x), not approximate.  Asserted against ref and interpret
modes in tests/test_sic.py::TestPaddedTail.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _suffix_kernel(w_ref, o_ref, carry_ref, *, block: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    w = w_ref[0].astype(jnp.float32)                      # [L]
    # strict[k, i] = 1 iff k > i : w @ strict == exclusive in-block suffix
    ks = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    is_ = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    strict = (ks > is_).astype(jnp.float32)
    carry = carry_ref[0, 0]                               # Σ of later blocks
    s = jnp.dot(w, strict, preferred_element_type=jnp.float32) + carry
    carry_ref[0, 0] = carry + jnp.sum(w)
    o_ref[0] = s.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sic_suffix_pallas(w, block: int = 128, interpret: bool = True):
    """w: [B, N] → exclusive suffix sums [B, N] (s[b, n] = Σ_{j>n} w[b, j]).

    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    b, n = w.shape
    pad = (-n) % block
    wp = jnp.pad(w, ((0, 0), (0, pad))) if pad else w
    nc = wp.shape[1] // block

    kern = functools.partial(_suffix_kernel, block=block)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    out = pl.pallas_call(
        kern,
        grid=(b, nc),
        # blocks are visited right-to-left: grid step j touches block
        # nc-1-j, so the carry accumulates the suffix of later blocks
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, nc - 1 - j))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, nc - 1 - j)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, w.dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(wp)
    return out[:, :n] if pad else out
