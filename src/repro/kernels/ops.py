"""Dispatch wrappers: Pallas kernel on TPU, interpret-mode kernel for CPU
validation, jnp oracle as the portable fallback.

The model stack calls these through ``cfg.use_pallas``; the SPMD dry-run uses
the jnp path (Pallas does not lower on the CPU backend outside interpret
mode — DESIGN.md §3).
"""
from __future__ import annotations

import jax

from .ref import sic_suffix_ref, ssd_scan_ref, swa_attention_ref
from .sic_suffix import sic_suffix_pallas
from .ssd_scan import ssd_scan_pallas
from .swa_attention import swa_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(x, dt, a, b, c, chunk: int = 128, mode: str = "auto"):
    """mode: auto | pallas | interpret | ref"""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ssd_scan_ref(x, dt, a, b, c)
    interpret = (mode == "interpret") or not _on_tpu()
    return ssd_scan_pallas(x, dt, a, b, c, chunk=chunk, interpret=interpret)


def sic_suffix_sum(w, block: int = 128, mode: str = "auto"):
    """Exclusive suffix sum along the last axis of ``w`` [..., N] — the SIC
    interference scan of the large-N power engine (``repro.core.sic``).

    mode: auto | pallas | interpret | ref — same switch as ``ssd_scan``:
    ``ref`` is the jnp flip-cumsum oracle (and the ``auto`` choice off-TPU),
    ``interpret`` forces the Pallas kernel through the CPU interpreter
    (validation), ``pallas`` compiles it (TPU)."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return sic_suffix_ref(w)
    interpret = (mode == "interpret") or not _on_tpu()
    flat = w.reshape((-1, w.shape[-1]))
    return sic_suffix_pallas(flat, block=block,
                             interpret=interpret).reshape(w.shape)


def swa_attention(q, k, v, window: int = 0, softcap: float = 0.0,
                  block: int = 128, mode: str = "auto"):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return swa_attention_ref(q, k, v, window=window, softcap=softcap)
    interpret = (mode == "interpret") or not _on_tpu()
    return swa_attention_pallas(q, k, v, window=window, softcap=softcap,
                                block=block, interpret=interpret)
