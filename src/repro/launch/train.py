"""Training launcher.

Two modes:

  * centralized LM training on the local mesh (any --arch, reduced or full):
      PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \\
          --steps 50 --global-batch 8 --seq-len 256
  * federated (the paper's system): DT-assisted FL with reputation selection
    and Stackelberg allocation driving per-round scheduling:
      PYTHONPATH=src python -m repro.launch.train --federated --rounds 30
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_variant
from ..data.pipeline import PipelineConfig, lm_batches
from ..models import init_params
from ..optim import AdamWConfig, init_opt_state
from ..checkpoint.io import save_checkpoint
from .mesh import make_host_mesh
from .steps import make_train_step


def centralized(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    cfg = cfg.replace(train_microbatches=args.microbatches)
    if args.set:
        from .dryrun import parse_overrides
        cfg = cfg.replace(**parse_overrides(args.set))
    pipe = PipelineConfig(global_batch=args.global_batch,
                          seq_len=args.seq_len, vocab_size=cfg.vocab_size,
                          seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=args.lr, moment_dtype=cfg.param_dtype)
    opt = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      num_microbatches=args.microbatches),
                      donate_argnums=(0, 1))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.global_batch}x{args.seq_len}")
    it = lm_batches(pipe)
    t0 = time.time()
    for step in range(args.steps):
        batch = next(it)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tok_s = (step + 1) * args.global_batch * args.seq_len / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} tok/s {tok_s:.0f}",
                  flush=True)
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, {"params": params}, step)
    if args.ckpt_every:
        save_checkpoint(args.ckpt_dir, {"params": params}, args.steps)
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


def federated(args):
    from ..core.channel import sample_positions
    from ..core.digital_twin import DTConfig, sample_v_max
    from ..core.fl_round import FLConfig, FLState, run_training
    from ..core.reputation import init_reputation
    from ..core.stackelberg import GameConfig
    from ..data.federated import make_federated_data
    from ..data.synthetic import SYNTHETIC_MNIST
    from ..models.classifier import make_classifier

    key = jax.random.PRNGKey(args.seed)
    ks = jax.random.split(key, 6)
    data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=args.clients,
                               cap=128, poison_ratio=args.poison_ratio)
    params, logits_fn = make_classifier("mlp", ks[1], in_dim=784, hidden=64)
    fl = FLConfig(scheme=args.scheme, epsilon=args.epsilon,
                  local_steps=15, server_steps=15, lr=0.1)
    state = FLState(params=params, rep=init_reputation(args.clients),
                    v_max=sample_v_max(ks[2], args.clients, DTConfig()),
                    distances=sample_positions(ks[3], args.clients), key=ks[4])
    state, hist = run_training(state, data, fl, GameConfig(), logits_fn,
                               args.rounds)
    for h in hist[:: max(1, args.rounds // 10)]:
        print(json.dumps({k: v for k, v in h.items()
                          if not hasattr(v, "shape")}), flush=True)
    print(f"final acc {hist[-1]['val_acc']:.4f} "
          f"mean cost {sum(h['total_cost'] for h in hist)/len(hist):.3f}")
    return hist[-1]["val_acc"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    # federated mode
    ap.add_argument("--federated", action="store_true")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--poison-ratio", type=float, default=0.0)
    ap.add_argument("--epsilon", type=float, default=0.0)
    ap.add_argument("--scheme", default="proposed")
    ap.add_argument("--set", action="append", default=[],
                    help="model-config override key=value (repeatable)")
    args = ap.parse_args()
    if args.federated:
        federated(args)
    else:
        centralized(args)


if __name__ == "__main__":
    main()
