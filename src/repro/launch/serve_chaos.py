"""Service-level chaos harness for the allocation service (ISSUE 9).

PR 7 gave the *training* layer a fault-injection scenario library
(``repro.core.faults``: declarative configs, named presets, trace-safe
knobs).  This module is the matching story for the *serving* layer:
``ChaosScenario`` declares a reproducible storm — burst overload,
NaN/Inf channel rows, artificial dispatch stalls, transient dispatch
failures, poisoned (all-NaN) solver outputs, malformed requests — and
``run_chaos`` drives it through a live ``AllocationService``, then
audits the wreckage against the service's graceful-degradation
contract:

  * **exactly-once** — every submitted rid appears in ``drain()``
    exactly once, with a status from ``STATUS_VOCAB``; the stream never
    dies (no exception escapes the service for any injected condition).
  * **graceful priority degradation** — under overload, HIGH-priority
    requests keep completing (bounded p99) while LOW-priority requests
    shed; shedding is always structured, never silent.
  * **containment** — poisoned outputs trip the per-(bucket, scheme)
    circuit breaker instead of propagating NaN allocations as ``"ok"``.

Faults inject at the service's dispatch seam (``service._dispatch``),
keyed on the DISPATCH ORDINAL — deterministic given the scenario, no
wall-clock or RNG in the injection decision, so a chaos run is
replayable.  ``chaos_dispatch`` wraps the real executable:

  * ``stall_dispatches``  — sleep ``stall_s`` before dispatching (an
    artificially slow executable; exercises the watchdog and the
    bounded-queue backpressure).
  * ``fail_dispatches``   — raise ``ChaosDispatchError`` (a transient
    infrastructure failure; exercises backoff retry).  Each ATTEMPT
    consumes one ordinal, so a single listed ordinal fails once and the
    backoff retry succeeds; list a consecutive run of ordinals to
    exhaust the whole retry budget.
  * ``poison_dispatches`` — run the real solve, then replace every
    floating-point leaf with NaN (a numerically-poisoned executable;
    exercises non-finite containment + the breaker).

Used by ``tests/test_serve_chaos.py`` (tier-1, marker ``chaos``),
``benchmarks/serve_latency.py`` (the ``chaos`` section of
``BENCH_serve.json``) and ``scripts/dev_smoke.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from .alloc_serve import STATUS_VOCAB, AllocationService, AllocRequest


class ChaosDispatchError(RuntimeError):
    """Injected transient dispatch failure (infrastructure, not input)."""


@dataclass(frozen=True)
class ChaosScenario:
    """One reproducible serving storm.  All stream randomness derives
    from ``seed``; all fault injection keys on the dispatch ordinal."""
    name: str
    n_requests: int = 60
    seed: int = 0
    n_lo: int = 1                       # client-count range of the stream
    n_hi: int = 8
    hi_priority_frac: float = 0.25      # fraction submitted at priority 2
    hi_deadline_s: float | None = None  # deadline attached to hi-priority
    nan_request_frac: float = 0.0       # fraction with NaN/Inf channel rows
    malformed_every: int = 0            # every k-th request is malformed
    #                                     (empty h2 — submit() raises; the
    #                                     harness catches and counts it)
    stall_dispatches: tuple = ()        # dispatch ordinals to stall
    stall_s: float = 0.25
    fail_dispatches: tuple = ()         # ordinals raising ChaosDispatchError
    poison_dispatches: tuple = ()       # ordinals with all-NaN outputs
    service_kwargs: dict = field(default_factory=dict)


#: Named presets, PR-7 style: small, deterministic, each stressing one
#: containment mechanism; ``full_chaos`` composes all of them.
SCENARIOS = {
    "burst_overload": ChaosScenario(
        name="burst_overload", n_requests=80, hi_priority_frac=0.25,
        service_kwargs={"max_queue": 16, "max_batch": 4,
                        "buckets": (8,)}),
    "nan_storm": ChaosScenario(
        name="nan_storm", n_requests=40, nan_request_frac=0.3,
        service_kwargs={"max_batch": 4, "buckets": (8,)}),
    "stalled_dispatch": ChaosScenario(
        name="stalled_dispatch", n_requests=30, stall_dispatches=(1,),
        stall_s=0.25,
        service_kwargs={"max_batch": 4, "buckets": (8,)}),
    "full_chaos": ChaosScenario(
        name="full_chaos", n_requests=80, hi_priority_frac=0.25,
        nan_request_frac=0.15, malformed_every=17,
        stall_dispatches=(2,), stall_s=0.2, fail_dispatches=(4,),
        poison_dispatches=(6,),
        service_kwargs={"max_queue": 24, "max_batch": 4,
                        "buckets": (8,), "backoff_base_s": 0.01}),
}


def chaos_dispatch(real_dispatch, scenario: ChaosScenario, counters: dict):
    """Wrap the service's dispatch seam with ordinal-keyed injection.

    ``counters`` (mutated in place) tallies ``dispatch_calls`` (every
    attempt, including retries of a failed ordinal) plus one counter
    per injected fault kind."""

    def wrapped(*args, **kwargs):
        ordinal = counters["dispatch_calls"]
        counters["dispatch_calls"] += 1
        if ordinal in scenario.stall_dispatches:
            counters["injected_stalls"] += 1
            time.sleep(scenario.stall_s)
        if ordinal in scenario.fail_dispatches:
            counters["injected_failures"] += 1
            raise ChaosDispatchError(
                f"injected transient failure at dispatch #{ordinal}")
        out = real_dispatch(*args, **kwargs)
        if ordinal in scenario.poison_dispatches:
            counters["injected_poison"] += 1
            out = jax.tree_util.tree_map(
                lambda x: jnp.full_like(x, jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, out)
        return out

    return wrapped


def make_chaos_stream(scenario: ChaosScenario):
    """The deterministic request stream: (AllocRequest, expected_raise)
    pairs.  ``expected_raise`` marks malformed requests that ``submit``
    is CONTRACTED to raise on (caller bugs, outside exactly-once)."""
    rng = np.random.default_rng(scenario.seed)
    stream = []
    for i in range(scenario.n_requests):
        n = int(rng.integers(scenario.n_lo, scenario.n_hi + 1))
        h2 = rng.uniform(0.05, 2.0, n).astype(np.float32)
        malformed = (scenario.malformed_every > 0
                     and i % scenario.malformed_every
                     == scenario.malformed_every - 1)
        if malformed:
            h2 = np.zeros((0,), np.float32)
        elif rng.uniform() < scenario.nan_request_frac:
            h2[int(rng.integers(0, n))] = (
                np.nan if rng.uniform() < 0.5 else np.inf)
        hi = rng.uniform() < scenario.hi_priority_frac
        stream.append((AllocRequest(
            h2=h2, priority=2 if hi else 0,
            deadline_s=scenario.hi_deadline_s if hi else None,
            seed=i), malformed))
    return stream


@dataclass
class ChaosReport:
    """Audited outcome of one chaos run."""
    scenario: str
    submitted: int                     # rids handed out by submit()
    malformed_raised: int              # submit() raised (by contract)
    results: list                      # drained AllocResults, rid-sorted
    status_counts: dict
    lost_rids: list                    # submitted but never drained
    duplicate_rids: list               # drained more than once
    invalid_status: list               # statuses outside STATUS_VOCAB
    nan_leaked_ok: int                 # status=="ok" rows w/ non-finite p
    hi_latency_ms: list                # completed hi-priority latencies
    injection: dict                    # chaos_dispatch counters
    health: dict                       # service.health() at the end

    @property
    def exactly_once(self) -> bool:
        return not (self.lost_rids or self.duplicate_rids
                    or self.invalid_status)

    def hi_p99_ms(self) -> float:
        if not self.hi_latency_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.hi_latency_ms), 99))


def run_chaos(scenario: ChaosScenario,
              service: AllocationService | None = None,
              warm: bool = True) -> ChaosReport:
    """Drive one scenario through a live service and audit the result.

    The service is real (actual bucket executables, actual scheduler);
    only the dispatch seam is wrapped.  ``warm=True`` pre-compiles the
    buckets BEFORE arming the chaos wrapper, so injected ordinals land
    on steady-state dispatches, not compiles."""
    if service is None:
        service = AllocationService(**dict(scenario.service_kwargs))
    if warm:
        service.warmup(schemes=("proposed",))
    counters = {"dispatch_calls": 0, "injected_stalls": 0,
                "injected_failures": 0, "injected_poison": 0}
    service._dispatch = chaos_dispatch(service._dispatch, scenario,
                                       counters)
    submitted_rids, malformed_raised = [], 0
    for req, malformed in make_chaos_stream(scenario):
        try:
            submitted_rids.append(service.submit(req))
        except ValueError:
            if not malformed:
                raise           # stream died on a well-formed request
            malformed_raised += 1
    results = service.drain()

    seen = [r.rid for r in results]
    counts: dict = {}
    for r in results:
        counts[r.status] = counts.get(r.status, 0) + 1
    sub = set(submitted_rids)
    return ChaosReport(
        scenario=scenario.name,
        submitted=len(submitted_rids),
        malformed_raised=malformed_raised,
        results=results,
        status_counts=counts,
        lost_rids=sorted(sub - set(seen)),
        duplicate_rids=sorted(rid for rid in set(seen)
                              if seen.count(rid) > 1),
        invalid_status=sorted({r.status for r in results}
                              - set(STATUS_VOCAB)),
        nan_leaked_ok=sum(1 for r in results if r.status == "ok"
                          and not np.all(np.isfinite(r.p))),
        hi_latency_ms=[r.latency_s * 1e3 for r in results
                       if r.priority >= 2
                       and r.status in ("ok", "infeasible", "timeout")],
        injection=dict(counters),
        health=service.health())


def assert_exactly_once(report: ChaosReport) -> None:
    """Raise AssertionError unless the run honored the contract."""
    assert not report.lost_rids, (
        f"{report.scenario}: LOST rids {report.lost_rids[:10]} "
        f"({len(report.lost_rids)} total) — exactly-once violated")
    assert not report.duplicate_rids, (
        f"{report.scenario}: DUPLICATE rids {report.duplicate_rids[:10]}")
    assert not report.invalid_status, (
        f"{report.scenario}: statuses outside {STATUS_VOCAB}: "
        f"{report.invalid_status}")
    assert report.nan_leaked_ok == 0, (
        f"{report.scenario}: {report.nan_leaked_ok} status='ok' rows "
        f"carry non-finite allocations")
