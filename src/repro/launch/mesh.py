"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 197 TFLOP/s bf16,
16 GB @ 819 GB/s HBM, ~50 GB/s/link ICI.  Defined as FUNCTIONS so importing
this module never touches jax device state (the dry-run must set
``xla_force_host_platform_device_count`` before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"))


# hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
