"""ShapeDtypeStruct input specs for every (architecture × input shape).

Shapes assigned to this paper:
  train_4k      seq_len=4,096    global_batch=256   (training)
  prefill_32k   seq_len=32,768   global_batch=32    (inference-prefill)
  decode_32k    seq_len=32,768   global_batch=128   (inference-decode)
  long_500k     seq_len=524,288  global_batch=1     (long-context-decode)

Decode shapes lower ``serve_step`` — ONE token against a seq_len-deep KV
cache.  ``long_500k`` forces the sliding-window decode variant for
pure-full-attention archs (DESIGN.md §4); SSM/hybrid archs and gemma's
native local:global patterns run unmodified.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from ..models import init_caches
from ..models.config import MAMBA, ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

LONG_DECODE_WINDOW = 4_096


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(applicable, reason-if-not). seamless skips long_500k (DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family == "audio":
        return False, ("enc-dec speech model: 500k-token decode is outside "
                       "the family's operating regime (skip per DESIGN.md §4)")
    return True, ""


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments (sub-quadratic variant for long_500k)."""
    if shape.name == "long_500k":
        pure_full_attn = (not any(s.kind == MAMBA for s in cfg.pattern)
                          and all(s.window == 0 for s in cfg.pattern))
        if pure_full_attn:
            cfg = cfg.replace(decode_window=LONG_DECODE_WINDOW)
    return cfg


def token_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if shape.mode in ("train", "prefill"):
        if cfg.num_patch_tokens:
            p = cfg.num_patch_tokens
            spec = {"tokens": SDS((b, s - p), i32),
                    "patches": SDS((b, p, cfg.d_model), f32)}
            if shape.mode == "train":
                spec["targets"] = SDS((b, s - p), i32)
            return spec
        spec = {"tokens": SDS((b, s), i32)}
        if shape.mode == "train":
            spec["targets"] = SDS((b, s), i32)
        if cfg.encoder_layers:
            spec["frames"] = SDS((b, s // cfg.encoder_ratio, cfg.d_model), f32)
        return spec
    raise ValueError(shape.mode)


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """Cache pytree as ShapeDtypeStructs (no allocation)."""
    return jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Everything the lowered step function consumes, minus params/opt."""
    if shape.mode in ("train", "prefill"):
        return {"batch": token_specs(cfg, shape)}
    return {"token": SDS((shape.global_batch, 1), jnp.int32),
            "caches": cache_specs(cfg, shape)}
