"""Batched serving driver: prime a KV cache by stepping the prompt, then
decode with a jitted serve_step.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \\
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_variant
from ..models import decode_step, init_caches, init_params, prefill_with_caches
from .steps import make_serve_step


def generate(cfg, params, prompt, max_seq: int, gen: int, greedy=True,
             key=None, prime: str = "prefill"):
    """prompt: [B, P] int32 → returns [B, P+gen] tokens.

    prime="prefill" runs the one-pass cache-collecting prefill;
    prime="steps" replays the prompt through decode_step (reference path).
    Both prime paths feed the decode loop last-position logits of rank 2
    ([B, V]); a [B, 1, V] rank from a priming path would otherwise make
    ``argmax(...)[:, None]`` produce [B, 1, 1] next-tokens and break the
    concatenate against [B, P] — normalized once below so the two paths
    stay shape-identical (parity: tests/test_serve_generate.py).
    """
    b, plen = prompt.shape
    step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    toks = prompt
    if prime == "prefill":
        logits, caches = jax.jit(
            lambda p, t: prefill_with_caches(p, {"tokens": t}, cfg, max_seq)
        )(params, prompt)
    else:
        caches = init_caches(cfg, b, max_seq)
        logits = None
        for t in range(plen):        # prime the cache token by token
            logits, caches = step(params, toks[:, t:t + 1], caches)
    if logits.ndim == 3:             # [B, 1, V] → [B, V] (see docstring)
        logits = logits[:, -1, :]
    for t in range(gen):
        if greedy or key is None:
            nxt = jnp.argmax(logits, axis=-1)[:, None]
        else:
            key, sk = jax.random.split(key)
            nxt = jax.random.categorical(sk, logits)[:, None]
        toks = jnp.concatenate([toks, nxt.astype(jnp.int32)], axis=1)
        logits, caches = step(params, nxt.astype(jnp.int32), caches)
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompt,
                    max_seq=args.prompt_len + args.gen + 1, gen=args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} generated {args.gen} tokens "
          f"per seq in {dt:.2f}s → {n_new/dt:.1f} tok/s (incl. priming)")
    print("sample:", toks[0, :32].tolist())


if __name__ == "__main__":
    main()
