import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may import jax.  Tests that want a
# smaller mesh pre-set their own device count (tests/test_dryrun.py).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and dump memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-2.7b --shape long_500k --mesh multipod

Results land in runs/dryrun/<arch>_<shape>_<mesh>.json (cached; --force to
redo).  EXPERIMENTS.md §Dry-run and benchmarks/roofline.py read these.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, ALIASES, get_config
from ..models import init_params
from ..optim import AdamWConfig, init_opt_state
from ..sharding.rules import (batch_shardings, cache_shardings,
                              params_shardings, replicated)
from .mesh import make_production_mesh
from .specs import SHAPES, adapt_config, input_specs, shape_applicable
from .steps import make_prefill, make_serve_step, make_train_step

RESULTS_DIR = "runs/dryrun"

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective bytes by op type, parsed from optimized HLO.

    Operand sizes are looked up from each operand's defining instruction;
    shapes in the SPMD module are per-device shards, so the totals are
    bytes-per-device."""
    sizes = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))
    stats = {op: {"count": 0, "operand_bytes": 0} for op in COLLECTIVE_OPS}
    opnd_re = re.compile(r"%?([\w.\-]+)")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _INSTR_RE.match(line)
        if not m:
            continue
        for op in COLLECTIVE_OPS:
            # match the op name as the instruction, not inside metadata
            if re.search(rf"\)?\s{op}(?:-start|-done)?\(", stripped) or \
               re.search(rf"=\s*\S+\s+{op}(?:-start)?\(", stripped):
                if f"{op}-done" in stripped:
                    continue  # counted at -start
                args = stripped.split(op, 1)[1]
                args = args[args.find("(") + 1:]
                depth, end = 1, 0
                for i, ch in enumerate(args):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        end = i
                        break
                operand_names = [n for n in opnd_re.findall(args[:end])
                                 if n in sizes]
                stats[op]["count"] += 1
                stats[op]["operand_bytes"] += sum(sizes[n]
                                                  for n in operand_names)
                break
    stats["total_bytes"] = sum(v["operand_bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def build_lowerable(arch: str, shape_name: str, mesh, overrides=None):
    """Returns (fn, args, in_shardings, out_shardings, donate, meta)."""
    cfg = adapt_config(get_config(arch), SHAPES[shape_name])
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda: init_params(cfg, key))
    pshard = params_shardings(params_sds, mesh)
    specs = input_specs(cfg, shape)
    rep = replicated(mesh)

    if shape.mode == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.param_dtype,
                              chunked_update_bytes=2**28 if cfg.chunked_optimizer else 0,
                              update_in_moment_dtype=cfg.optimizer_lowp_update)
        opt_sds = jax.eval_shape(lambda: init_opt_state(params_sds, opt_cfg))
        oshard = params_shardings(opt_sds, mesh)
        oshard["count"] = rep
        batch_sds = specs["batch"]
        bshard = batch_shardings(batch_sds, mesh)
        shards = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                shards *= mesh.shape[a]
        n_micro = max(1, min(cfg.train_microbatches,
                             shape.global_batch // shards))
        fn = make_train_step(cfg, opt_cfg, num_microbatches=n_micro)
        args = (params_sds, opt_sds, batch_sds)
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        donate = (0, 1)
        meta = {"n_micro": n_micro, "mode": "train"}
    elif shape.mode == "prefill":
        fn = make_prefill(cfg)
        args = (params_sds, specs["batch"])
        in_sh = (pshard, batch_shardings(specs["batch"], mesh))
        out_sh = None
        donate = ()
        meta = {"mode": "prefill"}
    else:
        fn = make_serve_step(cfg)
        caches = specs["caches"]
        cshard = cache_shardings(caches, mesh)
        tshard = batch_shardings({"t": specs["token"]}, mesh)["t"]
        # §Perf (decode collective-bound): FSDP layouts all-gather the
        # weights EVERY token.  When the TP-only shard fits the HBM budget
        # AND the batch is large enough that the per-token gather matters,
        # keep weights model-resident; the 340B class (and batch=1
        # long-context, where the gather amortizes differently and HBM is
        # cache-dominated) stays FSDP.
        p_bytes = cfg.param_count() * cfg.storage_dtype.itemsize
        tp_size = mesh.shape.get("model", 1)
        tp_resident = (p_bytes / tp_size <= 4 * 2**30
                       and shape.global_batch >= 16)
        if tp_resident:
            pshard = params_shardings(params_sds, mesh, fsdp_axis=None)
        args = (params_sds, specs["token"], caches)
        in_sh = (pshard, tshard, cshard)
        out_sh = (None, cshard)
        donate = (2,)
        meta = {"mode": "decode", "decode_window": cfg.decode_window,
                "tp_resident_weights": tp_resident}
    meta.update({
        "arch": arch, "shape": shape_name,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "family": cfg.family,
    })
    return fn, args, in_sh, out_sh, donate, meta


def run_one(arch: str, shape_name: str, mesh_kind: str,
            force: bool = False, overrides=None, tag: str = "") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}_{shape_name}_{mesh_kind}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    from ..sharding.context import set_active_mesh
    set_active_mesh(mesh)   # enables intra-jit sharding constraints at trace
    try:
        fn, args, in_sh, out_sh, donate, meta = build_lowerable(
            arch, shape_name, mesh, overrides=overrides)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = _mem_dict(compiled.memory_analysis())
        cost = dict(compiled.cost_analysis() or {})
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
        hlo_text = compiled.as_text()
        coll = collective_stats(hlo_text)
        # persist optimized HLO for the trip-count-aware roofline walker
        import zstandard as zstd
        hlo_dir = os.path.join(RESULTS_DIR, "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{mesh_kind}{suffix}.hlo.zst"), "wb") as f:
            f.write(zstd.ZstdCompressor(level=3).compress(hlo_text.encode()))
        n_chips = 1
        for v in mesh.shape.values():
            n_chips *= v
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "ok", "n_chips": n_chips,
            "meta": meta, "memory": mem,
            "cost": {"flops": cost.get("flops", 0.0),
                     "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "cost_raw": cost,
            "collectives": coll,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:],
                  "elapsed_s": round(time.time() - t0, 2)}
    finally:
        set_active_mesh(None)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def parse_overrides(pairs):
    """key=value strings → typed config overrides (bool/int/float/str)."""
    out = {}
    for pair in pairs:
        k, v = pair.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable), e.g. "
                         "--set seq_shard_activations=true --set moe_impl=ep")
    args = ap.parse_args()
    overrides = parse_overrides(args.set)

    if args.all:
        combos = [(a, s, m) for a in ALIASES
                  for s in SHAPES for m in ("pod", "multipod")]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, args.mesh)]

    for arch, shape, meshk in combos:
        r = run_one(arch, shape, meshk, force=args.force,
                    overrides=overrides or None,
                    tag="custom" if overrides else "")
        status = r["status"]
        line = f"{arch:24s} {shape:12s} {meshk:8s} {status}"
        if status == "ok":
            mem = r["memory"]
            per_dev = (mem.get("argument_size_in_bytes", 0)
                       + mem.get("temp_size_in_bytes", 0)
                       + mem.get("output_size_in_bytes", 0)
                       - mem.get("alias_size_in_bytes", 0))
            line += (f"  mem/dev={per_dev/2**30:.2f}GiB "
                     f"flops={r['cost']['flops']:.3g} "
                     f"coll={r['collectives']['total_bytes']/2**20:.1f}MiB "
                     f"compile={r['compile_s']:.0f}s")
        elif status == "error":
            line += f"  {r['error'][:120]}"
        print(line, flush=True)


if __name__ == "__main__":
    main()
