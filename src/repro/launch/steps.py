"""Step factories: train_step (with gradient accumulation), prefill,
serve_step (one-token decode).  These are the functions the dry-run lowers
and the drivers jit."""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..models import decode_step, forward_logits, loss_fn, prefill
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_update, global_norm, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    num_microbatches: int = 0) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation: the global batch is reshaped to
    [n_micro, B/n_micro, ...] and scanned, accumulating f32 grads.
    """
    n_micro = num_microbatches or cfg.train_microbatches

    def micro_grads(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, mb, cfg), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if n_micro > 1:
            from ..sharding.context import constrain_batch

            def reshape(x):
                b = x.shape[0]
                y = x.reshape((n_micro, b // n_micro) + x.shape[1:])
                # keep the per-microbatch batch dim sharded over (pod, data)
                return constrain_batch(y, batch_dim=1)
            micro = jax.tree_util.tree_map(reshape, batch)

            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def body(acc, mb):
                loss_acc, g_acc = acc
                loss, _, grads = micro_grads(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dt), g_acc, grads)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), g0),
                                                micro)
            loss = loss_sum / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        else:
            loss, _, grads = micro_grads(params, batch)
        new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return new_params, new_opt, metrics

    return train_step


def make_prefill(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, batch, cfg)
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, token, caches):
        return decode_step(params, token, caches, cfg)
    return serve_step
