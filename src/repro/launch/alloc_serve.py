"""Streaming allocation service: ragged-N continuous batching over the
masked Stackelberg engine (ISSUE-6 tentpole), wrapped in an SLA-aware
resilience layer (ISSUE-9 tentpole).

The offline engine answers fixed-N, fixed-K questions; production is an
*online* stream of heterogeneous cells — every request carries its own
client count N, channel draws, and physics knobs, and clients join/drop
between rounds so N never stays put.  Recompiling per N would burn ~1 s
of XLA compile per distinct shape; this module instead routes requests
through a SMALL FIXED SET of bucket executables:

  * **N-buckets** — a request with n clients is padded up to the smallest
    bucket width nb ≥ n (default widths 8/16/32/64/128) with ZERO channel
    gains and an [nb] boolean mask.  Zero-gain padding is invisible to
    the SIC chain by construction (p·|h|² = 0 in every suffix sum — see
    ``repro.core.sic``), keeps the descending SIC order, and the mask
    erases the padded lanes from d_hat, the latency maxima, the energy
    sums and the feasibility test (``stackelberg._solve(mask=...)``), so
    a padded solve is BIT-IDENTICAL to the exact-N solve.
  * **request-batching** — up to ``max_batch`` same-bucket requests ride
    one dispatch as a leading vmap axis; partial batches are topped up
    with all-masked dummy rows so the executable's batch shape is fixed
    (zero retraces over a warm stream, counted by
    ``TRACE_COUNTS["serve_allocation"]``).  Per-request physics
    (t_max / bandwidth / model_bits / …) stack into [B]-leaved
    ``GamePhysics`` operands — heterogeneous cells share the executable.
  * **double-buffered dispatch** — flushes enqueue asynchronously (JAX
    async dispatch keeps the device busy) and block only when more than
    ``max_inflight`` batches are outstanding, overlapping host-side
    pack/unpack with device compute.  Operand buffers are donated to the
    executable (the [B, nb] inputs are dead after dispatch and XLA may
    reuse them for the outputs).

One executable exists per (scheme, bucket width, batch width,
dinkelbach_inner, sic_mode); ``warmup()`` pre-compiles the set so a
latency-SLA deployment pays no cold-start on the stream.

Results come back in the REQUEST'S OWN client order (the service sorts
into SIC order on the way in and unsorts on the way out).

The SLA / resilience contract (ISSUE 9)
=======================================

Every submitted rid yields EXACTLY ONE ``AllocResult`` from ``drain()``
— the exactly-once invariant — with a status from the five-word
vocabulary:

  * ``"ok"``          — solved, feasible, delivered inside any deadline.
  * ``"infeasible"``  — solved, but the equilibrium violates the
    deadline/resource box even after the retry ladder (arrays are the
    solver's best answer; ``degradation`` records the ladder).
  * ``"rejected"``    — the service could not produce a valid allocation:
    oversized N, non-finite channel gains, admission control (predicted
    queue wait already busts ``deadline_s``), circuit breaker open, or a
    dispatch that failed after backoff retries.  Arrays are NaN,
    ``error`` says why.
  * ``"shed"``        — dropped by priority-ordered load shedding when
    the bounded queue (``max_queue``) overflowed: the LOWEST-priority,
    youngest pending request is shed first, never silently.
  * ``"timeout"``     — solved, but delivered after the request's
    ``deadline_s`` (or expired in the queue before dispatch).

**Per-request SLA.**  ``AllocRequest.deadline_s`` (submit→result wall
budget) and ``AllocRequest.priority`` (higher = more important) drive
three scheduler mechanisms: (1) admission control — an EWMA of measured
per-(bucket, scheme) dispatch latency predicts the queue wait; a request
whose deadline the prediction already busts is rejected FAST, before it
wastes a batch lane; (2) bounded queues — when ``max_queue`` is set the
service stops blocking the producer (PR-8 behavior) and instead defers
dispatch while the in-flight window is full, opportunistically retiring
ready batches (``jax.Array.is_ready`` polling), and sheds the
lowest-priority pending request once the bound is hit; (3) batches are
packed highest-priority-first, so under overload high-priority p99
degrades gracefully while low-priority sheds.

**Degraded-retry.**  An infeasible equilibrium walks a bounded retry
ladder (default ``("relax_tmax", "fallback_oma")``): first re-solve with
``t_max × relax_factor`` (a traced operand — same executable, zero
retrace), then fall back to the cheaper ``oma`` scheme.  Each result
carries its ``degradation`` trail (e.g. ``("relax_tmax:1.5",
"fallback:oma")``); ``latency_s`` stays honest (original submit time).
Transient dispatch FAILURES (the dispatch seam raising) retry with
exponential backoff up to ``dispatch_retries`` times before the batch's
requests become structured ``"rejected"`` rows.

**Containment.**  A cooperative watchdog records in-flight batches whose
dispatch→complete wall exceeds ``watchdog_s`` (counted, fed to the
breaker — a stalled executable is unhealthy); per-(bucket, scheme)
circuit breakers trip OPEN after ``breaker_threshold`` consecutive bad
batches (non-finite outputs, a watchdog trip, a dispatch failure — plus
all-infeasible batches when ``breaker_on_infeasible`` is opted in:
infeasibility is a data property and a valid answer, not executable
ill-health, so it doesn't open the breaker by default), fast-fail
submissions while open, move to HALF_OPEN
after ``breaker_cooldown_s`` and close again on the next healthy batch.
``health()`` snapshots queue depths, breaker states, every resilience
counter and per-priority p50/p99 latency.

The BASELINE path — no deadline, no ``max_queue``, feasible,
uncontended — is bit-identical to the PR-8 scheduler: same batch
composition (priority sort is stable and all-equal), same executables,
same operands; the resilience layer only adds host-side bookkeeping.

``benchmarks/serve_latency.py`` measures the steady state plus overload
and chaos sections (→ ``BENCH_serve.json``, claims-gated by
``scripts/check_bench.py``); ``repro.launch.serve_chaos`` is the
service-level fault-injection harness.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.stackelberg import (GameConfig, _oma_body, _random_body, _solve,
                                stack_physics)
from ..core.tracking import TRACE_COUNTS
from ..sharding import game_mesh

DEFAULT_BUCKETS = (8, 16, 32, 64, 128)
SERVE_SCHEMES = ("proposed", "ideal", "wo_dt", "oma", "oma_tdma", "random")
STATUS_VOCAB = ("ok", "infeasible", "rejected", "shed", "timeout")


# ---------------------------------------------------------------------------
# the bucket executable
# ---------------------------------------------------------------------------
@partial(jax.jit,
         static_argnames=("scheme", "max_iter", "inner", "sic_mode",
                          "shards"),
         donate_argnums=(2, 3, 4, 5))
def _serve_batch_jit(phys, keys, h2, D, v_max, eps, mask, tol, scheme,
                     max_iter, inner, sic_mode, shards=1):
    """One padded bucket dispatch: B requests × nb client lanes.

    phys  : GamePhysics with [B] leaves (per-request physics knobs)
    keys  : [B, 2] PRNG keys (consumed by the "random" scheme only)
    h2    : [B, nb] channel gains, each row descending with a zero tail
    D     : [B, nb] data sizes (zero on padded lanes)
    v_max : [B, nb] insensitive fractions (zero on padded lanes)
    eps   : [B] per-request DT deviation
    mask  : [B, nb] bool, True on real client lanes
    tol   : Alg.-2 stopping tolerance (scalar operand)

    Static keys: scheme / max_iter / inner / sic_mode (+ the B, nb
    shapes).  Everything else — including every physics float — is a
    traced operand, so one executable serves arbitrarily heterogeneous
    cells.  The [B, nb] operand buffers (h2, D, v_max) and eps are
    donated — dead after dispatch, XLA reuses them for the matching
    [B, nb] outputs (p/q/f/alpha/rates) and the [B] scalars.  The
    GamePhysics leaves stay undonated: only two [B] f32 outputs exist
    to absorb eleven [B] leaves, and XLA warns on every unusable one.

    ``shards`` > 1 splits the batch axis over the 1D draw mesh via
    ``shard_map`` (each device solves its local rows' independent
    while_loops); the service sizes B to a device multiple, so the
    split is exact and the executable shape never changes.
    """
    TRACE_COUNTS["serve_allocation"] += 1

    def batch(ph_b, kk, h2_b, d_b, vm_b, eps_b, m_b, tl):
        def one(ph, key, h2_r, d_r, vm_r, eps_r, m_r):
            dtype = jnp.result_type(h2_r)
            if scheme in ("proposed", "ideal"):
                return _solve(ph, h2_r, d_r, vm_r, eps_r, max_iter, tl,
                              inner, sic_mode, mask=m_r)
            if scheme == "wo_dt":
                return _solve(ph, h2_r, d_r, jnp.zeros_like(h2_r),
                              jnp.zeros((), dtype), max_iter, tl, inner,
                              sic_mode, mask=m_r)
            if scheme == "oma":
                return _oma_body(ph, h2_r, d_r, vm_r, eps_r, inner,
                                 tdma=False, mask=m_r)
            if scheme == "oma_tdma":
                return _oma_body(ph, h2_r, d_r, vm_r, eps_r, inner,
                                 tdma=True, mask=m_r)
            if scheme == "random":
                return _random_body(ph, key, h2_r, d_r, vm_r, eps_r,
                                    mask=m_r)
            raise ValueError(f"unknown scheme {scheme!r}")

        return jax.vmap(one)(ph_b, kk, h2_b, d_b, vm_b, eps_b, m_b)

    if shards > 1:
        d = P(game_mesh.DRAW_AXIS)
        batch = shard_map(batch, mesh=game_mesh.mesh_1d(shards),
                          in_specs=(d,) * 7 + (P(),), out_specs=d,
                          check_rep=False)
    return batch(phys, keys, h2, D, v_max, eps, mask, tol)


# ---------------------------------------------------------------------------
# requests / results
# ---------------------------------------------------------------------------
@dataclass
class AllocRequest:
    """One cell's allocation question.  ``h2`` may arrive in ANY client
    order — the service sorts into SIC order and unsorts the answer.
    ``d`` / ``v_max`` are scalars or per-client [n] arrays aligned with
    ``h2``'s order.

    SLA knobs (ISSUE 9): ``deadline_s`` is the submit→result wall budget
    — admission control reject-fasts when the predicted queue wait
    already busts it, and a result delivered late is tagged
    ``status="timeout"``; ``priority`` orders load shedding (lowest shed
    first) and batch packing (highest packed first); ``allow_degraded``
    opts this request out of the infeasible retry ladder."""
    h2: object
    d: object = 200.0
    v_max: object = 0.5
    cfg: GameConfig = field(default_factory=GameConfig)
    scheme: str = "proposed"
    epsilon: float = 0.0
    seed: int = 0              # per-request randomness ("random" scheme)
    deadline_s: float | None = None
    priority: int = 0
    allow_degraded: bool = True


@dataclass
class AllocResult:
    """Per-request allocation, in the request's own client order.

    ``status`` is the graceful-degradation contract (STATUS_VOCAB — see
    the module docstring for the full five-word semantics):
      * ``"ok"``         — solved, ``feasible=True``, inside deadline.
      * ``"infeasible"`` — solved, but the equilibrium violates the
        deadline/resource box (``feasible=False``) even after the retry
        ladder; the allocation arrays are still the solver's best answer
        — the caller decides whether to use, relax, or drop the cell.
      * ``"rejected"``   — no valid allocation: oversized N, non-finite
        input, admission control, open circuit breaker, failed dispatch,
        or non-finite solver output.  Arrays are NaN, ``error`` says why.
      * ``"shed"``       — dropped by priority-ordered load shedding
        under queue overflow.  A bad or shed request yields a structured
        row instead of killing the in-flight stream — never silent loss.
      * ``"timeout"``    — completed (or expired in queue) after
        ``deadline_s``; completed rows still carry the solved arrays.

    ``degradation`` is the retry-ladder trail, e.g.
    ``("relax_tmax:1.5", "fallback:oma")`` — empty on the baseline path.
    ``scheme`` is the scheme that produced the final arrays (``"oma"``
    after a fallback).  ``latency_s`` is always submit→emit wall time,
    including for rejected/shed rows (honest latency, ISSUE-9
    satellite)."""
    rid: int
    n: int
    bucket: int
    scheme: str
    p: np.ndarray
    q: np.ndarray
    f: np.ndarray
    alpha: np.ndarray
    rates: np.ndarray
    t_total: float
    energy: float
    feasible: bool
    iterations: int
    latency_s: float           # submit → result available on host
    status: str = "ok"
    error: str = ""
    priority: int = 0
    deadline_s: float | None = None
    degradation: tuple = ()


@dataclass
class _Pending:
    rid: int
    req: AllocRequest
    n: int
    order: np.ndarray          # SIC sort permutation of the request's h2
    h2: np.ndarray             # [n] sorted descending
    d: np.ndarray              # [n] aligned with h2
    v_max: np.ndarray          # [n]
    t_submit: float
    eff_cfg: GameConfig = None     # effective config (ladder may relax t_max)
    eff_scheme: str = ""           # effective scheme (ladder may fall back)
    stage: int = 0                 # retry-ladder stages consumed
    degradation: tuple = ()


@dataclass
class _InFlight:
    key: tuple
    pending: list               # the real _Pending rows (dummies excluded)
    out: object                 # device Allocation, [B, nb] fields
    t_dispatch: float


class _Breaker:
    """Per-(bucket, scheme, inner, sic_mode) circuit breaker state."""
    __slots__ = ("state", "fails", "opened_at")

    def __init__(self):
        self.state = "closed"       # closed | open | half_open
        self.fails = 0              # consecutive bad batches
        self.opened_at = 0.0        # monotonic time of the last open


class AllocationService:
    """Continuous-batching scheduler over the masked bucket executables,
    with the ISSUE-9 resilience layer (admission control, bounded-queue
    shedding, degraded-retry, circuit breakers, watchdog).

    submit() enqueues (auto-flushing full batches), flush() force-packs
    partial batches with dummy rows, drain() completes everything —
    including retry-ladder re-dispatches — and returns the accumulated
    ``AllocResult``s sorted by rid.  ``warmup()`` pre-compiles the
    bucket set.  ``health()`` snapshots the resilience state.  See the
    module docstring for the design and the SLA contract.

    ``max_queue=None`` (default) keeps the PR-8 blocking scheduler
    bit-identically; setting it switches to the bounded-queue
    non-blocking mode with priority shedding.  ``self._dispatch`` is the
    dispatch seam — the chaos harness (``repro.launch.serve_chaos``)
    wraps it to inject stalls, transient failures and poisoned outputs.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_batch: int = 8, max_inflight: int = 2,
                 max_iter: int = 20, tol: float = 1e-6,
                 max_queue: int | None = None,
                 ewma_alpha: float = 0.25,
                 degraded_retry: bool = True,
                 retry_ladder: Sequence[str] = ("relax_tmax",
                                                "fallback_oma"),
                 relax_factor: float = 1.5,
                 dispatch_retries: int = 2,
                 backoff_base_s: float = 0.05,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 breaker_on_infeasible: bool = False,
                 watchdog_s: float | None = 30.0,
                 latency_window: int = 512):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket widths {buckets}")
        bad = [s for s in retry_ladder
               if s not in ("relax_tmax", "fallback_oma")]
        if bad:
            raise ValueError(f"unknown retry-ladder stages {bad}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_batch = int(max_batch)
        # multi-device: shard the batch axis of every bucket dispatch —
        # the fixed dispatch width rounds up to a device multiple once at
        # init (extra rows are all-masked dummies, same as partial-batch
        # fill), so the executable shape stays retrace-free
        self.shards = game_mesh.batch_shards(self.max_batch)
        self.batch_width = game_mesh.padded_size(self.max_batch, self.shards)
        self.max_inflight = int(max_inflight)
        self.max_iter = int(max_iter)
        self.tol = float(tol)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.ewma_alpha = float(ewma_alpha)
        self.degraded_retry = bool(degraded_retry)
        self.retry_ladder = tuple(retry_ladder)
        self.relax_factor = float(relax_factor)
        self.dispatch_retries = int(dispatch_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        # infeasibility is a DATA property (a valid answer in the status
        # vocabulary), not executable ill-health: all-infeasible batches
        # feed the breaker only on request — e.g. a deployment whose
        # stream is known-feasible and wants miscompiles caught.  On a
        # mixed stream (the bench trace runs ~38% infeasible cells) the
        # default would fast-fail healthy requests.
        self.breaker_on_infeasible = bool(breaker_on_infeasible)
        self.watchdog_s = None if watchdog_s is None else float(watchdog_s)
        self.latency_window = int(latency_window)
        self._next_rid = 0
        self._pending: dict = collections.defaultdict(list)
        self._inflight: collections.deque = collections.deque()
        self._done: list = []
        self._dispatch = _serve_batch_jit      # chaos-injection seam
        self._ewma: dict = {}                  # key -> dispatch seconds
        self._breakers: dict = {}              # key -> _Breaker
        self.breaker_log: list = []            # (key_str, old, new) capped
        self._lat: dict = collections.defaultdict(
            lambda: collections.deque(maxlen=self.latency_window))
        self.stats = collections.Counter()

    # -- intake -------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket width ≥ n; raises ValueError when n exceeds
        the largest bucket (``submit`` catches this same error and turns
        it into a structured rejection — single source of truth for the
        oversize message)."""
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"request with {n} clients exceeds the largest "
                         f"bucket {self.buckets[-1]}; widen `buckets`")

    def _key_str(self, key: tuple) -> str:
        nb, scheme, inner, sic_mode = key
        return f"n{nb}/{scheme}/{inner}/{sic_mode}"

    def _reject(self, req: AllocRequest, n: int, why: str, t0: float,
                status: str = "rejected") -> int:
        """Graceful degradation: a request the service cannot dispatch
        becomes a structured per-request error row (NaN allocation) with
        HONEST submit→reject latency instead of an exception that kills
        the in-flight stream.  Malformed LOCAL input (empty request,
        unknown scheme) still raises from ``submit`` — those are caller
        bugs, not stream conditions."""
        rid = self._next_rid
        self._next_rid += 1
        nanv = np.full((max(n, 0),), np.nan, np.float32)
        self._done.append(AllocResult(
            rid=rid, n=n, bucket=0, scheme=req.scheme,
            p=nanv, q=nanv.copy(), f=nanv.copy(), alpha=nanv.copy(),
            rates=nanv.copy(), t_total=float("nan"), energy=float("nan"),
            feasible=False, iterations=0,
            latency_s=time.perf_counter() - t0,
            status=status, error=why, priority=req.priority,
            deadline_s=req.deadline_s))
        self.stats[status] += 1
        return rid

    def _emit_structured(self, r: _Pending, status: str, error: str,
                         bucket: int = 0) -> None:
        """Exactly-once bookkeeping for a queued row that never reached a
        healthy completion (shed / expired / dispatch failure)."""
        nanv = np.full((max(r.n, 0),), np.nan, np.float32)
        self._done.append(AllocResult(
            rid=r.rid, n=r.n, bucket=bucket, scheme=r.eff_scheme,
            p=nanv, q=nanv.copy(), f=nanv.copy(), alpha=nanv.copy(),
            rates=nanv.copy(), t_total=float("nan"), energy=float("nan"),
            feasible=False, iterations=0,
            latency_s=time.perf_counter() - r.t_submit,
            status=status, error=error, priority=r.req.priority,
            deadline_s=r.req.deadline_s, degradation=r.degradation))
        self.stats[status] += 1

    def _predict_wait(self, key: tuple) -> float | None:
        """Coarse queue-wait model for admission control: EWMA dispatch
        seconds × (in-flight batches + this key's queued full batches +
        the batch this request would join).  None (admit) until the
        first measured completion seeds the EWMA."""
        ew = self._ewma.get(key)
        if ew is None:
            return None
        ahead = (len(self._inflight)
                 + len(self._pending.get(key, ())) // self.max_batch + 1)
        return ew * ahead

    def submit(self, req: AllocRequest) -> int:
        """Enqueue one request; returns its rid.  Flushes the bucket as
        soon as it holds ``max_batch`` requests (PR-8 behavior); with
        ``max_queue`` set, dispatch instead defers while the in-flight
        window is full and the bounded queue sheds lowest-priority-first.

        Fast-fail paths (all structured rows, never raises mid-stream):
        N exceeding the largest bucket, non-finite channel gains, an open
        circuit breaker, and admission control on ``deadline_s``."""
        t0 = time.perf_counter()
        if req.scheme not in SERVE_SCHEMES:
            raise ValueError(f"unknown scheme {req.scheme!r}; "
                             f"expected one of {SERVE_SCHEMES}")
        h2 = np.asarray(req.h2, np.float32).reshape(-1)
        n = h2.shape[0]
        if n == 0:
            raise ValueError("empty request (0 clients)")
        if not np.all(np.isfinite(h2)):
            return self._reject(req, n, "non-finite channel gains in h2",
                                t0)
        try:
            nb = self.bucket_for(n)     # single source of the oversize msg
        except ValueError as e:
            return self._reject(req, n, str(e), t0)
        key = (nb, req.scheme, req.cfg.dinkelbach_inner, req.cfg.sic_mode)
        br = self._breakers.get(key)
        if br is not None and br.state == "open":
            if time.monotonic() - br.opened_at >= self.breaker_cooldown_s:
                self._breaker_transition(key, br, "half_open")
            else:
                self.stats["breaker_rejected"] += 1
                return self._reject(
                    req, n, f"circuit breaker open for "
                            f"{self._key_str(key)} "
                            f"({br.fails} consecutive bad batches)", t0)
        if req.deadline_s is not None:
            wait = self._predict_wait(key)
            if wait is not None and wait > req.deadline_s:
                self.stats["admission_rejected"] += 1
                return self._reject(
                    req, n, f"admission control: predicted queue wait "
                            f"{wait:.4f}s exceeds deadline "
                            f"{req.deadline_s:.4f}s", t0)
        order = np.argsort(-h2, kind="stable")      # SIC decode order
        d = np.broadcast_to(np.asarray(req.d, np.float32), (n,))[order]
        vm = np.broadcast_to(np.asarray(req.v_max, np.float32), (n,))[order]
        rid = self._next_rid
        self._next_rid += 1
        self._pending[key].append(_Pending(
            rid=rid, req=req, n=n, order=order, h2=h2[order], d=d, v_max=vm,
            t_submit=t0, eff_cfg=req.cfg, eff_scheme=req.scheme))
        self.stats["submitted"] += 1
        if self.max_queue is None:
            if len(self._pending[key]) >= self.max_batch:
                self._flush_key(key)               # PR-8 blocking path
        else:
            self._shed_over_bound()
            self._pump()
        return rid

    # -- bounded queue / shedding ------------------------------------------
    def _shed_over_bound(self) -> None:
        """Priority-ordered load shedding: while the pending total
        exceeds ``max_queue``, the LOWEST-priority, YOUNGEST (largest
        rid) queued request becomes a structured ``status="shed"`` row —
        older same-priority requests are closer to dispatch and survive."""
        while (sum(len(v) for v in self._pending.values())
               > self.max_queue):
            victim_key, victim_i = None, None
            victim_rank = None
            for key, rows in self._pending.items():
                for i, r in enumerate(rows):
                    if r.rid < 0:
                        continue                   # warmup probes exempt
                    rank = (r.req.priority, -r.rid)
                    if victim_rank is None or rank < victim_rank:
                        victim_rank, victim_key, victim_i = rank, key, i
            if victim_key is None:
                return
            r = self._pending[victim_key].pop(victim_i)
            if not self._pending[victim_key]:
                del self._pending[victim_key]
            self._emit_structured(
                r, "shed", f"bounded queue full (max_queue="
                           f"{self.max_queue}): shed priority "
                           f"{r.req.priority}", bucket=victim_key[0])

    def _reap_ready(self) -> None:
        """Opportunistically retire in-flight batches whose results are
        already on host (non-blocking ``is_ready`` poll) — the bounded-
        queue mode's replacement for the PR-8 blocking completion."""
        while self._inflight:
            head = self._inflight[0]
            try:
                if not head.out.energy.is_ready():
                    break
            except AttributeError:     # no is_ready on this array type
                break
            self._complete(self._inflight.popleft())

    def _pump(self) -> None:
        """Bounded-queue dispatch policy: reap ready batches, then
        dispatch full highest-priority chunks while the in-flight window
        has room.  Never blocks the producer — overflow is handled by
        ``_shed_over_bound``, partial batches wait for ``flush``."""
        self._reap_ready()
        progressed = True
        while progressed and len(self._inflight) <= self.max_inflight:
            progressed = False
            keys = sorted(
                self._pending,
                key=lambda k: -max((r.req.priority
                                    for r in self._pending[k]), default=0))
            for key in keys:
                if len(self._pending.get(key, ())) < self.max_batch:
                    continue
                chunk = self._take_chunk(key)
                if chunk:
                    self._dispatch_chunk(key, chunk)
                    progressed = True
                if len(self._inflight) > self.max_inflight:
                    return

    # -- dispatch -----------------------------------------------------------
    def _take_chunk(self, key: tuple) -> list:
        """Pop up to ``max_batch`` rows from this key's queue, highest
        priority first (stable — FIFO within a priority level, so the
        all-default stream packs exactly like PR 8).  Rows whose deadline
        already expired while queued emit ``status="timeout"`` without
        wasting a batch lane."""
        rows = self._pending.pop(key, [])
        now = time.perf_counter()
        live = []
        for r in rows:
            if (r.rid >= 0 and r.req.deadline_s is not None
                    and now - r.t_submit > r.req.deadline_s):
                self.stats["expired_in_queue"] += 1
                self._emit_structured(
                    r, "timeout", f"deadline {r.req.deadline_s:.4f}s "
                                  f"expired while queued", bucket=key[0])
            else:
                live.append(r)
        if not live:
            return []
        live.sort(key=lambda r: (-r.req.priority, r.rid))
        chunk, rest = live[:self.max_batch], live[self.max_batch:]
        if rest:
            self._pending[key] = rest + self._pending.pop(key, [])
        return chunk

    def _dispatch_chunk(self, key: tuple, rows: list) -> None:
        """Pack one padded batch and dispatch it, retrying transient
        dispatch failures with exponential backoff; a dispatch that
        still fails turns every request in the chunk into a structured
        ``"rejected"`` row and feeds the circuit breaker."""
        nb, scheme, inner, sic_mode = key
        b = self.batch_width                    # fixed batch width per
        n_real = len(rows)                      # executable (zero retraces)
        h2 = np.zeros((b, nb), np.float32)
        D = np.zeros((b, nb), np.float32)
        vm = np.zeros((b, nb), np.float32)
        mask = np.zeros((b, nb), bool)
        eps = np.zeros((b,), np.float32)
        for i, r in enumerate(rows):
            h2[i, :r.n] = r.h2
            D[i, :r.n] = r.d
            vm[i, :r.n] = r.v_max
            mask[i, :r.n] = True
            eps[i] = r.req.epsilon
        # dummy rows reuse the first request's physics (masked out anyway)
        cfgs = [r.eff_cfg for r in rows] + [rows[0].eff_cfg] * (b - n_real)
        phys = stack_physics(cfgs)
        keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(
            [r.req.seed for r in rows] + [0] * (b - n_real), jnp.uint32))
        last_err = None
        for attempt in range(self.dispatch_retries + 1):
            if attempt:
                self.stats["dispatch_retries"] += 1
                time.sleep(self.backoff_base_s * (2 ** (attempt - 1)))
            try:
                out = self._dispatch(phys, keys, h2, D, vm, eps, mask,
                                     jnp.asarray(self.tol, jnp.float32),
                                     scheme=scheme, max_iter=self.max_iter,
                                     inner=inner, sic_mode=sic_mode,
                                     shards=self.shards)
                break
            except Exception as e:              # noqa: BLE001 — seam errors
                last_err = e
        else:
            self.stats["dispatch_failures"] += 1
            self._breaker_record(key, bad=True)
            for r in rows:
                if r.rid >= 0:
                    self._emit_structured(
                        r, "rejected",
                        f"dispatch failed after "
                        f"{self.dispatch_retries + 1} attempts: "
                        f"{last_err}", bucket=nb)
            return
        self._inflight.append(_InFlight(key=key, pending=rows, out=out,
                                        t_dispatch=time.perf_counter()))
        self.stats["dispatches"] += 1
        self.stats["padded_slots"] += b - n_real

    def _flush_key(self, key: tuple) -> None:
        while True:
            chunk = self._take_chunk(key)
            if not chunk:
                return
            self._dispatch_chunk(key, chunk)
            while len(self._inflight) > self.max_inflight:
                self._complete(self._inflight.popleft())

    def flush(self) -> None:
        """Dispatch every partial batch (dummy-padded to the fixed width)."""
        for key in sorted(list(self._pending.keys())):
            self._flush_key(key)

    # -- circuit breaker ----------------------------------------------------
    def _breaker_transition(self, key: tuple, br: _Breaker,
                            state: str) -> None:
        self.breaker_log.append((self._key_str(key), br.state, state))
        del self.breaker_log[:-256]            # bounded transition history
        self.stats[f"breaker_{state}"] += 1
        br.state = state
        if state == "open":
            br.opened_at = time.monotonic()
        elif state == "closed":
            br.fails = 0

    def _breaker_record(self, key: tuple, bad: bool) -> None:
        """Feed one batch-health observation: ``breaker_threshold``
        consecutive bad batches (or one bad half-open probe) open the
        breaker; a healthy half-open probe closes it."""
        br = self._breakers.setdefault(key, _Breaker())
        if bad:
            br.fails += 1
            if br.state == "half_open" or (
                    br.state == "closed"
                    and br.fails >= self.breaker_threshold):
                self._breaker_transition(key, br, "open")
        else:
            if br.state == "half_open":
                self._breaker_transition(key, br, "closed")
            elif br.state == "closed":
                br.fails = 0

    # -- degraded retry -----------------------------------------------------
    def _ladder_next(self, r: _Pending):
        """Next applicable retry-ladder stage for an infeasible row, or
        None when exhausted.  ``relax_tmax`` applies to every
        deterministic scheme; ``fallback_oma`` only to the Stackelberg
        family (falling back from oma to oma is a no-op, and the random
        baseline earns no retries)."""
        i = r.stage
        while i < len(self.retry_ladder):
            s = self.retry_ladder[i]
            if s == "relax_tmax" and r.eff_scheme != "random":
                return i, s
            if s == "fallback_oma" and r.eff_scheme in ("proposed", "ideal",
                                                        "wo_dt"):
                return i, s
            i += 1
        return None

    def _requeue_retry(self, r: _Pending, nxt) -> None:
        i, stage = nxt
        if stage == "relax_tmax":
            cfg2 = dataclasses.replace(
                r.eff_cfg, t_max=r.eff_cfg.t_max * self.relax_factor)
            scheme2 = r.eff_scheme
            tag = f"relax_tmax:{self.relax_factor:g}"
        else:
            cfg2, scheme2, tag = r.eff_cfg, "oma", "fallback:oma"
        r2 = dataclasses.replace(r, eff_cfg=cfg2, eff_scheme=scheme2,
                                 stage=i + 1,
                                 degradation=r.degradation + (tag,))
        nb = self.bucket_for(r.n)
        self._pending[(nb, scheme2, cfg2.dinkelbach_inner,
                       cfg2.sic_mode)].append(r2)
        self.stats["retries"] += 1

    # -- completion ---------------------------------------------------------
    def _complete(self, inf: _InFlight) -> None:
        key = inf.key
        nb = key[0]
        try:
            out = jax.block_until_ready(inf.out)
        except Exception as e:         # device-side failure surfaces here
            self.stats["dispatch_failures"] += 1
            self._breaker_record(key, bad=True)
            for r in inf.pending:
                if r.rid >= 0:
                    self._emit_structured(
                        r, "rejected", f"batch execution failed: {e}",
                        bucket=nb)
            return
        dt = time.perf_counter() - inf.t_dispatch
        real = [i for i, r in enumerate(inf.pending) if r.rid >= 0]
        if real:
            # EWMA of measured dispatch latency feeds admission control;
            # warmup probes (compile-dominated, no real rows) don't seed it
            prev = self._ewma.get(key)
            self._ewma[key] = dt if prev is None else (
                self.ewma_alpha * dt + (1.0 - self.ewma_alpha) * prev)
        watchdog_trip = (self.watchdog_s is not None
                         and dt > self.watchdog_s)
        if watchdog_trip:
            self.stats["watchdog_trips"] += 1
        host = {f: np.asarray(getattr(out, f))
                for f in ("p", "q", "f", "alpha", "rates", "t_total",
                          "energy", "feasible", "iterations")}
        if real:
            idx = np.asarray(real)
            finite = all(np.all(np.isfinite(host[f][idx]))
                         for f in ("p", "t_total", "energy"))
            all_infeasible = not bool(np.any(host["feasible"][idx]))
            self._breaker_record(
                key, bad=((not finite) or watchdog_trip
                          or (self.breaker_on_infeasible
                              and all_infeasible)))
        now = time.perf_counter()
        for i, r in enumerate(inf.pending):
            if r.rid < 0:              # warmup probe row — not a user request
                continue
            row_finite = (np.all(np.isfinite(host["p"][i, :r.n]))
                          and np.isfinite(host["t_total"][i])
                          and np.isfinite(host["energy"][i]))
            feasible = bool(host["feasible"][i])
            if not row_finite:
                self._emit_structured(
                    r, "rejected", "non-finite allocation from solver",
                    bucket=nb)
                continue
            if (not feasible and self.degraded_retry
                    and r.req.allow_degraded):
                nxt = self._ladder_next(r)
                if nxt is not None:    # re-dispatch, don't emit yet
                    self._requeue_retry(r, nxt)
                    continue
            inv = np.empty_like(r.order)
            inv[r.order] = np.arange(r.n)        # SIC order → request order
            unsort = lambda a: np.ascontiguousarray(a[i, :r.n][inv])
            latency = now - r.t_submit
            late = (r.req.deadline_s is not None
                    and latency > r.req.deadline_s)
            if not feasible:
                status, error = "infeasible", \
                    "equilibrium violates the deadline/resource box"
            elif late:
                status = "timeout"
                error = (f"completed {latency:.4f}s after submit > "
                         f"deadline {r.req.deadline_s:.4f}s")
            else:
                status, error = "ok", ""
            self._done.append(AllocResult(
                rid=r.rid, n=r.n, bucket=nb, scheme=r.eff_scheme,
                p=unsort(host["p"]), q=unsort(host["q"]),
                f=unsort(host["f"]), alpha=unsort(host["alpha"]),
                rates=unsort(host["rates"]),
                t_total=float(host["t_total"][i]),
                energy=float(host["energy"][i]),
                feasible=feasible,
                iterations=int(host["iterations"][i]),
                latency_s=latency,
                status=status, error=error, priority=r.req.priority,
                deadline_s=r.req.deadline_s, degradation=r.degradation))
            self.stats["completed"] += 1
            self._lat[r.req.priority].append(latency)
            if not feasible:
                self.stats["infeasible"] += 1
            elif late:
                self.stats["timeout"] += 1
            elif r.degradation:
                self.stats["degraded_ok"] += 1

    def drain(self) -> list:
        """Flush all partial batches, retire all in-flight dispatches
        (looping until retry-ladder re-dispatches settle too), and
        return every accumulated result SORTED BY RID — one row per
        submitted rid, exactly once."""
        while self._pending or self._inflight:
            self.flush()
            while self._inflight:
                self._complete(self._inflight.popleft())
        done, self._done = self._done, []
        done.sort(key=lambda r: r.rid)
        return done

    # -- observability ------------------------------------------------------
    def health(self) -> dict:
        """Resilience snapshot: queue depths, breaker states, EWMA
        dispatch latencies, every counter, and per-priority p50/p99
        latency over the last ``latency_window`` completions."""
        lat = {}
        for pri in sorted(self._lat):
            arr = np.asarray(self._lat[pri], np.float64) * 1e3
            if arr.size:
                lat[str(pri)] = {
                    "n": int(arr.size),
                    "p50_ms": float(np.percentile(arr, 50)),
                    "p99_ms": float(np.percentile(arr, 99))}
        return {
            "queued": {self._key_str(k): len(v)
                       for k, v in self._pending.items() if v},
            "queued_total": sum(len(v) for v in self._pending.values()),
            "inflight": len(self._inflight),
            "breakers": {self._key_str(k): {"state": b.state,
                                            "fails": b.fails}
                         for k, b in self._breakers.items()},
            "breaker_transitions": list(self.breaker_log),
            "ewma_dispatch_s": {self._key_str(k): round(v, 6)
                                for k, v in self._ewma.items()},
            "counters": {k: int(v) for k, v in sorted(self.stats.items())},
            "latency_by_priority_ms": lat,
        }

    # -- pre-compilation ----------------------------------------------------
    def warmup(self, schemes: Sequence[str] = ("proposed",),
               cfg: GameConfig | None = None) -> float:
        """Compile every (bucket, scheme) executable with an all-dummy
        batch; returns the wall seconds spent (the cold-start tax a warm
        deployment never pays on the stream).  Probe rows (rid=-1) never
        surface in ``drain()``, ``stats["completed"]`` or the EWMA."""
        cfg = cfg or GameConfig()
        t0 = time.perf_counter()
        for scheme in schemes:
            for nb in self.buckets:
                key = (nb, scheme, cfg.dinkelbach_inner, cfg.sic_mode)
                row = _Pending(rid=-1, req=AllocRequest(h2=np.ones(1),
                                                        cfg=cfg,
                                                        scheme=scheme),
                               n=1, order=np.zeros(1, np.int64),
                               h2=np.ones(1, np.float32),
                               d=np.zeros(1, np.float32),
                               v_max=np.zeros(1, np.float32),
                               t_submit=time.perf_counter(),
                               eff_cfg=cfg, eff_scheme=scheme)
                self._pending[key] = [row]
                self._flush_key(key)
        while self._inflight:
            self._complete(self._inflight.popleft())
        return time.perf_counter() - t0
