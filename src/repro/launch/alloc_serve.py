"""Streaming allocation service: ragged-N continuous batching over the
masked Stackelberg engine (the ISSUE-6 tentpole).

The offline engine answers fixed-N, fixed-K questions; production is an
*online* stream of heterogeneous cells — every request carries its own
client count N, channel draws, and physics knobs, and clients join/drop
between rounds so N never stays put.  Recompiling per N would burn ~1 s
of XLA compile per distinct shape; this module instead routes requests
through a SMALL FIXED SET of bucket executables:

  * **N-buckets** — a request with n clients is padded up to the smallest
    bucket width nb ≥ n (default widths 8/16/32/64/128) with ZERO channel
    gains and an [nb] boolean mask.  Zero-gain padding is invisible to
    the SIC chain by construction (p·|h|² = 0 in every suffix sum — see
    ``repro.core.sic``), keeps the descending SIC order, and the mask
    erases the padded lanes from d_hat, the latency maxima, the energy
    sums and the feasibility test (``stackelberg._solve(mask=...)``), so
    a padded solve is BIT-IDENTICAL to the exact-N solve.
  * **request-batching** — up to ``max_batch`` same-bucket requests ride
    one dispatch as a leading vmap axis; partial batches are topped up
    with all-masked dummy rows so the executable's batch shape is fixed
    (zero retraces over a warm stream, counted by
    ``TRACE_COUNTS["serve_allocation"]``).  Per-request physics
    (t_max / bandwidth / model_bits / …) stack into [B]-leaved
    ``GamePhysics`` operands — heterogeneous cells share the executable.
  * **double-buffered dispatch** — flushes enqueue asynchronously (JAX
    async dispatch keeps the device busy) and block only when more than
    ``max_inflight`` batches are outstanding, overlapping host-side
    pack/unpack with device compute.  Operand buffers are donated to the
    executable (the [B, nb] inputs are dead after dispatch and XLA may
    reuse them for the outputs).

One executable exists per (scheme, bucket width, batch width,
dinkelbach_inner, sic_mode); ``warmup()`` pre-compiles the set so a
latency-SLA deployment pays no cold-start on the stream.

Results come back in the REQUEST'S OWN client order (the service sorts
into SIC order on the way in and unsorts on the way out).

Latency/throughput numbers for the mixed-N arrival trace live in
``benchmarks/serve_latency.py`` (→ ``BENCH_serve.json``, gated by
``scripts/check_bench.py``).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.stackelberg import (GameConfig, _oma_body, _random_body, _solve,
                                stack_physics)
from ..core.tracking import TRACE_COUNTS
from ..sharding import game_mesh

DEFAULT_BUCKETS = (8, 16, 32, 64, 128)
SERVE_SCHEMES = ("proposed", "ideal", "wo_dt", "oma", "oma_tdma", "random")


# ---------------------------------------------------------------------------
# the bucket executable
# ---------------------------------------------------------------------------
@partial(jax.jit,
         static_argnames=("scheme", "max_iter", "inner", "sic_mode",
                          "shards"),
         donate_argnums=(2, 3, 4, 5))
def _serve_batch_jit(phys, keys, h2, D, v_max, eps, mask, tol, scheme,
                     max_iter, inner, sic_mode, shards=1):
    """One padded bucket dispatch: B requests × nb client lanes.

    phys  : GamePhysics with [B] leaves (per-request physics knobs)
    keys  : [B, 2] PRNG keys (consumed by the "random" scheme only)
    h2    : [B, nb] channel gains, each row descending with a zero tail
    D     : [B, nb] data sizes (zero on padded lanes)
    v_max : [B, nb] insensitive fractions (zero on padded lanes)
    eps   : [B] per-request DT deviation
    mask  : [B, nb] bool, True on real client lanes
    tol   : Alg.-2 stopping tolerance (scalar operand)

    Static keys: scheme / max_iter / inner / sic_mode (+ the B, nb
    shapes).  Everything else — including every physics float — is a
    traced operand, so one executable serves arbitrarily heterogeneous
    cells.  The [B, nb] operand buffers (h2, D, v_max) and eps are
    donated — dead after dispatch, XLA reuses them for the matching
    [B, nb] outputs (p/q/f/alpha/rates) and the [B] scalars.  The
    GamePhysics leaves stay undonated: only two [B] f32 outputs exist
    to absorb eleven [B] leaves, and XLA warns on every unusable one.

    ``shards`` > 1 splits the batch axis over the 1D draw mesh via
    ``shard_map`` (each device solves its local rows' independent
    while_loops); the service sizes B to a device multiple, so the
    split is exact and the executable shape never changes.
    """
    TRACE_COUNTS["serve_allocation"] += 1

    def batch(ph_b, kk, h2_b, d_b, vm_b, eps_b, m_b, tl):
        def one(ph, key, h2_r, d_r, vm_r, eps_r, m_r):
            dtype = jnp.result_type(h2_r)
            if scheme in ("proposed", "ideal"):
                return _solve(ph, h2_r, d_r, vm_r, eps_r, max_iter, tl,
                              inner, sic_mode, mask=m_r)
            if scheme == "wo_dt":
                return _solve(ph, h2_r, d_r, jnp.zeros_like(h2_r),
                              jnp.zeros((), dtype), max_iter, tl, inner,
                              sic_mode, mask=m_r)
            if scheme == "oma":
                return _oma_body(ph, h2_r, d_r, vm_r, eps_r, inner,
                                 tdma=False, mask=m_r)
            if scheme == "oma_tdma":
                return _oma_body(ph, h2_r, d_r, vm_r, eps_r, inner,
                                 tdma=True, mask=m_r)
            if scheme == "random":
                return _random_body(ph, key, h2_r, d_r, vm_r, eps_r,
                                    mask=m_r)
            raise ValueError(f"unknown scheme {scheme!r}")

        return jax.vmap(one)(ph_b, kk, h2_b, d_b, vm_b, eps_b, m_b)

    if shards > 1:
        d = P(game_mesh.DRAW_AXIS)
        batch = shard_map(batch, mesh=game_mesh.mesh_1d(shards),
                          in_specs=(d,) * 7 + (P(),), out_specs=d,
                          check_rep=False)
    return batch(phys, keys, h2, D, v_max, eps, mask, tol)


# ---------------------------------------------------------------------------
# requests / results
# ---------------------------------------------------------------------------
@dataclass
class AllocRequest:
    """One cell's allocation question.  ``h2`` may arrive in ANY client
    order — the service sorts into SIC order and unsorts the answer.
    ``d`` / ``v_max`` are scalars or per-client [n] arrays aligned with
    ``h2``'s order."""
    h2: object
    d: object = 200.0
    v_max: object = 0.5
    cfg: GameConfig = field(default_factory=GameConfig)
    scheme: str = "proposed"
    epsilon: float = 0.0
    seed: int = 0              # per-request randomness ("random" scheme)


@dataclass
class AllocResult:
    """Per-request allocation, in the request's own client order.

    ``status`` is the graceful-degradation contract (ISSUE-7 satellite):
      * ``"ok"``         — solved, ``feasible=True``.
      * ``"infeasible"`` — solved, but the equilibrium violates the
        deadline/resource box (``feasible=False``); the allocation arrays
        are still the solver's best answer — the caller decides whether
        to use, relax, or drop the cell.
      * ``"rejected"``   — never dispatched (e.g. N exceeds the largest
        bucket); allocation arrays are NaN, ``error`` says why.  A bad
        request yields a structured row instead of killing the in-flight
        stream.
    """
    rid: int
    n: int
    bucket: int
    scheme: str
    p: np.ndarray
    q: np.ndarray
    f: np.ndarray
    alpha: np.ndarray
    rates: np.ndarray
    t_total: float
    energy: float
    feasible: bool
    iterations: int
    latency_s: float           # submit → result available on host
    status: str = "ok"
    error: str = ""


@dataclass
class _Pending:
    rid: int
    req: AllocRequest
    n: int
    order: np.ndarray          # SIC sort permutation of the request's h2
    h2: np.ndarray             # [n] sorted descending
    d: np.ndarray              # [n] aligned with h2
    v_max: np.ndarray          # [n]
    t_submit: float


@dataclass
class _InFlight:
    key: tuple
    pending: list               # the real _Pending rows (dummies excluded)
    out: object                 # device Allocation, [B, nb] fields
    t_dispatch: float


class AllocationService:
    """Continuous-batching scheduler over the masked bucket executables.

    submit() enqueues (auto-flushing full batches), flush() force-packs
    partial batches with dummy rows, drain() completes everything and
    returns the accumulated ``AllocResult``s.  ``warmup()`` pre-compiles
    the bucket set.  See the module docstring for the design.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_batch: int = 8, max_inflight: int = 2,
                 max_iter: int = 20, tol: float = 1e-6):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket widths {buckets}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_batch = int(max_batch)
        # multi-device: shard the batch axis of every bucket dispatch —
        # the fixed dispatch width rounds up to a device multiple once at
        # init (extra rows are all-masked dummies, same as partial-batch
        # fill), so the executable shape stays retrace-free
        self.shards = game_mesh.batch_shards(self.max_batch)
        self.batch_width = game_mesh.padded_size(self.max_batch, self.shards)
        self.max_inflight = int(max_inflight)
        self.max_iter = int(max_iter)
        self.tol = float(tol)
        self._next_rid = 0
        self._pending: dict = collections.defaultdict(list)
        self._inflight: collections.deque = collections.deque()
        self._done: list = []
        self.stats = collections.Counter()

    # -- intake -------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"request with {n} clients exceeds the largest "
                         f"bucket {self.buckets[-1]}; widen `buckets`")

    def _reject(self, req: AllocRequest, n: int, why: str) -> int:
        """Graceful degradation: a request the service cannot dispatch
        becomes a structured per-request error row (status="rejected",
        NaN allocation) instead of an exception that kills the in-flight
        stream.  Malformed LOCAL input (empty request, unknown scheme)
        still raises from ``submit`` — those are caller bugs, not stream
        conditions."""
        rid = self._next_rid
        self._next_rid += 1
        nanv = np.full((max(n, 0),), np.nan, np.float32)
        self._done.append(AllocResult(
            rid=rid, n=n, bucket=0, scheme=req.scheme,
            p=nanv, q=nanv.copy(), f=nanv.copy(), alpha=nanv.copy(),
            rates=nanv.copy(), t_total=float("nan"), energy=float("nan"),
            feasible=False, iterations=0, latency_s=0.0,
            status="rejected", error=why))
        self.stats["rejected"] += 1
        return rid

    def submit(self, req: AllocRequest) -> int:
        """Enqueue one request; returns its rid.  Flushes the bucket as
        soon as it holds ``max_batch`` requests.

        A request whose N exceeds the largest bucket is not dispatchable:
        it completes immediately as a ``status="rejected"`` result (see
        ``AllocResult``) rather than raising into the stream."""
        if req.scheme not in SERVE_SCHEMES:
            raise ValueError(f"unknown scheme {req.scheme!r}; "
                             f"expected one of {SERVE_SCHEMES}")
        h2 = np.asarray(req.h2, np.float32).reshape(-1)
        n = h2.shape[0]
        if n == 0:
            raise ValueError("empty request (0 clients)")
        if n > self.buckets[-1]:
            return self._reject(
                req, n, f"request with {n} clients exceeds the largest "
                        f"bucket {self.buckets[-1]}; widen `buckets`")
        nb = self.bucket_for(n)
        order = np.argsort(-h2, kind="stable")      # SIC decode order
        d = np.broadcast_to(np.asarray(req.d, np.float32), (n,))[order]
        vm = np.broadcast_to(np.asarray(req.v_max, np.float32), (n,))[order]
        rid = self._next_rid
        self._next_rid += 1
        key = (nb, req.scheme, req.cfg.dinkelbach_inner, req.cfg.sic_mode)
        self._pending[key].append(_Pending(
            rid=rid, req=req, n=n, order=order, h2=h2[order], d=d, v_max=vm,
            t_submit=time.perf_counter()))
        self.stats["submitted"] += 1
        if len(self._pending[key]) >= self.max_batch:
            self._flush_key(key)
        return rid

    # -- dispatch -----------------------------------------------------------
    def _flush_key(self, key: tuple) -> None:
        rows = self._pending.pop(key, [])
        if not rows:
            return
        nb, scheme, inner, sic_mode = key
        b = self.batch_width                    # fixed batch width per
        n_real = len(rows)                      # executable (zero retraces)
        h2 = np.zeros((b, nb), np.float32)
        D = np.zeros((b, nb), np.float32)
        vm = np.zeros((b, nb), np.float32)
        mask = np.zeros((b, nb), bool)
        eps = np.zeros((b,), np.float32)
        for i, r in enumerate(rows):
            h2[i, :r.n] = r.h2
            D[i, :r.n] = r.d
            vm[i, :r.n] = r.v_max
            mask[i, :r.n] = True
            eps[i] = r.req.epsilon
        # dummy rows reuse the first request's physics (masked out anyway)
        cfgs = [r.req.cfg for r in rows] + [rows[0].req.cfg] * (b - n_real)
        phys = stack_physics(cfgs)
        keys = jax.vmap(jax.random.PRNGKey)(jnp.asarray(
            [r.req.seed for r in rows] + [0] * (b - n_real), jnp.uint32))
        out = _serve_batch_jit(phys, keys, h2, D, vm, eps, mask,
                               jnp.asarray(self.tol, jnp.float32),
                               scheme=scheme, max_iter=self.max_iter,
                               inner=inner, sic_mode=sic_mode,
                               shards=self.shards)
        self._inflight.append(_InFlight(key=key, pending=rows, out=out,
                                        t_dispatch=time.perf_counter()))
        self.stats["dispatches"] += 1
        self.stats["padded_slots"] += b - n_real
        while len(self._inflight) > self.max_inflight:
            self._complete(self._inflight.popleft())

    def flush(self) -> None:
        """Dispatch every partial batch (dummy-padded to the fixed width)."""
        for key in sorted(self._pending.keys()):
            self._flush_key(key)

    # -- completion ---------------------------------------------------------
    def _complete(self, inf: _InFlight) -> None:
        out = jax.block_until_ready(inf.out)
        nb = inf.key[0]
        host = {f: np.asarray(getattr(out, f))
                for f in ("p", "q", "f", "alpha", "rates", "t_total",
                          "energy", "feasible", "iterations")}
        now = time.perf_counter()
        for i, r in enumerate(inf.pending):
            if r.rid < 0:              # warmup probe row — not a user request
                continue
            inv = np.empty_like(r.order)
            inv[r.order] = np.arange(r.n)        # SIC order → request order
            unsort = lambda a: np.ascontiguousarray(a[i, :r.n][inv])
            feasible = bool(host["feasible"][i])
            self._done.append(AllocResult(
                rid=r.rid, n=r.n, bucket=nb, scheme=r.req.scheme,
                p=unsort(host["p"]), q=unsort(host["q"]),
                f=unsort(host["f"]), alpha=unsort(host["alpha"]),
                rates=unsort(host["rates"]),
                t_total=float(host["t_total"][i]),
                energy=float(host["energy"][i]),
                feasible=feasible,
                iterations=int(host["iterations"][i]),
                latency_s=now - r.t_submit,
                status="ok" if feasible else "infeasible",
                error="" if feasible else
                      "equilibrium violates the deadline/resource box"))
            self.stats["completed"] += 1
            if not feasible:
                self.stats["infeasible"] += 1

    def drain(self) -> list:
        """Flush all partial batches, retire all in-flight dispatches, and
        return every accumulated result (submit order not guaranteed —
        order by ``rid`` for a stable view)."""
        self.flush()
        while self._inflight:
            self._complete(self._inflight.popleft())
        done, self._done = self._done, []
        return done

    # -- pre-compilation ----------------------------------------------------
    def warmup(self, schemes: Sequence[str] = ("proposed",),
               cfg: GameConfig | None = None) -> float:
        """Compile every (bucket, scheme) executable with an all-dummy
        batch; returns the wall seconds spent (the cold-start tax a warm
        deployment never pays on the stream)."""
        cfg = cfg or GameConfig()
        t0 = time.perf_counter()
        for scheme in schemes:
            for nb in self.buckets:
                key = (nb, scheme, cfg.dinkelbach_inner, cfg.sic_mode)
                row = _Pending(rid=-1, req=AllocRequest(h2=np.ones(1),
                                                        cfg=cfg,
                                                        scheme=scheme),
                               n=1, order=np.zeros(1, np.int64),
                               h2=np.ones(1, np.float32),
                               d=np.zeros(1, np.float32),
                               v_max=np.zeros(1, np.float32),
                               t_submit=time.perf_counter())
                self._pending[key] = [row]
                self._flush_key(key)
        while self._inflight:
            self._complete(self._inflight.popleft())
        return time.perf_counter() - t0
