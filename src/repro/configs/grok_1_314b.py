"""grok-1-314b [moe] — 8 experts, top-2, hf:xai-org/grok-1.

64L, d_model=6144, 48H (GQA kv=8), head_dim=128, per-expert d_ff=32768,
vocab=131072.  Attention-logit softcap 30 (grok's tanh logit clamp).
bf16 storage + bf16 optimizer moments to fit the 16 GB/chip HBM budget.
"""
from repro.models.config import MOE, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48, num_kv_heads=8, head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        pattern=(BlockSpec(kind=MOE),),
        num_experts=8,
        num_experts_per_tok=2,
        attn_softcap=30.0,
        activation="swiglu",      # grok's GeGLU experts (3 matrices → 314B total)
        tie_embeddings=True,
        param_dtype="bfloat16",
        train_microbatches=32,
        seq_shard_activations=True,
        grad_accum_dtype="bfloat16",
        optimizer_lowp_update=True,
        kv_cache_dtype="int8",   # halves decode KV residency (§Perf)
        moe_chunk_tokens=16_384,
    )
