"""olmoe-1b-7b [moe] — 64 experts, top-8, arXiv:2409.02060.

16L, d_model=2048, 16H (GQA kv=16), per-expert d_ff=1024, vocab=50304.
1B active / 7B total parameters.
"""
from repro.models.config import MOE, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        pattern=(BlockSpec(kind=MOE),),
        num_experts=64,
        num_experts_per_tok=8,
        qk_norm=True,
        tie_embeddings=True,
        moe_impl="ep",   # shard_map all-to-all expert parallelism (§Perf)
        train_microbatches=8,
    )
