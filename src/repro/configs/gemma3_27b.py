"""gemma3-27b [dense] — 5:1 local:global attention, 128k context,
hf:google/gemma-3-1b-pt (family card).

62L, d_model=5376, 32H (GQA kv=16), head_dim=128, d_ff=21504, vocab=262144.
Pattern: 5 sliding-window(1024) layers per global layer; qk-norm (gemma3
dropped softcaps in favour of qk-norm).  62 = 10×6 + 2 remainder locals.
"""
from repro.models.config import ATTN, BlockSpec, ModelConfig


def config() -> ModelConfig:
    local = BlockSpec(kind=ATTN, window=1024)
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32, num_kv_heads=16, head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        pattern=(local, local, local, local, local, BlockSpec(kind=ATTN)),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        train_microbatches=16,
    )
