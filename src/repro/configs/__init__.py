"""Architecture config registry.

Each module defines ``config() -> ModelConfig`` with the exact assigned
specification (source cited in the module docstring) and the registry maps
``--arch`` ids to them.  ``smoke_variant`` derives the reduced CPU-testable
configuration (≤2 pattern repetitions, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from ..models.config import BlockSpec, ModelConfig

ARCH_IDS = [
    "mamba2_2p7b", "seamless_m4t_large_v2", "gemma2_9b", "gemma3_27b",
    "olmoe_1b_7b", "grok_1_314b", "granite_3_8b", "nemotron_4_340b",
    "internvl2_76b", "zamba2_2p7b",
]

# public pool ids (dashes) → module names
ALIASES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "gemma2-9b": "gemma2_9b",
    "gemma3-27b": "gemma3_27b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "grok-1-314b": "grok_1_314b",
    "granite-3-8b": "granite_3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2.7b": "zamba2_2p7b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 pattern groups, d_model ≤ 512,
    ≤4 experts — runs a forward/train step on CPU."""
    kv = 4 if cfg.num_kv_heads >= cfg.num_heads else 2
    pattern = tuple(BlockSpec(kind=s.kind, window=min(s.window, 8) if s.window else 0)
                    for s in cfg.pattern)
    return cfg.replace(
        num_layers=2 * len(pattern),
        d_model=256,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        pattern=pattern,
        num_experts=min(4, cfg.num_experts) if cfg.num_experts else 0,
        num_experts_per_tok=min(2, cfg.num_experts_per_tok)
        if cfg.num_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_patch_tokens=16 if cfg.num_patch_tokens else 0,
        train_microbatches=1,
        param_dtype="float32",
        dtype="float32",
        remat=False,
    )
