"""internvl2-76b [vlm] — InternViT + (Llama-3-70B-class) LM backbone,
arXiv:2404.16821.

LM backbone: 80L, d_model=8192, 64H (GQA kv=8), head_dim=128, d_ff=28672,
vocab=128256.  The InternViT vision encoder + projector are STUBS per the
assignment: ``input_specs`` provides 1024 precomputed patch embeddings
[B, 1024, d_model] prefixed to the text sequence.
"""
from repro.models.config import ATTN, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64, num_kv_heads=8, head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        pattern=(BlockSpec(kind=ATTN),),
        num_patch_tokens=1024,
        tie_embeddings=False,
        param_dtype="bfloat16",
        train_microbatches=32,
        seq_shard_activations=True,
        grad_accum_dtype="bfloat16",
        optimizer_lowp_update=True,
        kv_cache_dtype="int8",   # halves decode KV residency (§Perf)
    )
