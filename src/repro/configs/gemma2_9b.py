"""gemma2-9b [dense] — local+global alternating attention, logit softcaps,
arXiv:2408.00118.

42L, d_model=3584, 16H (GQA kv=8), head_dim=256, d_ff=14336, vocab=256000.
Pattern: alternating sliding-window(4096) / global layers; attn softcap 50,
final-logit softcap 30.
"""
from repro.models.config import ATTN, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        pattern=(BlockSpec(kind=ATTN, window=4096), BlockSpec(kind=ATTN)),
        attn_softcap=50.0,
        logit_softcap=30.0,
        tie_embeddings=True,
        train_microbatches=8,
    )
