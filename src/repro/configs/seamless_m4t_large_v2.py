"""seamless-m4t-large-v2 [audio] — enc-dec multimodal, arXiv:2308.11596.

24L decoder (+24L encoder backbone), d_model=1024, 16H (GQA kv=16),
d_ff=8192, vocab=256206.  The mel-spectrogram/conv frontend is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
[B, seq_len//4, d_model].
"""
from repro.models.config import CROSS, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16, num_kv_heads=16, head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        pattern=(BlockSpec(kind=CROSS),),
        activation="gelu",
        encoder_layers=24,
        encoder_ratio=4,
        tie_embeddings=True,
        train_microbatches=8,
    )
