"""nemotron-4-340b [dense] — GQA + squared-ReLU MLP, arXiv:2402.16819.

96L, d_model=18432, 96H (GQA kv=8), head_dim=192, d_ff=73728, vocab=256000.
bf16 storage + bf16 optimizer moments (16 GB/chip budget; DESIGN.md §5);
aggressive microbatching (global 256 → micro 4).
"""
from repro.models.config import ATTN, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96, num_kv_heads=8, head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        pattern=(BlockSpec(kind=ATTN),),
        activation="squared_relu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        train_microbatches=64,
        seq_shard_activations=True,
        grad_accum_dtype="bfloat16",
        optimizer_lowp_update=True,
        kv_cache_dtype="int8",   # halves decode KV residency (§Perf)
    )
