"""mamba2-2.7b [ssm] — SSD (state-space duality), arXiv:2405.21060.

64L, d_model=2560, attention-free, vocab=50280, ssm_state=128.
d_inner = 2·2560 = 5120, head_dim 64 → 80 SSM heads, ngroups=1.
"""
from repro.models.config import MAMBA, BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=1, num_kv_heads=1, head_dim=64,   # no attention blocks
        d_ff=0,
        vocab_size=50280,
        pattern=(BlockSpec(kind=MAMBA),),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        tie_embeddings=True,
        train_microbatches=8,
    )
