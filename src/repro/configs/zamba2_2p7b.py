"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks,
arXiv:2411.15242.

54L, d_model=2560, 32H (GQA kv=32), d_ff=10240, vocab=32000, ssm_state=64.
Pattern: 5 Mamba2 blocks + 1 shared attention block (two parameter sets
alternating across the 9 groups — Zamba2's weight-shared global blocks).
"""
from repro.models.config import MAMBA, SHARED_ATTN, BlockSpec, ModelConfig


def config() -> ModelConfig:
    m = BlockSpec(kind=MAMBA)
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32, num_kv_heads=32, head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        pattern=(m, m, m, m, m, BlockSpec(kind=SHARED_ATTN)),
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        tie_embeddings=True,
        train_microbatches=8,
    )
