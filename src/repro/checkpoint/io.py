"""Checkpoint save/restore: pytree → directory of .npy shards + JSON manifest.

No orbax dependency.  Arrays are written host-local (fully addressable view);
the manifest records the flattened tree structure so restore round-trips
exactly.  Deliberately simple but real: atomic rename, step retention.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree: Any, step: int, keep: int = 3) -> str:
    """Write ``tree`` under ``path/step_{step:08d}`` atomically."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_")
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "num_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or orig_dtype == "bfloat16":
            # numpy can't serialize ml_dtypes (bfloat16 etc.) — upcast to
            # f32 (exact for bf16); restore re-casts to the reference dtype
            arr = arr.astype(np.float32)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append({"index": i, "shape": list(arr.shape),
                                   "dtype": orig_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(path, keep)
    return final


def _retain(path: str, keep: int):
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d))


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore_checkpoint(path: str, like: Any, step: int | None = None) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert manifest["num_leaves"] == len(leaves), "tree structure mismatch"
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        assert list(arr.shape) == list(ref.shape), (i, arr.shape, ref.shape)
        out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
