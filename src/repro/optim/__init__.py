from .adamw import AdamWConfig, adamw_update, global_norm, init_opt_state
from .schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
           "constant", "warmup_cosine"]
