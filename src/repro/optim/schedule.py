"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup: int = 100, total: int = 10000,
                  floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor`` × peak."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step):
    return 1.0
