"""AdamW with per-tensor dtype policies and global-norm clipping.

No optax dependency: pure-pytree implementation.  ``moment_dtype`` lets the
340B-class configs keep first/second moments in bf16 so the optimizer state
fits the 16 GB/chip HBM budget (see DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # Leaves larger than this (bytes) with a leading stack axis get their
    # update scanned over that axis.  §Perf iter-3 verdict: REFUTED — the
    # scan stages copies of (g, mu, nu, p) into the loop, costing more than
    # the temps it saves (nemotron 30.3 → 47.1 GiB).  Kept for the record;
    # leave 0.
    chunked_update_bytes: int = 0     # 0 = disabled
    # §Perf iter-4: run the update math in the moment dtype instead of f32
    # (halves the elementwise temps when moments are bf16; the weight
    # update itself still applies in f32 master precision).
    update_in_moment_dtype: bool = False


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 lr_scale=1.0) -> Tuple[Any, Dict]:
    """Returns (new_params, new_opt_state)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    mdt = jnp.dtype(cfg.moment_dtype)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd_math(g, mu, nu, p):
        wdt = mdt if cfg.update_in_moment_dtype else jnp.float32
        gw = g.astype(wdt) * jnp.asarray(scale, wdt)
        muw = (jnp.asarray(cfg.b1, wdt) * mu.astype(wdt)
               + jnp.asarray(1 - cfg.b1, wdt) * gw)
        nuw = (jnp.asarray(cfg.b2, wdt) * nu.astype(wdt)
               + jnp.asarray(1 - cfg.b2, wdt) * gw * gw)
        step = (muw / b1c.astype(wdt)) / (jnp.sqrt(nuw / b2c.astype(wdt))
                                          + jnp.asarray(cfg.eps, wdt))
        step = step + jnp.asarray(cfg.weight_decay, wdt) * p.astype(wdt)
        newp = p.astype(jnp.float32) - lr * step.astype(jnp.float32)
        return newp.astype(p.dtype), muw.astype(mdt), nuw.astype(mdt)

    def upd(g, mu, nu, p):
        big = (cfg.chunked_update_bytes
               and p.ndim >= 2 and p.shape[0] >= 8
               and p.size * 4 >= cfg.chunked_update_bytes)
        if not big:
            return upd_math(g, mu, nu, p)
        # scan the update over the leading (layer-stack) axis: f32 temps
        # shrink by the stack size
        def body(_, xs):
            return None, upd_math(*xs)
        _, (newp, mu2, nu2) = jax.lax.scan(body, None, (g, mu, nu, p))
        return newp, mu2, nu2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, mu, nu, p) for g, mu, nu, p
           in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}
