"""Large-N SIC transmit-power engine (paper §V-B-3, Eqs. 35–45).

The paper optimizes the N clients' uplink powers SUCCESSIVELY in SIC decode
order: client n's Dinkelbach subproblem (Eqs. 38–45) sees the effective
gain

    F_n = |h_n|² / (Σ_{j>n} p_j·|h_j|² + σ²)              (Eq. 36 denominator)

built from the ALREADY-optimized powers of later-decoded clients, so the
reference implementation (``dinkelbach.successive_power``) is an O(N)
sequential reverse ``lax.scan`` — exact in one pass (reverse Gauss–Seidel
on a strictly triangular dependency), but serial in N: the ROADMAP's
large-N open item.

This module computes the SAME fixed point with Jacobi-style sweeps that
parallelize over the client axis:

  sweep k:   I_n ← Σ_{j>n} p_j^{(k)}·|h_j|²     (parallel suffix scan)
             p_n^{(k+1)} ← Dinkelbach(F_n(I_n))  (vmap over all N clients)

iterated inside a ``lax.while_loop`` until the power vector is stationary
(max|Δp| ≤ 1e-6·p_max).  Convergence argument: the dependency p_n ← {p_j :
j > n} is strictly triangular, so after sweep k the trailing k clients'
powers are EXACT — N sweeps reproduce the sequential solution identically,
and the while-loop bound is set to N as that backstop.  In practice the
interference coupling is a strong contraction (σ² plus later powers damp
each update) and the sweeps converge geometrically: ~4–17 sweeps at any N
measured (so the blocked engine does O(sweeps·N) parallel work instead of
an O(N) serial chain).  A stationary point of the sweep map IS the unique
SIC fixed point, so parity with the sequential scan is ≤1e-5 by
construction (asserted in tests/test_sic.py).

The suffix interference Σ_{j>n} p_j|h_j|² is an exclusive suffix sum —
routed through ``kernels.ops.sic_suffix_sum`` with the same mode switch as
the model kernels (``auto | pallas | interpret | ref``): jnp flip-cumsum
oracle on CPU, blocked Pallas scan (``kernels/sic_suffix.py``) on TPU or
under the CPU interpreter for validation.

Padded (masked) tails — the ragged-N serving contract: the allocation
service (``repro.launch.alloc_serve``) pads variable-N cells up to a
bucket width with ZERO channel gains at the tail of the SIC order.  Both
engines here are invariant to such tails by construction, with no mask
operand needed at this level:

  * interference: a padded lane contributes p·|h|² = p·0 = 0 to every
    suffix sum, so real clients' effective gains F_n match the exact-N
    solve — bit-identical through the Pallas kernel's sequential carry
    (zero blocks add exactly 0.0); the jnp flip-cumsum oracle is an XLA
    associative tree whose shape changes with padding, so it lands
    within the repo's 1e-5 relative budget instead;
  * the padded lane itself: F = 0 ⇒ rate ≡ 0, the Dinkelbach rate-floor
    power goes to +inf and is clipped to the box top, so p = p_max,
    q = 0 — finite, and discarded by the service's mask anyway;
  * SIC ordering: gains sort descending, so an all-zero tail never
    interleaves with real clients;
  * sweep count (blocked engine): padded lanes are stationary after the
    first sweep (Δp = 0), so the while-loop exit is driven by the real
    lanes exactly as in the exact-N solve.

``tests/test_sic.py::TestPaddedTail`` asserts all of this; the masking of
round-level reductions (latency maxima, energy sums) lives one level up
in ``stackelberg.round_metrics``.

Mode switch (the static ``sic_mode`` key on ``GameConfig``, threaded
through every engine tier):

  * ``sequential``        — the reverse-scan reference (default);
  * ``blocked``           — Jacobi sweeps, jnp suffix scan;
  * ``blocked_interpret`` — Jacobi sweeps, Pallas suffix kernel in
                            interpret mode (CPU validation of the kernel);
  * ``blocked_pallas``    — Jacobi sweeps, compiled Pallas suffix kernel
                            (TPU backends).

Differentiability contract (the IFT path, ``core.implicit``): BOTH
families converge to the SAME fixed point — the dependency ``p_n ← {p_j :
j > n}`` is strictly triangular — so reverse-mode gradients through the
equilibrium never differentiate these solvers at all.  The ``custom_vjp``
linearizes ONE differentiable Algorithm-2 sweep at the solution instead,
and that sweep always takes ``suffix_interference(..., mode="ref")``: the
flip-cumsum closed form is the designated grad-safe path, while the
scan/while_loop/Pallas engines here remain forward-value-only (their
1e-6-clamped update rules would need the double-``where`` treatment of
``dinkelbach._inner_projected`` if anyone ever backprops them directly —
don't; route gradients through ``equilibrium_implicit``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..kernels.ops import sic_suffix_sum
from .dinkelbach import dinkelbach_power, successive_power
from .tracking import TRACE_COUNTS

SIC_MODES = ("sequential", "blocked", "blocked_interpret", "blocked_pallas")

# sic_mode → the kernels.ops.sic_suffix_sum mode the sweeps refresh with
_SUFFIX_MODE = {"blocked": "ref", "blocked_interpret": "interpret",
                "blocked_pallas": "pallas"}

# sweep stationarity: max|Δp| ≤ REL_TOL·p_max exits early; the N-sweep
# backstop guarantees the exact sequential fixed point regardless
REL_TOL = 1e-6


def suffix_interference(w, mode: str = "ref", block: int = 128):
    """Exclusive suffix sum s[..., n] = Σ_{j>n} w[..., j] — the interference
    each client sees from later-decoded clients (w = p·|h|²)."""
    return sic_suffix_sum(w, block=block, mode=mode)


@partial(jax.jit, static_argnames=("inner", "suffix_mode", "max_sweeps",
                                   "return_sweeps", "early_exit"))
def successive_power_blocked(h2_sorted, d, g, bandwidth, sigma2, p_min,
                             p_max, inner: str = "projected",
                             suffix_mode: str = "ref",
                             max_sweeps: int | None = None,
                             return_sweeps: bool = False,
                             early_exit: bool = True):
    """All N clients' powers via Jacobi fixed-point sweeps — same fixed
    point as ``successive_power`` (the sequential reverse scan), but each
    sweep vmaps the N Dinkelbach solves against a frozen interference
    vector and refreshes it with one parallel suffix scan.

    h2_sorted: [N] descending (SIC decode order); d/g broadcast to [N].
    ``max_sweeps`` defaults to N (the exactness backstop — see module
    docstring); ``return_sweeps`` additionally returns the sweep count the
    while-loop actually ran (benchmark instrumentation).
    ``early_exit=False`` disables the stationarity test so the loop runs
    all ``max_sweeps`` sweeps — the triangular-exactness backstop path
    (tests exercise it directly; production callers leave it on).
    """
    TRACE_COUNTS["successive_power_blocked"] += 1
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    bound = n if max_sweeps is None else max_sweeps
    d_v = jnp.broadcast_to(d, h2_sorted.shape).astype(dtype)
    g_v = jnp.broadcast_to(g, h2_sorted.shape).astype(dtype)
    tol = jnp.asarray(REL_TOL, dtype) * p_max

    def sweep(p, q):
        intf = suffix_interference(p * h2_sorted, mode=suffix_mode)
        f_eff = h2_sorted / (intf + sigma2)
        # warm-start each client's Dinkelbach from the previous sweep's q:
        # the interference moves little between late sweeps, so the ratio
        # iteration lands in ~1-2 steps instead of ~6 from a cold start
        # (the fixed point is q-init-independent — see dinkelbach_power)
        p_n, q_n, _ = jax.vmap(
            lambda dd, gg, ff, qq: dinkelbach_power(dd, gg, ff, bandwidth,
                                                    p_min, p_max,
                                                    inner=inner, q_init=qq)
        )(d_v, g_v, f_eff, q)
        return p_n, q_n

    def cond(carry):
        _p, _q, it, done = carry
        return (~done) & (it < bound)

    def body(carry):
        p, q, it, _done = carry
        p_new, q_new = sweep(p, q)
        done = (jnp.max(jnp.abs(p_new - p)) < tol) if early_exit \
            else jnp.asarray(False)
        return (p_new, q_new, it + 1, done)

    p0 = jnp.full(h2_sorted.shape, 1.0, dtype) * p_max
    q0 = jnp.zeros(h2_sorted.shape, dtype)
    p, q, sweeps, _ = jax.lax.while_loop(
        cond, body, (p0, q0, jnp.asarray(0, jnp.int32), jnp.asarray(False)))
    # one unconditional polish sweep: the loop exits when Δp ≤ tol, and the
    # contraction (~0.3×/sweep) pulls the residue well under the ≤1e-5
    # parity budget vs the sequential scan (p-tolerance stacking otherwise
    # amplifies into q through the interference term)
    p, _q = sweep(p, q)
    # q = R(p*)/U(p*) at the RETURNED p and its own interference — the
    # sweep's Dinkelbach q was evaluated against the previous iterate's
    # interference (one sweep stale), which costs ~1e-4 on q near strong
    # coupling even when p is already stationary
    intf = suffix_interference(p * h2_sorted, mode=suffix_mode)
    f_eff = h2_sorted / (intf + sigma2)
    rate = bandwidth * jnp.log2(1.0 + p * f_eff)
    q = rate / jnp.maximum(p * d_v, 1e-30)
    if return_sweeps:
        return p, q, sweeps
    return p, q


def successive_power_eager(h2_sorted, d, g, bandwidth, sigma2, p_min, p_max,
                           inner: str = "projected"):
    """Host-side reference: a Python loop over clients N → 1, accumulating
    the interference as a float — the slowest, most literal reading of
    §V-B-3, kept purely as the numerical oracle for the scan/blocked
    engines (tests).  Not jit/vmap-able."""
    h2_sorted = jnp.asarray(h2_sorted)
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    d_v = jnp.broadcast_to(jnp.asarray(d, dtype), (n,))
    g_v = jnp.broadcast_to(jnp.asarray(g, dtype), (n,))
    ps, qs = [0.0] * n, [0.0] * n
    intf = 0.0
    for i in range(n - 1, -1, -1):
        f_eff = h2_sorted[i] / (intf + sigma2)
        p_i, q_i, _ = dinkelbach_power(d_v[i], g_v[i], f_eff, bandwidth,
                                       p_min, p_max, inner=inner)
        ps[i], qs[i] = p_i, q_i
        intf = intf + float(p_i) * float(h2_sorted[i])
    return jnp.stack(ps).astype(dtype), jnp.stack(qs).astype(dtype)


def successive_power_any(h2_sorted, d, g, bandwidth, sigma2, p_min, p_max,
                         inner: str = "projected",
                         sic_mode: str = "sequential"):
    """Static-mode dispatch between the sequential reverse scan and the
    blocked fixed-point engine — the single entry the Stackelberg solver
    bodies call, so every tier (single/batched/sweep, and the FL round)
    opts into large-N mode through one key."""
    if sic_mode == "sequential":
        return successive_power(h2_sorted, d, g, bandwidth, sigma2, p_min,
                                p_max, inner=inner)
    if sic_mode not in _SUFFIX_MODE:
        raise ValueError(f"unknown sic_mode {sic_mode!r}; "
                         f"expected one of {SIC_MODES}")
    return successive_power_blocked(h2_sorted, d, g, bandwidth, sigma2,
                                    p_min, p_max, inner=inner,
                                    suffix_mode=_SUFFIX_MODE[sic_mode])
