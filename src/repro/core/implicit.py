"""Differentiable Stackelberg equilibrium via the implicit function theorem.

``equilibrium`` solves Algorithm 2 with a ``lax.while_loop`` — opaque to
reverse-mode AD (and unrolling it would be both wrong near the safeguard
and catastrophically expensive).  This module registers a ``custom_vjp``
on the equilibrium *fixed point* instead: the forward pass runs the
existing solver untouched, and the backward pass applies the implicit
function theorem at the solution.

Mathematical contract
---------------------
Let ``x = (f, p, q)`` and ``θ = (physics, h2_sorted, D, v)``.  At a
converged equilibrium ``x* = T(x*, θ)`` where ``T`` is one differentiable
Algorithm-2 sweep (``_fp_step``):

  * Dinkelbach power: ``p' = Π_[lo,hi](B/(ln2·q·d) − 1/F)`` against the
    suffix interference of the current ``p`` (Eq. 43 with the multipliers
    absorbed by the box), then ``q' = R(p')/U(p')`` at ``p'``'s own
    interference (the Dinkelbach ratio at its fixed point);
  * leader frequency: ``f' = clip(c(1−v)D/A_n, f_min, f_max)`` with
    ``A_n = max(t_max − t_com(p'), ·)`` (§V-B-2).

Both ``sic_mode`` families (the sequential reverse scan and the blocked
Jacobi sweeps) converge to the SAME fixed point — the dependency
``p_n ← {p_j : j > n}`` is strictly triangular — so this ONE backward map
serves both; the suffix scan inside it always uses the differentiable
``ref`` (flip-cumsum) path.

The IFT gives ``dx*/dθ = (I − ∂T/∂x)⁻¹ ∂T/∂θ``; the VJP therefore solves
the adjoint system ``w = g + (∂T/∂x)ᵀ w`` by Neumann/fixed-point
iteration (a ``lax.while_loop`` over the linearized map — NEVER a
backprop through the unrolled solver loop) and returns ``(∂T/∂θ)ᵀ w``.
The alternation is a contraction at regular equilibria (the same property
that makes Algorithm 2 converge), so the Neumann series converges
geometrically.

Validity contract (tested in tests/test_implicit.py):

  * gradients are meaningful only at CONVERGED, FEASIBLE equilibria — the
    fixed-point equation is what the IFT differentiates, and the
    best-iterate safeguard returns a non-fixed-point iterate exactly when
    the solve is infeasible;
  * ``feasible=False`` solves therefore get ZERO cotangents through the
    fixed point (the backward pass masks them), so a vmapped batch with a
    few infeasible draws still yields finite, well-defined gradients —
    only the direct (non-fixed-point) paths through ``_finish`` carry
    gradient for those lanes;
  * the forward solver's tolerances bound the gradient error: the
    returned point satisfies ``|x − T(x)| = O(tol + δ_dinkelbach)``, which
    composes with the ≤1e-3 relative gradcheck budget.

ε (the DT mapping deviation) never enters the leader fixed point — only
the follower finish (``d_hat → α → t_dt → latency``) — so ``∂E/∂ε ≡ 0``
by construction while latency gradients flow; this matches the paper's
Table-less observation that the deviation costs latency, not energy.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from . import noma
from .dinkelbach import _inner_projected, _p_floor
from .sic import suffix_interference
from .stackelberg import (Allocation, GameConfig, _finish, _solve, leader_f,
                          leader_v, local_compute_latency)
from .tracking import TRACE_COUNTS

__all__ = ["FixedPointStatics", "equilibrium_implicit", "fixed_point_step"]


@dataclass(frozen=True)
class FixedPointStatics:
    """Hashable solver statics for the custom_vjp (nondiff_argnums must be
    hashable by value — a ``functools.partial`` would retrace per call)."""
    max_iter: int = 20
    tol: float = 1e-6
    inner: str = "projected"
    sic_mode: str = "sequential"
    adjoint_iters: int = 100
    adjoint_tol: float = 1e-10
    masked: bool = False        # structural flag: mask operand present?


def fixed_point_step(x, theta):
    """One differentiable Algorithm-2 sweep ``T(x, θ)`` (see module doc).

    ``x = (f, p, q)`` each [N]; ``θ = (phys, h2_sorted, D, v)``.  Written
    exclusively with grad-safe closed forms (double-``where`` denominators)
    so its JVP/VJP are finite on masked lanes (h2 = 0), cold-start q = 0
    and saturated clip boundaries.
    """
    f, p, q = x
    phys, h2, D, v = theta
    c, d_bits = phys.cycles_per_sample, phys.model_bits
    dtype = jnp.result_type(h2)

    # --- Dinkelbach power against the current iterate's interference ----
    t_cmp = local_compute_latency(c, v, D, f)
    g_n = jnp.maximum(phys.t_max - t_cmp, 1e-3)         # rate-floor slack
    intf = suffix_interference(p * h2, mode="ref")
    f_eff = h2 / (intf + phys.sigma2)
    lo = jnp.minimum(_p_floor(d_bits, g_n, f_eff, phys.bandwidth,
                              phys.p_min), phys.p_max)
    hi = phys.p_max * jnp.ones_like(lo)
    p_new = _inner_projected(q, d_bits, f_eff, phys.bandwidth, lo, hi)

    # --- Dinkelbach ratio at p_new's own interference -------------------
    intf2 = suffix_interference(p_new * h2, mode="ref")
    f_eff2 = h2 / (intf2 + phys.sigma2)
    rates = phys.bandwidth * jnp.log2(1.0 + p_new * f_eff2)
    u = p_new * d_bits
    u_ok = u > 1e-30
    q_new = jnp.where(u_ok, rates / jnp.where(u_ok, u, jnp.ones((), dtype)),
                      jnp.zeros((), dtype))

    # --- leader frequency runs to the deadline --------------------------
    t_com = noma.tx_latency(d_bits, rates)
    a_n = jnp.maximum(phys.t_max - t_com, 1e-3)
    f_new = leader_f(c, v, D, a_n, phys.f_min, phys.f_max)
    return (f_new, p_new, q_new)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fp_solve(statics: FixedPointStatics, phys, h2, D, v, mask_f):
    """Solve the equilibrium fixed point; returns ``(f, p, q, feasible,
    iterations)`` with ``feasible`` as a float (so the backward pass can
    receive/emit well-typed cotangents and mask on it).  ``mask_f`` is the
    padded-bucket mask as floats (all-ones when ``statics.masked`` is
    False); it only shapes the forward reductions — its cotangent is
    zero."""
    TRACE_COUNTS["equilibrium_implicit"] += 1
    mask = (mask_f > 0.5) if statics.masked else None
    alloc = _solve(phys, h2, D, v, 0.0, statics.max_iter, statics.tol,
                   statics.inner, statics.sic_mode, mask)
    dtype = jnp.result_type(h2)
    return (alloc.f, alloc.p, alloc.q,
            jnp.asarray(alloc.feasible, dtype), alloc.iterations)


def _fp_fwd(statics, phys, h2, D, v, mask_f):
    TRACE_COUNTS["equilibrium_implicit_fwd"] += 1
    out = _fp_solve(statics, phys, h2, D, v, mask_f)
    f, p, q, feas, _it = out
    return out, (phys, h2, D, v, f, p, q, feas, mask_f)


def _fp_bwd(statics, res, cotangents):
    TRACE_COUNTS["equilibrium_implicit_bwd"] += 1
    phys, h2, D, v, f, p, q, feas, mask_f = res
    gf, gp, gq, _gfeas, _git = cotangents
    x = (f, p, q)
    theta = (phys, h2, D, v)

    # contract: infeasible solves are not fixed points of T (best-iterate
    # safeguard) — their cotangents through the equilibrium are zeroed
    ok = feas > 0.5
    g = tuple(jnp.where(ok, t, jnp.zeros_like(t)) for t in (gf, gp, gq))

    # Neumann/fixed-point adjoint:  w ← g + (∂T/∂x)ᵀ w   at (x*, θ)
    _, vjp_x = jax.vjp(lambda xx: fixed_point_step(xx, theta), x)
    tol = statics.adjoint_tol

    def cond(carry):
        _w, delta, it = carry
        return (delta > tol) & (it < statics.adjoint_iters)

    def body(carry):
        w, _delta, it = carry
        (aw,) = vjp_x(w)
        w_new = tuple(gi + ai for gi, ai in zip(g, aw))
        delta = sum(jnp.max(jnp.abs(wn - wo))
                    for wn, wo in zip(w_new, w))
        return (w_new, delta, it + 1)

    dtype = jnp.result_type(h2)
    w0 = (g, jnp.asarray(jnp.inf, dtype), jnp.asarray(0, jnp.int32))
    w, _delta, _it = jax.lax.while_loop(cond, body, w0)

    # pull the adjoint back through θ:  ḡθ = (∂T/∂θ)ᵀ w
    _, vjp_theta = jax.vjp(lambda th: fixed_point_step(x, th), theta)
    (gtheta,) = vjp_theta(w)
    return gtheta + (jnp.zeros_like(mask_f),)  # (phys, h2, D, v, mask_f)


_fp_solve.defvjp(_fp_fwd, _fp_bwd)


def equilibrium_implicit(cfg, h2_sorted, D, v_max, epsilon=0.0,
                         max_iter: int = 20, tol: float = 1e-6,
                         inner: str | None = None,
                         sic_mode: str | None = None,
                         mask=None,
                         adjoint_iters: int = 100,
                         adjoint_tol: float = 1e-10) -> Allocation:
    """Differentiable Algorithm 2: identical forward values to
    ``equilibrium`` (same ``_solve``), with gradients through the solution
    via the IFT custom_vjp instead of the opaque while_loop.

    ``cfg`` may be a ``GameConfig`` (floats — physics constants, no
    gradient) or a ``GamePhysics`` pytree of traced scalars (the
    mechanism layer differentiates through these).  Traceable: jit/vmap
    this freely — each (shape, statics) pair compiles once
    (``TRACE_COUNTS['equilibrium_implicit']``).

    Gradients flow into every θ leaf (physics scalars, channel gains,
    data sizes, v_max) and into ``epsilon`` through the follower finish;
    see the module docstring for the feasibility contract.
    """
    if isinstance(cfg, GameConfig):
        if inner is None:
            inner = cfg.dinkelbach_inner
        if sic_mode is None:
            sic_mode = cfg.sic_mode
        phys = cfg.physics(jnp.result_type(jnp.asarray(h2_sorted)))
    else:
        phys = cfg
        inner = inner or "projected"
        sic_mode = sic_mode or "sequential"
    statics = FixedPointStatics(max_iter=max_iter, tol=float(tol),
                                inner=inner, sic_mode=sic_mode,
                                adjoint_iters=adjoint_iters,
                                adjoint_tol=float(adjoint_tol),
                                masked=mask is not None)
    h2 = jnp.asarray(h2_sorted)
    n = h2.shape[0]
    dtype = jnp.result_type(h2)
    D = jnp.broadcast_to(jnp.asarray(D, dtype), (n,))
    v = leader_v(jnp.broadcast_to(jnp.asarray(v_max, dtype), (n,)))
    epsilon = jnp.asarray(epsilon, dtype)
    d_hat = v * D + epsilon
    if mask is not None:
        zero = jnp.zeros((), dtype)
        v = jnp.where(mask, v, zero)
        d_hat = jnp.where(mask, d_hat, zero)
        mask_f = mask.astype(dtype)
    else:
        mask_f = jnp.ones((n,), dtype)
    f, p, q, feas, iters = _fp_solve(statics, phys, h2, D, v, mask_f)
    return _finish(phys, h2, D, v, f, p, q, d_hat, iters, feas > 0.5, mask)
