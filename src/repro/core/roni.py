"""RONI (Reject On Negative Influence) poisoning detection — paper §III-3,
following Biscotti [31].

Each selected client's local update is validated before aggregation: the
server compares validation accuracy of the global aggregate WITH vs WITHOUT
that client's contribution; a drop beyond ``threshold`` marks the update as a
negative interaction (NI) and excludes it from aggregation.

``roni_filter`` is jit-cached on the (hashable) classifier function so the
per-round leave-one-out sweep never retraces (an eager closure here
recompiled the conv evaluation every FL round).  Everything else —
including ``threshold`` — is a traced operand, so the filter inlines into
the scan-compiled trajectory (``fl_round.run_training_scan``) and a
threshold sweep reuses one executable.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .aggregation import dt_aggregate


@partial(jax.jit, static_argnames=("logits_fn",))
def roni_filter(client_params, global_params, d_sizes, v, epsilon,
                logits_fn: Callable, x_val, y_val, threshold: float = 0.02):
    """Returns (positive_mask [N] bool, acc_base [N], acc_update [N]).

    Biscotti-style per-update RONI: client n's local model (= global model
    with its update applied) is evaluated on the held-out set against the
    pre-round global model; a drop beyond ``threshold`` marks the update as
    a negative interaction.  (A leave-one-out aggregate comparison carries
    ≈1/N of this signal and was empirically too weak to fire — see
    EXPERIMENTS.md §Paper-validation.)
    """
    n = d_sizes.shape[0]

    def acc(params):
        logits = logits_fn(params, x_val)
        return jnp.mean((jnp.argmax(logits, -1) == y_val).astype(jnp.float32))

    acc_base = acc(global_params)
    acc_update = jax.vmap(
        lambda i: acc(jax.tree_util.tree_map(lambda c: c[i], client_params))
    )(jnp.arange(n))
    positive = (acc_base - acc_update) <= threshold
    return positive, jnp.full((n,), acc_base), acc_update
