"""Algorithm 1 — transmit-power optimization via Dinkelbach fractional
programming (paper §V-B-3, Eqs. 35–45).

Per client the subproblem is

    min_p   p·d / (B·log2(1 + p·F))         (energy for the upload)
    s.t.    B·log2(1 + p·F) ≥ d / G         (rate floor ⇔ t_com ≤ G = Tmax − t_cmp;
                                             the paper's (35b) prints the flipped
                                             inequality but its Lagrangian (40)
                                             penalises R < d/G, i.e. a floor)
            p_min ≤ p ≤ p_max

Equivalently max R(p)/U(p); Dinkelbach iterates q ← R(p̂)/U(p̂) where
p̂ = argmax R(p) − q·U(p).  Two inner solvers:

  * ``_inner_projected`` — the concave stationary point  p0 = B/(ln2·q·d) − 1/F
    projected onto the feasible box (exactly the KKT solution with the
    multipliers absorbed by the active bounds);
  * ``_inner_kkt`` — the paper-faithful dual subgradient ascent on
    (λ1, λ2, λ3) with the primal update Eq. (43).

Both converge to the same point (asserted in tests); the projected solver is
the default fast path.

``successive_power`` applies the paper's successive-optimization order
(§V-B-3): clients are optimized N → 1 in SIC order, each seeing the already-
fixed interference of later-decoded clients — a reverse ``lax.scan``.
This chain is O(N) sequential; ``repro.core.sic`` solves the same fixed
point with client-parallel Jacobi sweeps for large N (the engines select
between them via the static ``sic_mode`` key on ``GameConfig``).

Everything except ``return_trace`` mode is trace-safe: ``dinkelbach_power``
and ``successive_power`` carry fixed-dtype arrays only, so the Stackelberg
engine can ``vmap`` them across K channel realizations (the batched
``lax.while_loop`` keeps converged lanes frozen while the rest iterate).
``bandwidth`` / ``sigma2`` / ``p_min`` / ``p_max`` / ``d`` are likewise
plain operands (the sweep engine passes traced ``GamePhysics`` scalars,
vmapped over a config axis) — only ``inner`` is a static compile key.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453


def _rate(p, f_eff, bandwidth):
    return bandwidth * jnp.log2(1.0 + p * f_eff)


def _p_floor(d, g, f_eff, bandwidth, p_min):
    """Smallest power meeting the rate floor R ≥ d/G.

    Grad-safe closed form: the naive ``(2**expo − 1) / f_eff`` is forward-
    correct (the caller clamps with ``min(·, p_max)``) but reverse-mode
    poison — ``2**expo`` overflows to inf for a starved deadline and
    ``1/f_eff`` is inf on a dead (masked) lane, and a ``where`` that merely
    *selects away* an inf branch still multiplies it by a zero cotangent
    (0·inf = NaN).  Both denominators are therefore replaced by safe values
    inside the untaken branch (double-``where``) and the exponent is
    saturated; every rewrite is value-identical after the caller's clamp."""
    expo = d / (jnp.maximum(g, 1e-9) * bandwidth)
    big = expo > 60.0            # 2**60 already exceeds any reachable p_max
    f_ok = f_eff > 1e-30
    f_safe = jnp.where(f_ok, f_eff, 1.0)
    need_raw = (2.0 ** jnp.where(big, 0.0, expo) - 1.0) / f_safe
    need = jnp.where(f_ok & ~big, need_raw, 1e30)
    return jnp.maximum(p_min, need)


def _inner_projected(q, d, f_eff, bandwidth, lo, hi):
    """Concave stationary point projected on [lo, hi], grad-safe.

    Double-``where`` on both divisions: the cold-start lane (q = 0) and the
    dead lane (f_eff = 0) must not evaluate 1/0 even in the branch the
    ``where`` discards, or reverse-mode emits NaN cotangents.  Forward
    values are unchanged — q→0 clipped to ``hi`` exactly as the old huge
    stationary point was, and a dead lane ends at p_max either way
    (its ``lo`` is already p_max via the rate-floor clamp)."""
    den = LN2 * q * d
    den_ok = den > 1e-20
    f_ok = f_eff > 1e-30
    den_safe = jnp.where(den_ok, den, 1.0)
    f_safe = jnp.where(f_ok, f_eff, 1.0)
    inv_f = jnp.where(f_ok, 1.0 / f_safe, 0.0)
    p0 = jnp.where(den_ok, bandwidth / den_safe - inv_f, hi)
    return jnp.clip(p0, lo, hi)


def _inner_kkt(q, d, g, f_eff, bandwidth, lo, hi, iters: int = 200,
               lr: float = 0.05):
    """Faithful Alg.1 inner solve: subgradient ascent on the dual (45a–c)."""
    rate_floor = d / jnp.maximum(g, 1e-9)

    def body(i, carry):
        lam, _p = carry
        l1, l2, l3 = lam
        denom = LN2 * (q * d + l2 - l3)
        p = bandwidth * (1.0 - l1) / jnp.maximum(denom, 1e-12) - 1.0 / f_eff
        p = jnp.clip(p, lo, hi)  # primal feasibility (Eq. 43 + box)
        r = _rate(p, f_eff, bandwidth)
        # paper Eqs. (45a)-(45c), with the rate term normalised for step-size
        l1 = jnp.maximum(l1 - lr * (rate_floor - r) / jnp.maximum(rate_floor, 1.0), 0.0)
        l2 = jnp.maximum(l2 - lr * (lo - p), 0.0)
        l3 = jnp.maximum(l3 - lr * (p - hi), 0.0)
        return (jnp.stack([l1, l2, l3]), p)

    lam0 = jnp.zeros(3)
    _, p = jax.lax.fori_loop(0, iters, body, (lam0, lo))
    return p


def dinkelbach_power(d, g, f_eff, bandwidth, p_min, p_max,
                     delta: float = 1e-6, max_iter: int = 50,
                     inner: str = "projected", return_trace: bool = False,
                     q_init=None):
    """Optimal transmit power for one client (scalar inputs).

    Returns (p*, q*, iterations) — q* is the optimal rate-per-energy
    R(p*)/U(p*), the quantity whose convergence Fig. 4 plots.

    ``q_init`` warm-starts the Dinkelbach ratio (default 0, the paper's
    cold start).  Dinkelbach's iteration converges to the unique q* from
    any q₀ ≥ 0, so a warm start changes the iteration count, never the
    fixed point — the blocked SIC engine passes the previous sweep's q to
    cut the per-sweep solve to ~1–2 iterations.
    """
    lo = jnp.minimum(_p_floor(d, g, f_eff, bandwidth, p_min), p_max)
    hi = p_max * jnp.ones_like(lo)

    def solve(q):
        if inner == "kkt":
            return _inner_kkt(q, d, g, f_eff, bandwidth, lo, hi)
        return _inner_projected(q, d, f_eff, bandwidth, lo, hi)

    def cond(carry):
        _p, _q, w, it = carry
        return (jnp.abs(w) > delta) & (it < max_iter)

    def body(carry):
        _p, q, _w, it = carry
        p = solve(q)
        r, u = _rate(p, f_eff, bandwidth), p * d
        w = (r - q * u) / jnp.maximum(r, 1.0)      # relative Dinkelbach gap
        return (p, r / jnp.maximum(u, 1e-30), w, it + 1)

    p0 = hi
    q0 = jnp.zeros_like(lo) if q_init is None else q_init * jnp.ones_like(lo)
    if return_trace:  # python loop, records q per iteration (Fig. 4)
        p, q, w, it, trace = p0, q0, jnp.inf, 0, [float(q0)]
        while it < max_iter and abs(float(w)) > delta:
            p = solve(q)
            r, u = _rate(p, f_eff, bandwidth), p * d
            w = (r - q * u) / jnp.maximum(r, 1.0)
            q = r / max(float(u), 1e-30)
            trace.append(float(q))
            it += 1
        return p, q, it, trace
    # fixed-dtype carry: weak-typed jnp.inf / python-int counters would
    # promote (and retrace) under x64 or when vmapped from the batched engine
    w0 = jnp.asarray(jnp.inf, p0.dtype)
    p, q, w, it = jax.lax.while_loop(cond, body,
                                     (p0, q0, w0, jnp.asarray(0, jnp.int32)))
    return p, q, it


@partial(jax.jit, static_argnames=("inner",))
def successive_power(h2_sorted, d, g, bandwidth, sigma2, p_min, p_max,
                     inner: str = "projected"):
    """Optimize all N clients' powers in the successive order N → 1.

    h2_sorted: [N] descending (SIC decode order).  Client n's effective gain
    F_n = |h_n|² / (Σ_{j>n} p_j |h_j|² + σ²) uses the already-optimized
    powers of later-decoded clients — a reverse scan carrying Σ p_j |h_j|².
    """
    def body(intf, xs):
        h2_n, d_n, g_n = xs
        f_eff = h2_n / (intf + sigma2)
        p_n, q_n, _ = dinkelbach_power(d_n, g_n, f_eff, bandwidth,
                                       p_min, p_max, inner=inner)
        return intf + p_n * h2_n, (p_n, q_n)

    d_v = jnp.broadcast_to(d, h2_sorted.shape)
    g_v = jnp.broadcast_to(g, h2_sorted.shape)
    _, (p, q) = jax.lax.scan(body, jnp.zeros(()), (h2_sorted, d_v, g_v),
                             reverse=True)
    return p, q
