"""Digital-twin network state (paper §II).

DT_n = {w_n, D̂_n}: the server-side twin of client n holds the client's model
parameters and an *estimate* of the client's insensitive data.  The estimated
size obeys D̂_n = v_n·D_n + ε; mapped feature values carry a deviation noise
ε·u, u ~ U(−1, 1) (Fig. 6 protocol), modelling imperfect real-time mapping.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DTConfig:
    epsilon: float = 0.0        # mapping deviation magnitude
    v_max_low: float = 0.3      # per-client max insensitive fraction range
    v_max_high: float = 0.8


def sample_v_max(key, m: int, cfg: DTConfig):
    return cfg.v_max_low + jax.random.uniform(key, (m,)) * (
        cfg.v_max_high - cfg.v_max_low)


def mapped_sizes(v, d_sizes, epsilon: float):
    """D̂_n = v_n·D_n + ε (sample-count estimate)."""
    return v * d_sizes + epsilon


def dt_feature_noise(key, x, epsilon):
    """Apply the Fig.-6 deviation: x̂ = x·(1 + ε·u), u ~ U(−1,1) per element.

    ``epsilon`` may be a traced scalar (the scanned FL trajectory passes it
    as an operand); the ε = 0 short-circuit only fires for concrete python
    zeros — the traced path computes x·(1 + 0·u) = x exactly, so both
    agree bit-for-bit."""
    if isinstance(epsilon, (int, float)) and epsilon <= 0.0:
        return x
    u = jax.random.uniform(key, x.shape, minval=-1.0, maxval=1.0)
    return x * (1.0 + epsilon * u)


def split_mapping_mask(key, counts_mask, v):
    """Per-sample Bernoulli(v_n) mask: True = sample mapped to the DT.

    counts_mask: [N, cap] validity mask of per-client sample slots.
    v:           [N] mapping ratios.
    """
    u = jax.random.uniform(key, counts_mask.shape)
    return (u < v[:, None]) & counts_mask
