"""Uplink NOMA transmission model (paper §II-C) + OMA baseline.

All rate functions take channel power gains ``h2`` sorted in DESCENDING
order — the paper's SIC decoding order (client 1 decoded first, suffering
interference from all later-decoded clients; client N decoded last,
interference-free; Eq. 9).

``bandwidth`` / ``sigma2`` accept plain floats OR traced JAX scalars: the
sweep engine feeds them as ``GamePhysics`` operands (possibly vmapped over
a config axis), so nothing here may branch on their values or treat them
as static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .channel import BANDWIDTH_HZ, noise_power


def sic_order(h2):
    """Indices sorting channel gains in descending order (decode order)."""
    return jnp.argsort(-h2)


def noma_rates(p, h2_sorted, bandwidth=BANDWIDTH_HZ, sigma2=None):
    """Achievable rates (bit/s) under SIC, Eq. (9).

    p, h2_sorted: [N] aligned with the descending-gain decode order.
    Interference on client n = sum_{j>n} p_j |h_j|².
    """
    if sigma2 is None:
        sigma2 = noise_power(bandwidth)
    rx = p * h2_sorted
    # reverse-exclusive cumulative sum: interference from later-decoded clients
    intf = jnp.flip(jnp.cumsum(jnp.flip(rx))) - rx
    sinr = rx / (intf + sigma2)
    return bandwidth * jnp.log2(1.0 + sinr)


def sum_capacity(p, h2, bandwidth=BANDWIDTH_HZ, sigma2=None):
    """MAC sum capacity B·log2(1 + Σ p|h|²/σ²) — SIC achieves it exactly."""
    if sigma2 is None:
        sigma2 = noise_power(bandwidth)
    return bandwidth * jnp.log2(1.0 + jnp.sum(p * h2) / sigma2)


def oma_rates(p, h2, bandwidth=BANDWIDTH_HZ, sigma2_full=None):
    """Orthogonal baseline: equal bandwidth split B/N, no interference."""
    n = h2.shape[0]
    bw = bandwidth / n
    if sigma2_full is None:
        sigma2_full = noise_power(bandwidth)
    sigma2 = sigma2_full / n           # noise scales with sub-band width
    return bw * jnp.log2(1.0 + p * h2 / sigma2)


def tx_latency(d_bits, rates):
    """Eq. (10)."""
    return d_bits / jnp.maximum(rates, 1e-9)


def tx_energy(p, t_com):
    """Eq. (11)."""
    return p * t_com
