"""Global model aggregation with DT assistance (paper Eq. 3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dt_aggregate(client_params, server_params, d_sizes, v, epsilon: float,
                 include_mask=None, server_include=None):
    """Eq. (3):

        w = (1/D) Σ_n [ (1−v_n)·D_n·w_n + (v_n·D_n + ε)·w_S ]

    client_params : pytree stacked over clients on axis 0 ([N, ...] leaves)
    server_params : pytree (the DT-side model w_S)
    d_sizes, v    : [N]
    include_mask  : optional [N] bool — RONI-excluded clients drop their
                    *local* term.
    server_include: optional scalar bool — RONI verdict on the DT-side
                    update itself (the twin mirrors poisoned data too).
    Excluded mass leaves the divisor — otherwise every exclusion uniformly
    shrinks the aggregate toward zero.

    All-excluded rounds stay finite (zero numerator over the clamped
    divisor → a zero tree, never NaN): the scanned trajectory
    (``fl_round.run_training_scan``) computes the aggregate
    unconditionally and keeps the previous global model via ``jnp.where``,
    so this function must be safe to evaluate on empty include masks.
    """
    d_total = jnp.sum(d_sizes)
    w_local = (1.0 - v) * d_sizes
    if include_mask is not None:
        inc = include_mask.astype(w_local.dtype)
        d_total = d_total - jnp.sum(w_local * (1.0 - inc))
        w_local = w_local * inc
    w_server = jnp.sum(v * d_sizes + epsilon)
    if server_include is not None:
        s_inc = jnp.asarray(server_include, w_local.dtype)
        d_total = d_total - w_server * (1.0 - s_inc)
        w_server = w_server * s_inc

    def agg(cl, sv):
        shape = (-1,) + (1,) * (cl.ndim - 1)
        return (jnp.sum(cl * w_local.reshape(shape), axis=0)
                + w_server * sv) / jnp.maximum(d_total, 1e-9)

    return jax.tree_util.tree_map(agg, client_params, server_params)


def fedavg(client_params, d_sizes, include_mask=None):
    """Plain FedAvg (the W/O-DT baseline's aggregation)."""
    w = d_sizes
    if include_mask is not None:
        w = w * include_mask.astype(w.dtype)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def agg(cl):
        shape = (-1,) + (1,) * (cl.ndim - 1)
        return jnp.sum(cl * w.reshape(shape), axis=0)

    return jax.tree_util.tree_map(agg, client_params)
