"""Learned mechanism design over the differentiable Stackelberg equilibrium.

The paper hand-picks every mechanism knob: the Eq.-16 selection weights
(ξ1, ξ2, ξ3), the DT mapping deviation ε, and the RONI drop threshold.
This layer tunes them — plus a per-client reward/pricing vector the paper
does not have (in the direction of incentive-compatible Stackelberg FL,
arXiv:2501.02662 / 1911.05642) — by gradient descent END-TO-END through
the game: the equilibrium solve inside the objective is
``core.implicit.equilibrium_implicit``, so ∂(lane energies, round
latency)/∂(knobs) flows through the solved Stackelberg fixed point via
the IFT custom_vjp, never through an unrolled solver loop.

Differentiability contract (inherited from ``core.implicit``): gradients
are meaningful only at converged, feasible equilibria; ``feasible=False``
draws contribute zero cotangents through the fixed point.  Two places the
REAL pipeline is non-differentiable get standard smooth relaxations here:

  * hard top-N selection (``argsort``) has mathematically zero gradient
    w.r.t. the weights — the objective therefore scores lanes with a
    soft inclusion probability ``s_m = σ((Z_m − Z_(N))/τ)`` around the
    stop-gradiented N-th score while the equilibrium itself is solved on
    the HARD top-N set (exactly the clients the real engine would pick,
    deterministic after the stable tie-break fix in
    ``reputation.select_clients``);
  * RONI accept/reject becomes leak / false-positive sigmoids around the
    threshold.

The tuned knobs map 1:1 onto the traced ``_fl_ops`` operand dict of
``core.fl_round`` (weights / epsilon / roni_threshold), so learned values
are evaluated through the REAL ``run_training_scan`` / ``sweep_training``
engines via ``ops_override`` — same executable, no new compile keys
(``to_fl_ops`` / ``to_fl_config``).  ``benchmarks/mechanism_design.py``
gates that loop: learned weights must beat the paper's hand-picked ξ on
the tuned objective, with the defended-accuracy/energy evaluation
recorded from ``sweep_training``.

One jitted outer step (``mechanism_step``): value_and_grad of the
objective + ``optim.adamw`` update, compile-keyed only on shapes and the
static ``MechanismStatics`` — every knob is a traced operand, so a whole
tuning run is one executable (``TRACE_COUNTS['mechanism_step']``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state
from . import reputation as rep
from .channel import sample_channel_gains, sample_positions
from .digital_twin import DTConfig, sample_v_max
from .fl_round import FLConfig
from .implicit import equilibrium_implicit
from .stackelberg import GameConfig, GamePhysics
from .tracking import TRACE_COUNTS

__all__ = ["MechanismParams", "MechanismStatics", "MechanismContext",
           "init_params", "params_to_knobs", "synthetic_context",
           "mechanism_objective", "mechanism_step", "tune_mechanism",
           "to_fl_config", "to_fl_ops"]

# knob ranges / transform scales (module constants, documented knobs)
EPS_SCALE = 50.0          # softplus(eps_raw)·scale ∈ [0, ~scale] samples
RONI_LO, RONI_HI = 1e-3, 0.2
# objective term weights: quality, energy, latency, RONI leak/false-pos,
# reward budget, ε-deviation penalty (DT mapping degradation proxy)
W_QUALITY = 4.0
W_ENERGY = 0.5
W_LATENCY = 0.2
W_LEAK = 2.0
W_FP = 1.0
W_BUDGET = 0.05
W_EPS = 1.0


@dataclass
class MechanismParams:
    """Unconstrained pytree the optimizer walks; ``params_to_knobs`` maps
    it to the constrained knob space (softmax / softplus / sigmoid)."""
    xi_logits: jax.Array   # [3] → softmax → (ξ1, ξ2, ξ3), simplex
    eps_raw: jax.Array     # () → softplus·EPS_SCALE → ε ≥ 0
    roni_raw: jax.Array    # () → RONI_LO + σ·(RONI_HI−RONI_LO)
    reward: jax.Array      # [M] → softplus → per-client reward ≥ 0


jax.tree_util.register_dataclass(
    MechanismParams,
    data_fields=tuple(f.name for f in dataclasses.fields(MechanismParams)),
    meta_fields=())


@dataclass
class MechanismContext:
    """Traced operands the objective is evaluated against — a frozen
    snapshot of the federation (reputation features, channel draws,
    physics).  All leaves are arrays; swapping values reuses the jitted
    step."""
    d_sizes: jax.Array     # [M] client data sizes (samples)
    ms: jax.Array          # [M] staleness counters
    pi_count: jax.Array    # [M]
    ni_count: jax.Array    # [M]
    v_max: jax.Array       # [M] max insensitive fractions
    h2_draws: jax.Array    # [K, M] channel power gains (unsorted)
    roni_gap: jax.Array    # [M] expected RONI validation-loss gap
    base_cost: jax.Array   # [M] per-round participation cost (J)
    phys: GamePhysics      # traced physics scalars


jax.tree_util.register_dataclass(
    MechanismContext,
    data_fields=tuple(f.name for f in dataclasses.fields(MechanismContext)),
    meta_fields=())


@dataclass(frozen=True)
class MechanismStatics:
    """Hashable compile keys of the tuning step."""
    n_selected: int = 5
    max_iter: int = 20
    tol: float = 1e-6
    inner: str = "projected"
    sic_mode: str = "sequential"
    tau_select: float = 0.05   # soft-inclusion temperature (Z units)
    tau_roni: float = 0.02     # RONI sigmoid temperature (gap units)
    budget: float = 5.0        # reward budget before the penalty bites
    adamw: AdamWConfig = AdamWConfig(lr=0.05, weight_decay=0.0,
                                     grad_clip=1.0)


def init_params(m: int,
                weights: Tuple[float, float, float] = rep.PROPOSED_WEIGHTS,
                epsilon: float = 10.0, roni_threshold: float = 0.02,
                reward: float = 0.1, dtype=jnp.float32) -> MechanismParams:
    """Start AT the paper's hand-picked operating point: the inverse knob
    transforms of (ξ, ε, threshold) — so step 0's objective IS the
    hand-picked mechanism's score and any improvement is attributable to
    learning."""
    w = jnp.asarray(weights, dtype)
    eps_frac = max(epsilon / EPS_SCALE, 1e-6)
    thr = min(max((roni_threshold - RONI_LO) / (RONI_HI - RONI_LO), 1e-6),
              1.0 - 1e-6)
    inv_softplus = lambda y: float(jnp.log(jnp.expm1(jnp.asarray(y))))
    return MechanismParams(
        xi_logits=jnp.log(jnp.maximum(w, 1e-6)),
        eps_raw=jnp.asarray(inv_softplus(eps_frac), dtype),
        roni_raw=jnp.asarray(float(jnp.log(thr / (1.0 - thr))), dtype),
        reward=jnp.full((m,), inv_softplus(reward), dtype))


def params_to_knobs(params: MechanismParams) -> Dict[str, jax.Array]:
    """Constrained knob space: ξ on the simplex, ε ≥ 0, threshold in
    [RONI_LO, RONI_HI], rewards ≥ 0."""
    return {
        "xi": jax.nn.softmax(params.xi_logits),
        "epsilon": jax.nn.softplus(params.eps_raw) * EPS_SCALE,
        "roni_threshold": RONI_LO + jax.nn.sigmoid(params.roni_raw)
        * (RONI_HI - RONI_LO),
        "rewards": jax.nn.softplus(params.reward),
    }


def synthetic_context(key, m: int = 20, k_draws: int = 8,
                      game: GameConfig | None = None,
                      attack_fraction: float = 0.25,
                      gain_scale: float = 100.0,
                      dtype=jnp.float32) -> MechanismContext:
    """A reproducible federation snapshot for tuning/tests/benchmarks:
    heterogeneous data sizes, a poisoned-client tail with degraded PI
    counters and elevated RONI gaps, K channel draws (scaled into the
    deadline-feasible regime so the equilibria carry gradients)."""
    game = game or GameConfig()
    ks = jax.random.split(key, 6)
    d_sizes = jnp.round(200.0 + 800.0 * jax.random.uniform(ks[0], (m,)))
    ms = jnp.round(1.0 + 4.0 * jax.random.uniform(ks[1], (m,)))
    n_bad = int(round(attack_fraction * m))
    honest = jnp.arange(m) < (m - n_bad)
    pi = jnp.where(honest, 8.0, 2.0)
    ni = jnp.where(honest, 1.0, 7.0)
    roni_gap = jnp.where(honest,
                         0.01 + 0.01 * jax.random.uniform(ks[2], (m,)),
                         0.06 + 0.04 * jax.random.uniform(ks[3], (m,)))
    v_max = sample_v_max(ks[4], m, DTConfig())

    def draw(kk):
        k1, k2 = jax.random.split(kk)
        return sample_channel_gains(k2, sample_positions(k1, m)) * gain_scale

    h2 = jax.vmap(draw)(jax.random.split(ks[5], k_draws))
    base_cost = jnp.full((m,), 0.3)
    return MechanismContext(
        d_sizes=d_sizes.astype(dtype), ms=ms.astype(dtype),
        pi_count=pi.astype(dtype), ni_count=ni.astype(dtype),
        v_max=v_max.astype(dtype), h2_draws=h2.astype(dtype),
        roni_gap=roni_gap.astype(dtype), base_cost=base_cost.astype(dtype),
        phys=game.physics(dtype))


def mechanism_objective(params: MechanismParams, ctx: MechanismContext,
                        statics: MechanismStatics) -> jax.Array:
    """Scalar mechanism utility J (maximize).  Every term is differentiable
    in the knobs; the equilibrium terms differentiate THROUGH the solved
    Stackelberg game via the IFT custom_vjp."""
    knobs = params_to_knobs(params)
    xi, eps = knobs["xi"], knobs["epsilon"]
    thr, rewards = knobs["roni_threshold"], knobs["rewards"]
    n = statics.n_selected
    dtype = ctx.d_sizes.dtype

    # Eq.-16 reputation with TRACED weights (reputation() is linear in ξ)
    state = rep.ReputationState(ms=ctx.ms, pi_count=ctx.pi_count,
                                ni_count=ctx.ni_count)
    z = rep.reputation(state, ctx.d_sizes, 0.0, (xi[0], xi[1], xi[2]))

    # hard top-N (what the real engine selects; stable tie-break) ...
    idx = jax.lax.stop_gradient(jnp.argsort(-z, stable=True)[:n])
    # ... and soft inclusion around the stop-gradiented N-th score, the
    # selection-gradient relaxation (argsort itself has zero gradient)
    z_nth = jax.lax.stop_gradient(jnp.sort(z)[::-1][n - 1])
    s = jax.nn.sigmoid((z - z_nth) / statics.tau_select)        # [M]

    # equilibria on the hard-selected cohort, K channel draws
    d_sel = ctx.d_sizes[idx]
    v_sel = ctx.v_max[idx]
    h2_sel = ctx.h2_draws[:, idx]                               # [K, n]
    order = jax.lax.stop_gradient(jnp.argsort(-h2_sel, axis=1))
    h2_sorted = jnp.take_along_axis(h2_sel, order, axis=1)

    def solve_one(h2_row, ord_row):
        al = equilibrium_implicit(
            ctx.phys, h2_row, d_sel[ord_row], v_sel[ord_row], eps,
            max_iter=statics.max_iter, tol=statics.tol,
            inner=statics.inner, sic_mode=statics.sic_mode)
        lane_e = al.e_cmp + al.e_com                            # [n]
        # back to client order so lane terms align with idx
        inv = jnp.zeros_like(ord_row).at[ord_row].set(jnp.arange(n))
        return lane_e[inv], al.t_total, al.feasible

    lane_e, t_total, feas = jax.vmap(solve_one)(h2_sorted, order)
    s_sel = s[idx]                                              # [n]
    energy = jnp.mean(jnp.sum(lane_e * s_sel, axis=1))
    latency = jnp.mean(t_total)

    # participation: π_m = σ(reward − cost); selected lanes use their
    # solved per-round energy as the cost (end-to-end pricing), the rest
    # the context's base cost
    cost = ctx.base_cost.at[idx].set(jnp.mean(lane_e, axis=0))
    pi_part = jax.nn.sigmoid((rewards - cost) / 0.1)
    quality = (rep.accuracy_contribution(ctx.d_sizes)
               * rep.positive_interaction(state))
    acc_proxy = jnp.sum(s * pi_part * quality) / n

    # RONI: drop prob σ((gap − thr)/τ); attackers (low PI ratio) leaking
    # past the threshold vs honest clients falsely dropped
    harm = 1.0 - rep.positive_interaction(state)
    p_drop = jax.nn.sigmoid((ctx.roni_gap - thr) / statics.tau_roni)
    leak = jnp.sum(s * harm * (1.0 - p_drop))
    false_pos = jnp.sum(s * (1.0 - harm) * p_drop)

    budget_spend = jnp.sum(pi_part * rewards)
    over = jax.nn.relu(budget_spend - statics.budget)

    return (W_QUALITY * acc_proxy
            - W_ENERGY * energy
            - W_LATENCY * latency
            - W_LEAK * leak
            - W_FP * false_pos
            - W_BUDGET * budget_spend - over * over
            - W_EPS * (eps / EPS_SCALE) ** 2).astype(dtype)


@partial(jax.jit, static_argnames=("statics",))
def _mechanism_step_jit(params, opt_state, ctx, statics):
    TRACE_COUNTS["mechanism_step"] += 1
    neg_j, grads = jax.value_and_grad(
        lambda p: -mechanism_objective(p, ctx, statics))(params)
    new_params, new_opt = adamw_update(grads, opt_state, params,
                                       statics.adamw)
    return new_params, new_opt, -neg_j, grads


def mechanism_step(params, opt_state, ctx, statics: MechanismStatics):
    """ONE jitted outer step: value_and_grad through the equilibria +
    AdamW.  Returns (params, opt_state, objective, grads); repeated calls
    with new values reuse the executable
    (``TRACE_COUNTS['mechanism_step']``)."""
    return _mechanism_step_jit(params, opt_state, ctx, statics)


def tune_mechanism(params: MechanismParams, ctx: MechanismContext,
                   statics: MechanismStatics, steps: int):
    """Host tuning loop; returns (params, history) with the per-step
    objective trace (floats) and the final knobs."""
    opt_state = init_opt_state(params, statics.adamw)
    trace = []
    for _ in range(steps):
        params, opt_state, j, _g = mechanism_step(params, opt_state, ctx,
                                                  statics)
        trace.append(float(j))
    return params, {"objective": trace, "knobs": jax.device_get(
        params_to_knobs(params))}


def to_fl_config(params: MechanismParams, base: FLConfig) -> FLConfig:
    """Learned knobs as a concrete ``FLConfig`` (host floats) — the
    evaluate-through-the-real-engine path."""
    k = jax.device_get(params_to_knobs(params))
    return dataclasses.replace(
        base, weights=tuple(float(x) for x in k["xi"]),
        epsilon=float(k["epsilon"]),
        roni_threshold=float(k["roni_threshold"]))


def to_fl_ops(params: MechanismParams, dtype=jnp.float32) -> Dict:
    """Learned knobs as a traced ``_fl_ops`` override (weights / epsilon /
    roni_threshold) for ``run_training_scan(..., ops_override=...)`` —
    evaluates the learned mechanism through the real engine with NO new
    compile keys, and keeps the knobs traced (so this composes with
    ``jax.grad`` wherever the round body is differentiable)."""
    k = params_to_knobs(params)
    return {"weights": k["xi"].astype(dtype),
            "epsilon": k["epsilon"].astype(dtype),
            "roni_threshold": k["roni_threshold"].astype(dtype)}
