"""The paper's primary contribution: DT-assisted FL over NOMA with
Stackelberg-game resource allocation and reputation-based client selection."""
from .channel import (BANDWIDTH_HZ, noise_power, sample_channel_gains,
                      sample_positions, sample_round_channels)
from .dinkelbach import dinkelbach_power, successive_power
from .sic import (SIC_MODES, successive_power_any, successive_power_blocked,
                  successive_power_eager, suffix_interference)
from .faults import (ATTACK_PROFILES, FaultConfig, FaultOps,
                     adaptive_attacker, duty_cycle_attacker, fault_ops,
                     stack_fault_ops, static_attacker, straggler_storm)
from .fl_round import (FLConfig, FLState, batched_training, run_round,
                       run_training, run_training_eager, run_training_scan,
                       stack_fl_ops, stack_states, sweep_training)
from .reputation import (BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS, ReputationState,
                         init_reputation, select_clients)
from .reputation import reputation as reputation_score
from . import reputation  # keep the submodule accessible (not the function)
from .fl_round import allocate, allocate_batched, fl_ops, sweep_allocation
from .implicit import (FixedPointStatics, equilibrium_implicit,
                       fixed_point_step)
from .mechanism import (MechanismContext, MechanismParams, MechanismStatics,
                        init_params, mechanism_objective, mechanism_step,
                        params_to_knobs, synthetic_context, to_fl_config,
                        to_fl_ops, tune_mechanism)
from .stackelberg import (TRACE_COUNTS, Allocation, GameConfig, GamePhysics,
                          reset_trace_counts)
from .stackelberg import (batched_equilibrium, batched_oma_allocation,
                          batched_oma_tdma_allocation,
                          batched_random_allocation, batched_wo_dt_allocation,
                          equilibrium, equilibrium_eager, follower_alpha,
                          leader_f, leader_v, oma_allocation,
                          oma_tdma_allocation, random_allocation,
                          stack_physics, sweep_equilibrium,
                          sweep_oma_allocation, sweep_oma_tdma_allocation,
                          sweep_random_allocation, sweep_wo_dt_allocation,
                          wo_dt_allocation)

__all__ = [
    "BANDWIDTH_HZ", "noise_power", "sample_channel_gains", "sample_positions",
    "sample_round_channels", "dinkelbach_power", "successive_power",
    "SIC_MODES", "successive_power_any", "successive_power_blocked",
    "successive_power_eager", "suffix_interference",
    "FLConfig", "FLState", "run_round", "run_training", "run_training_eager",
    "run_training_scan", "batched_training", "sweep_training", "stack_states",
    "stack_fl_ops", "TRACE_COUNTS", "reset_trace_counts",
    "ATTACK_PROFILES", "FaultConfig", "FaultOps", "adaptive_attacker",
    "duty_cycle_attacker", "fault_ops", "stack_fault_ops", "static_attacker",
    "straggler_storm",
    "BENCHMARK_WEIGHTS",
    "PROPOSED_WEIGHTS", "ReputationState", "init_reputation",
    "reputation_score", "select_clients", "Allocation", "GameConfig",
    "GamePhysics", "stack_physics", "equilibrium", "batched_equilibrium",
    "sweep_equilibrium", "batched_wo_dt_allocation", "sweep_wo_dt_allocation",
    "equilibrium_eager", "follower_alpha", "leader_f", "leader_v",
    "oma_allocation", "batched_oma_allocation", "oma_tdma_allocation",
    "batched_oma_tdma_allocation", "sweep_oma_allocation",
    "sweep_oma_tdma_allocation", "random_allocation",
    "batched_random_allocation", "sweep_random_allocation",
    "wo_dt_allocation", "allocate", "allocate_batched", "sweep_allocation",
    "fl_ops", "FixedPointStatics", "equilibrium_implicit",
    "fixed_point_step", "MechanismContext", "MechanismParams",
    "MechanismStatics", "init_params", "mechanism_objective",
    "mechanism_step", "params_to_knobs", "synthetic_context", "to_fl_config",
    "to_fl_ops", "tune_mechanism",
]
