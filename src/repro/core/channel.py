"""Wireless channel simulation (paper §VI setup).

Clients are dropped uniformly in a disc of radius 500 m around the server;
channel gain = G0 · d^(−3.76) · |g|² with Rayleigh fading (|g|² ~ Exp(1)),
carrier 1 GHz, AWGN density −174 dBm/Hz over B = 1 MHz.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# Table I constants
BANDWIDTH_HZ = 1.0e6
NOISE_DBM_PER_HZ = -174.0
PATHLOSS_EXP = 3.76
REF_GAIN = 1e-3          # −30 dB at 1 m (standard reference-distance gain)
CELL_RADIUS_M = 500.0


def noise_power(bandwidth_hz: float = BANDWIDTH_HZ) -> float:
    """AWGN power in watts over the given bandwidth."""
    return 10.0 ** ((NOISE_DBM_PER_HZ - 30.0) / 10.0) * bandwidth_hz


def sample_positions(key, m: int, radius: float = CELL_RADIUS_M):
    """Uniform in the disc; returns distances [m] to the server at the centre."""
    k1, k2 = jax.random.split(key)
    r = radius * jnp.sqrt(jax.random.uniform(k1, (m,)))
    return jnp.maximum(r, 1.0)


def sample_channel_gains(key, distances, pathloss_exp: float = PATHLOSS_EXP,
                         ref_gain: float = REF_GAIN):
    """|h|² per client: pathloss × Rayleigh power fading."""
    fading = jax.random.exponential(key, distances.shape)
    return ref_gain * distances ** (-pathloss_exp) * fading


def sample_round_channels(key, distances):
    """Fresh fading realization each FL round (block-fading model)."""
    return sample_channel_gains(key, distances)


def sample_sic_channel_batch(key, k: int, n: int,
                             radius: float = CELL_RADIUS_M):
    """[K, N] independent channel realizations, each row sorted descending
    — the SIC decode order the Stackelberg engine expects.  Shared by the
    Monte-Carlo benchmarks and smoke runs (tests build their own draws on
    purpose, to feed the engine independently-constructed inputs)."""
    def one(kk):
        k1, k2 = jax.random.split(kk)
        h2 = sample_channel_gains(k2, sample_positions(k1, n, radius))
        return jnp.sort(h2)[::-1]
    return jax.vmap(one)(jax.random.split(key, k))
