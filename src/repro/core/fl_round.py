"""One round of DT-assisted federated learning over NOMA (paper Fig. 1).

Round pipeline (§II–§V):
  1. reputation-based selection of N of M clients            (§III)
  2. fresh block-fading channel realization, SIC ordering    (§II-C)
  3. Stackelberg allocation (v*, f*, p*, α*) or baseline     (§IV–V)
  4. DT data split: Bernoulli(v_n) per sample → server-mapped (with ε
     feature deviation) vs local                             (§II)
  5. local SGD on clients (poisoners train on flipped labels) (Eq. 2)
     + server/DT SGD on the union of mapped data
  6. deadline check: clients with t_cmp + t_com > T_max straggle and
     drop out (the mechanism DT/NOMA alleviate)
  7. RONI validation → PI/NI bookkeeping, exclusion          (§III-3)
  8. DT-aware aggregation, Eq. (3)
  9. staleness update, Eq. (13)

Schemes: "proposed" (DT+NOMA), "wo_dt" (v≡0), "oma", "ideal" (no resource
constraints), matching §VI-C benchmarks.

Execution tiers — the whole R-round trajectory is ONE compiled program:

  * ``_round_body``        — the trace-safe round: static arguments are the
    discrete algorithm choices (scheme, use_roni, shapes/steps, logits_fn,
    dinkelbach inner); every numeric knob (lr, ε, RONI threshold, selection
    weights, the ``GamePhysics`` floats) is a traced operand, so distinct
    ``FLConfig``/``GameConfig`` values reuse one executable.  The
    "RONI rejected everything → keep the previous global model" decision is
    a ``jnp.where`` over the parameter pytree, not a host branch.
  * ``run_training_scan``  — R rounds as a single jitted ``lax.scan``
    dispatch.  Metrics come back as a dict of stacked arrays with a leading
    ``(R,)`` axis (``(R, N)`` for ``selected``) — the stacked-metrics
    history format; ``stackelberg.TRACE_COUNTS['run_round']`` proves the
    round body traces exactly once per (scheme, use_roni, shape).
  * ``batched_training``   — ``vmap`` of the scan over a leading seed axis
    (optionally with per-seed data, e.g. a poisoned-fraction axis): an
    S-seed × R-round sweep is one dispatch, seed axis device-sharded.
  * ``sweep_training``     — a leading CONFIG axis on top of the seed axis:
    C (``FLConfig``, ``GameConfig``) points × S seeds × R rounds as ONE
    dispatch of one executable.  The C points' numeric knobs are stacked
    into ``[C]``-leaved pytrees (``stack_physics`` / ``stack_fl_ops``), the
    C×S grid is flattened and device-sharded, and a whole Fig. 5/6/7/8-style
    figure grid traces the round body exactly once per (scheme, use_roni,
    shape) — scheme/use_roni/shapes are the only compile keys.
  * ``run_training``       — compat shim over ``run_training_scan``: same
    list-of-dicts history (python scalars) as the legacy host loop.
  * ``run_round`` / ``run_training_eager`` — the legacy host-side path
    (one dispatch per stage, per-round host syncs), kept as the numerical
    reference and the benchmark baseline of
    ``benchmarks/training_throughput.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..data.federated import FedData
from ..sharding import game_mesh
from . import reputation as rep
from .aggregation import dt_aggregate, fedavg
from .digital_twin import dt_feature_noise, split_mapping_mask
from .faults import (FaultConfig, FaultOps, attack_active, faded_channel,
                     fault_ops, sample_round_faults, slowdown_multiplier,
                     stack_fault_ops)
from .roni import roni_filter
from .stackelberg import (TRACE_COUNTS, Allocation, GameConfig,
                          _oma_body, _physics_cached, _random_body,
                          _shard_axis, _solve, batched_equilibrium,
                          batched_oma_allocation, batched_oma_tdma_allocation,
                          batched_random_allocation, batched_wo_dt_allocation,
                          equilibrium, oma_allocation, oma_tdma_allocation,
                          random_allocation, stack_physics, sweep_equilibrium,
                          sweep_oma_allocation, sweep_oma_tdma_allocation,
                          sweep_random_allocation, sweep_wo_dt_allocation,
                          wo_dt_allocation)
from .channel import sample_round_channels


@dataclass(frozen=True)
class FLConfig:
    n_selected: int = 5
    local_steps: int = 20
    server_steps: int = 20
    lr: float = 0.05
    epsilon: float = 0.0            # DT mapping deviation
    roni_threshold: float = 0.02
    weights: Tuple[float, float, float] = rep.PROPOSED_WEIGHTS
    scheme: str = "proposed"   # proposed | wo_dt | oma | oma_tdma | ideal | random
    use_roni: bool = True
    samples_per_unit: float = 1.0   # D_n (samples) → data units for latency


@dataclass
class FLState:
    params: dict
    rep: rep.ReputationState
    v_max: jax.Array        # [M]
    distances: jax.Array    # [M]
    key: jax.Array
    round: jax.Array | int = 0


# pytree registration: FLState is the lax.scan carry of the compiled
# trajectory (every field is a data leaf; ``round`` rides as an int32 array).
_FLSTATE_FIELDS = tuple(f.name for f in dataclasses.fields(FLState))
jax.tree_util.register_dataclass(FLState, data_fields=_FLSTATE_FIELDS,
                                 meta_fields=())


# ---------------------------------------------------------------------------
# local / server SGD
# ---------------------------------------------------------------------------
def masked_loss(logits_fn, p, x, y, w):
    logits = logits_fn(p, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


@partial(jax.jit, static_argnames=("logits_fn", "steps"))
def sgd_train(logits_fn, params, x, y, w, steps: int, lr: float):
    """Full-batch SGD (Eq. 2) for ``steps`` steps with per-sample weights.

    jit-cached on (logits_fn, steps) — an eager ``lax.scan`` here would
    retrace (and recompile the conv backward) every FL round."""
    def step(p, _):
        g = jax.grad(partial(masked_loss, logits_fn))(p, x, y, w)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(step, params, None, length=steps)
    return params


@partial(jax.jit, static_argnames=("logits_fn", "steps"))
def local_train_all(logits_fn, params, x, y, w, steps, lr):
    """vmap local SGD over the selected clients. x: [N, cap, dim]."""
    return jax.vmap(lambda xi, yi, wi: sgd_train(logits_fn, params, xi, yi,
                                                 wi, steps, lr))(x, y, w)


@partial(jax.jit, static_argnames=("logits_fn",))
def _val_acc(logits_fn, x_val, y_val, params):
    logits = logits_fn(params, x_val)
    return jnp.mean((jnp.argmax(logits, -1) == y_val).astype(jnp.float32))


# ---------------------------------------------------------------------------
# allocation dispatch (host-side tiers)
# ---------------------------------------------------------------------------
def allocate(scheme: str, game_cfg: GameConfig, key, h2_sorted, d_units,
             v_max_sel) -> Allocation:
    """Per-round resource allocation.  Every scheme routes through a fully
    jitted body whose physics floats are traced operands — one compile per
    (scheme, shape), shared across GameConfig parameterizations, no host
    syncs inside the solve."""
    if scheme in ("proposed", "ideal"):
        return equilibrium(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "wo_dt":
        return wo_dt_allocation(game_cfg, h2_sorted, d_units)
    if scheme == "oma":
        return oma_allocation(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "oma_tdma":
        return oma_tdma_allocation(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "random":
        return random_allocation(game_cfg, key, h2_sorted, d_units, v_max_sel)
    raise ValueError(scheme)


def allocate_batched(scheme: str, game_cfg: GameConfig, h2_batch, d_batch,
                     v_max_batch, epsilon: float = 0.0,
                     key=None) -> Allocation:
    """Monte-Carlo allocation: solve K network realizations in one XLA
    call (used by the Fig. 6–9 benchmark sweeps and throughput bench).
    EVERY scheme batches — proposed/ideal/wo_dt through the Stackelberg
    engine, OMA-FDMA/OMA-TDMA/random through their vmapped baseline
    bodies — and the K axis is device-sharded (single-device no-op).
    Large-N cells opt into the blocked SIC power engine through
    ``game_cfg.sic_mode`` (a static key — see ``repro.core.sic``), which
    reaches every Stackelberg-backed scheme here.
    ``epsilon`` (DT mapping deviation) reaches the engine for the DT
    schemes; "wo_dt" has no twin and ignores it (matching
    ``wo_dt_allocation``).  ``key`` seeds the "random" scheme's per-draw
    randomness (defaults to PRNGKey(0))."""
    if scheme in ("proposed", "ideal"):
        return batched_equilibrium(game_cfg, h2_batch, d_batch, v_max_batch,
                                   epsilon=epsilon)
    if scheme == "wo_dt":
        return batched_wo_dt_allocation(game_cfg, h2_batch, d_batch)
    if scheme == "oma":
        return batched_oma_allocation(game_cfg, h2_batch, d_batch,
                                      v_max_batch, epsilon=epsilon)
    if scheme == "oma_tdma":
        return batched_oma_tdma_allocation(game_cfg, h2_batch, d_batch,
                                           v_max_batch, epsilon=epsilon)
    if scheme == "random":
        key = jax.random.PRNGKey(0) if key is None else key
        return batched_random_allocation(game_cfg, key, h2_batch, d_batch,
                                         v_max_batch, epsilon=epsilon)
    raise ValueError(f"no batched path for scheme {scheme!r}")


def sweep_allocation(scheme: str, configs, h2_batch, d_batch, v_max_batch,
                     epsilon=0.0, key=None) -> Allocation:
    """Benchmark-grid allocation: C config points × K realizations of one
    scheme in ONE XLA dispatch of one compiled executable (the fig9 sweep
    workload).  ``configs`` is a sequence of GameConfig whose physics are
    stacked into a traced [C] axis; ``epsilon`` may be scalar or [C].
    Returns an ``Allocation`` with a [C, K] prefix on every field."""
    if scheme in ("proposed", "ideal"):
        return sweep_equilibrium(configs, h2_batch, d_batch, v_max_batch,
                                 epsilon=epsilon)
    if scheme == "wo_dt":
        return sweep_wo_dt_allocation(configs, h2_batch, d_batch)
    if scheme == "oma":
        return sweep_oma_allocation(configs, h2_batch, d_batch, v_max_batch,
                                    epsilon=epsilon)
    if scheme == "oma_tdma":
        return sweep_oma_tdma_allocation(configs, h2_batch, d_batch,
                                         v_max_batch, epsilon=epsilon)
    if scheme == "random":
        key = jax.random.PRNGKey(0) if key is None else key
        return sweep_random_allocation(configs, key, h2_batch, d_batch,
                                       v_max_batch, epsilon=epsilon)
    raise ValueError(f"no sweep path for scheme {scheme!r}")


def _allocate_traced(scheme: str, phys, inner: str, key, h2_sorted, d_units,
                     v_max_sel, sic_mode: str = "sequential",
                     mask=None) -> Allocation:
    """Scheme dispatch inside the traced round body: direct calls into the
    shared solver bodies with the traced ``GamePhysics`` — no nested jit
    wrappers, no host syncs, one executable across GameConfig values.
    ``scheme``/``inner``/``sic_mode`` are static (compile keys); everything
    else is an operand.

    ``mask`` ([N] bool operand, default None) is the graceful-degradation
    path of the fault engine: lanes of mid-round dropouts carry h2 = 0 (the
    SIC tail) and are masked through the same traced ``mask`` plumbing the
    padded serving buckets use (``stackelberg._solve``/``_oma_body``/
    ``_random_body``), so the equilibrium re-solves over the n_eff
    survivors instead of allocating power to a dead client."""
    dtype = jnp.result_type(h2_sorted)
    tol = jnp.asarray(1e-6, dtype)
    eps0 = jnp.asarray(0.0, dtype)
    if scheme in ("proposed", "ideal"):
        return _solve(phys, h2_sorted, d_units, v_max_sel, eps0, 20, tol,
                      inner, sic_mode, mask=mask)
    if scheme == "wo_dt":
        return _solve(phys, h2_sorted, d_units, jnp.zeros_like(h2_sorted),
                      eps0, 20, tol, inner, sic_mode, mask=mask)
    if scheme == "oma":
        return _oma_body(phys, h2_sorted, d_units, v_max_sel, eps0, inner,
                         tdma=False, mask=mask)
    if scheme == "oma_tdma":
        return _oma_body(phys, h2_sorted, d_units, v_max_sel, eps0, inner,
                         tdma=True, mask=mask)
    if scheme == "random":
        return _random_body(phys, key, h2_sorted, d_units, v_max_sel, eps0,
                            mask=mask)
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# round (trace-safe body + legacy eager wrapper)
# ---------------------------------------------------------------------------
def _round_body(state: FLState, data: FedData, phys, ops: Dict, scheme: str,
                use_roni: bool, n_selected: int, local_steps: int,
                server_steps: int, inner: str, logits_fn: Callable,
                sic_mode: str = "sequential",
                fops: FaultOps | None = None) -> Tuple[FLState, Dict]:
    """One FL round as a pure traced function.

    ``phys`` is the ``GamePhysics`` pytree; ``ops`` the dict of traced FL
    scalars (lr / epsilon / roni_threshold / samples_per_unit / weights).
    Returns (new_state, metrics) with metrics a dict of ARRAYS — under
    ``lax.scan`` they stack into the (R, ...) history.

    ``fops`` (a ``FaultOps`` pytree, or None) switches on the fault
    engine (``repro.core.faults``): adaptive/duty-cycled poisoning gated
    on the attacker's own pre-round reputation, Bernoulli channel outages
    that re-solve the equilibrium over the surviving lanes (the traced
    ``mask`` path), and compute-slowdown stragglers.  ``fops=None``
    compiles the EXACT pre-fault round program — the None-vs-pytree
    treedef is the only structural compile flag, every fault knob is an
    operand.  When faults are on, one extra PRNG split feeds the fault
    draws (the fault trajectory is a different — equally deterministic —
    stream from the fault-free one)."""
    m = data.x.shape[0]
    key, k_ch, k_map, k_dt, k_alloc = jax.random.split(state.key, 5)
    if fops is not None:
        key, k_fault = jax.random.split(key)

    # 1. selection (z is every client's current reputation — the adaptive
    # attacker reads its OWN score off the same Eq.-16 vector)
    sel, z_all = rep.select_clients(state.rep, data.sizes, n_selected,
                                    ops["epsilon"], ops["weights"])
    sel_mask = jnp.zeros((m,), bool).at[sel].set(True)

    # 2. channel + SIC order (descending gain among the selected); fault
    # processes apply BEFORE the sort, so outage lanes (h2 = 0) sink to
    # the SIC tail — the masked-solve invariant of stackelberg._solve
    h2 = sample_round_channels(k_ch, state.distances)[sel]
    if fops is not None:
        outage, slow = sample_round_faults(k_fault, fops, n_selected)
        h2 = faded_channel(fops, h2, outage, slow)
    order = jnp.argsort(-h2)
    sel_sorted = sel[order]
    h2_sorted = h2[order]
    alive = None if fops is None else ~outage[order]
    slow_sorted = None if fops is None else slow[order]

    # 3. allocation — dropped lanes masked, so the game re-solves with
    # n_eff survivors (graceful mid-round degradation, not a crash)
    d_units = data.sizes[sel_sorted] * ops["samples_per_unit"]
    v_max_sel = state.v_max[sel_sorted]
    alloc = _allocate_traced(scheme, phys, inner, k_alloc, h2_sorted,
                             d_units, v_max_sel, sic_mode, mask=alive)
    v = alloc.v if scheme != "ideal" else jnp.zeros_like(alloc.v)

    # 4. DT split of the selected clients' data.  (A dropped lane's v is
    # zeroed by the masked solve, so none of its samples map this round —
    # the dropout erases the client from the round end-to-end.)
    xs = data.x[sel_sorted]
    if fops is None:
        ys_train = data.y_train[sel_sorted]
    else:
        # adaptive attacker: poison only while the behavioral gates pass
        # (own reputation ≥ rep_gate · median(Z) AND the duty cycle is in
        # an on-phase); otherwise train honestly on the true labels
        attacking = attack_active(fops, data.poisoned[sel_sorted],
                                  z_all[sel_sorted], jnp.median(z_all),
                                  state.round)
        ys_train = jnp.where(attacking[:, None], data.y_train[sel_sorted],
                             data.y[sel_sorted])
    msk = data.mask[sel_sorted]
    map_mask = split_mapping_mask(k_map, msk, v)      # True = mapped to DT
    if scheme == "ideal":
        map_mask = jnp.zeros_like(map_mask)
    local_w = (msk & ~map_mask).astype(jnp.float32)

    # 5a. local SGD (poisoners flip labels locally)
    client_params = local_train_all(logits_fn, state.params, xs, ys_train,
                                    local_w, local_steps, ops["lr"])
    # 5b. server/DT SGD on mapped data (ε feature deviation).  The twin
    # mirrors the client's data AS-IS — a poisoner's mapped samples carry
    # the flipped labels too (DT offers no anti-poison oracle; DESIGN.md §8)
    n, cap, dim = xs.shape
    x_dt = dt_feature_noise(k_dt, xs, ops["epsilon"]).reshape(n * cap, dim)
    server_params = sgd_train(logits_fn, state.params, x_dt,
                              ys_train.reshape(-1),
                              map_mask.reshape(-1).astype(jnp.float32),
                              server_steps, ops["lr"])

    # 6. straggler deadline check (tolerance: the leader schedules
    # deadline-EXACT finishes, so `<=` would coin-flip on float error).
    # A slowed client's CPU underdelivers the allocated f_n: its ACHIEVED
    # compute time is t_cmp·slowdown, so deadline-exact schedules miss.
    if scheme == "ideal":
        meets = jnp.ones((n_selected,), bool)
    else:
        t_cmp_real = alloc.t_cmp if fops is None else (
            alloc.t_cmp * slowdown_multiplier(fops, slow_sorted))
        meets = (t_cmp_real + alloc.t_com) <= phys.t_max * 1.001
    if fops is not None:
        meets = meets & alive            # a dropped update never arrives

    # 7. RONI
    if use_roni:
        # per-update RONI against the pre-round global model (Biscotti [31]);
        # the DT/server update is validated the same way — the twin mirrors
        # poisoned mapped data too
        positive, acc_base, _ = roni_filter(client_params, state.params,
                                            d_units, v, ops["epsilon"],
                                            logits_fn, data.x_val,
                                            data.y_val,
                                            ops["roni_threshold"])
        server_ok = (acc_base[0]
                     - _val_acc(logits_fn, data.x_val, data.y_val,
                                server_params)) <= ops["roni_threshold"]
    else:
        positive = jnp.ones((n_selected,), bool)
        server_ok = jnp.asarray(True)
    include = positive & meets

    # 8. aggregation (Eq. 3); ideal uses plain FedAvg on full local data.
    # If RONI rejected EVERYTHING this round, keep the previous global model
    # (an empty aggregate would zero the parameters) — a jnp.where over the
    # parameter pytree, so the decision stays on-device inside the scan.
    if scheme == "ideal":
        agg = fedavg(client_params, d_units, include_mask=include)
        any_included = jnp.any(include)
    else:
        agg = dt_aggregate(client_params, server_params, d_units, v,
                           ops["epsilon"], include_mask=include,
                           server_include=server_ok)
        any_included = jnp.any(include) | server_ok
    new_params = jax.tree_util.tree_map(
        lambda new, old: jnp.where(any_included, new, old),
        agg, state.params)

    # 9. reputation bookkeeping (a dropped client's verdict is not
    # recorded — the server never received an update to judge)
    new_rep = rep.update_interactions(state.rep, sel_sorted, positive,
                                      count_mask=alive)
    new_rep = rep.update_staleness(new_rep, sel_mask)

    metrics = {
        "round": state.round,
        "selected": sel_sorted,
        "val_acc": _val_acc(logits_fn, data.x_val, data.y_val, new_params),
        "latency": alloc.t_total,
        "energy": alloc.energy,
        "total_cost": alloc.t_total + alloc.energy,
        "n_excluded_roni": jnp.sum(~positive).astype(jnp.int32),
        "n_stragglers": jnp.sum(~meets).astype(jnp.int32),
        "n_poisoned_selected":
            jnp.sum(data.poisoned[sel_sorted]).astype(jnp.int32),
        "mean_v": jnp.mean(v),
    }
    if fops is not None:
        metrics["n_dropped"] = jnp.sum(~alive).astype(jnp.int32)
        metrics["n_slowed"] = jnp.sum(slow_sorted & alive).astype(jnp.int32)
        metrics["n_attacking"] = jnp.sum(attacking).astype(jnp.int32)
    new_state = FLState(params=new_params, rep=new_rep, v_max=state.v_max,
                        distances=state.distances, key=key,
                        round=state.round + 1)
    return new_state, metrics


def _fl_ops(fl: FLConfig, dtype) -> Dict:
    """The traced-operand remainder of ``FLConfig`` (every numeric knob as
    a device scalar), mirroring ``GameConfig.physics()``: sweeping lr / ε /
    thresholds / selection weights reuses one executable."""
    return {
        "lr": jnp.asarray(fl.lr, dtype),
        "epsilon": jnp.asarray(fl.epsilon, dtype),
        "roni_threshold": jnp.asarray(fl.roni_threshold, dtype),
        "samples_per_unit": jnp.asarray(fl.samples_per_unit, dtype),
        "weights": jnp.asarray(fl.weights, dtype),
    }


# public alias: the FL knob dict IS a differentiable pytree — every entry
# is a traced array operand of the round body, so callers (the mechanism
# layer's ``to_fl_ops``) may pass (possibly grad-carrying) replacements
# through the ``ops_override`` argument of the training entry points.
fl_ops = _fl_ops


def _merge_ops(ops: Dict, ops_override) -> Dict:
    """Overlay caller-supplied knob arrays on the config-derived dict.
    Keys must already exist (typos must not silently vanish); values are
    cast to the engine dtype so an f64 mechanism run still hits the f32
    executable."""
    if ops_override is None:
        return ops
    unknown = set(ops_override) - set(ops)
    if unknown:
        raise ValueError(f"ops_override keys {sorted(unknown)} are not FL "
                         f"knobs; expected a subset of {sorted(ops)}")
    merged = dict(ops)
    for k, v in ops_override.items():
        merged[k] = jnp.asarray(v, ops[k].dtype)
    return merged


def _canon_state(state: FLState) -> FLState:
    """Fixed-dtype scan carry: a weak-typed python-int ``round`` would
    retrace the scan (or fail the carry fixpoint)."""
    return dataclasses.replace(state,
                               round=jnp.asarray(state.round, jnp.int32))


def _fault_operand(faults, dtype) -> FaultOps | None:
    """Normalize the user-facing ``faults`` argument: None passes through
    (the structural off flag), a ``FaultConfig`` lowers to traced operands,
    a pre-built ``FaultOps`` (e.g. a stacked [C] pytree) is used as-is."""
    if faults is None or isinstance(faults, FaultOps):
        return faults
    return fault_ops(faults, dtype)


def _prep(state: FLState, fl: FLConfig, game: GameConfig, faults=None):
    dtype = jnp.result_type(jnp.asarray(state.distances))
    return (_canon_state(state), _physics_cached(game, dtype),
            _fl_ops(fl, dtype), _fault_operand(faults, dtype))


def _static_kwargs(fl: FLConfig, game: GameConfig, logits_fn: Callable):
    return dict(scheme=fl.scheme, use_roni=fl.use_roni,
                n_selected=fl.n_selected, local_steps=fl.local_steps,
                server_steps=fl.server_steps, inner=game.dinkelbach_inner,
                logits_fn=logits_fn, sic_mode=game.sic_mode)


def run_round(state: FLState, data: FedData, fl: FLConfig, game: GameConfig,
              logits_fn: Callable, faults=None) -> Tuple[FLState, Dict]:
    """Legacy per-round entry point: executes the shared round body through
    the eager stage-by-stage path and syncs metrics to python scalars (the
    per-round host round-trips the scanned path exists to remove)."""
    state, phys, ops, fops = _prep(state, fl, game, faults)
    new_state, metrics = _round_body(state, data, phys, ops, fops=fops,
                                     **_static_kwargs(fl, game, logits_fn))
    host = {k: jax.device_get(v) for k, v in metrics.items()}
    for k, v in host.items():
        if k == "selected":
            continue
        host[k] = v.item()
    return new_state, host


def run_training_eager(state: FLState, data: FedData, fl: FLConfig,
                       game: GameConfig, logits_fn: Callable, rounds: int,
                       faults=None):
    """Legacy host-side round loop: R separate dispatch chains with
    per-round metric syncs.  Kept as the numerical reference for the
    scanned trajectory (tests) and as the baseline tier of
    ``benchmarks/training_throughput.py``."""
    history = []
    for _ in range(rounds):
        state, metrics = run_round(state, data, fl, game, logits_fn, faults)
        history.append(metrics)
    return state, history


# ---------------------------------------------------------------------------
# scan-compiled trajectory + seed-vmapped sweeps
# ---------------------------------------------------------------------------
_TRAINING_STATIC = ("scheme", "use_roni", "n_selected", "local_steps",
                    "server_steps", "inner", "logits_fn", "rounds",
                    "sic_mode")


@partial(jax.jit, static_argnames=_TRAINING_STATIC)
def _training_scan_jit(phys, state, data, ops, fops, *, rounds, **static):
    TRACE_COUNTS["run_training_scan"] += 1

    def body(carry, _):
        TRACE_COUNTS["run_round"] += 1
        return _round_body(carry, data, phys, ops, fops=fops, **static)

    return jax.lax.scan(body, state, None, length=rounds)


@partial(jax.jit,
         static_argnames=_TRAINING_STATIC + ("data_batched", "shards"))
def _batched_training_jit(phys, states, data, ops, fops, *, rounds,
                          data_batched, shards=1, **static):
    TRACE_COUNTS["batched_training"] += 1

    def run(ph, sts, dt, op, fo):
        def scan_one(st, d1):
            def body(carry, _):
                TRACE_COUNTS["run_round"] += 1
                return _round_body(carry, d1, ph, op, fops=fo, **static)

            return jax.lax.scan(body, st, None, length=rounds)

        if data_batched:
            return jax.vmap(scan_one)(sts, dt)
        return jax.vmap(lambda st: scan_one(st, dt))(sts)

    if shards > 1:
        # each device scans its local seed block independently (no
        # collectives — the trajectories never talk to each other)
        dspec = P(game_mesh.DRAW_AXIS) if data_batched else P()
        run = shard_map(run, mesh=game_mesh.mesh_1d(shards),
                        in_specs=(P(), P(game_mesh.DRAW_AXIS), dspec,
                                  P(), P()),
                        out_specs=P(game_mesh.DRAW_AXIS), check_rep=False)
    return run(phys, states, data, ops, fops)


def run_training_scan(state: FLState, data: FedData, fl: FLConfig,
                      game: GameConfig, logits_fn: Callable, rounds: int,
                      faults=None, ops_override=None):
    """The whole R-round trajectory as ONE ``lax.scan`` dispatch of one
    compiled program.

    Returns ``(final_state, metrics)`` where ``metrics`` is a dict of
    stacked arrays — scalars become ``(R,)``, ``selected`` becomes
    ``(R, N)`` — i.e. the per-round dicts of the legacy path transposed
    into arrays (``run_training`` converts back for compatibility).
    Compile key: (scheme, use_roni, shapes/steps, rounds, logits_fn,
    dinkelbach inner); all physics and FL scalars are traced operands, so
    e.g. an lr or t_max sweep reuses the executable.

    ``faults`` (a ``FaultConfig``, or None) switches on the fault engine —
    see ``repro.core.faults``.  Its presence is the only new structural
    compile flag; every fault knob is a traced operand, so a scenario
    sweep shares the executable.

    ``ops_override`` (dict, a subset of the ``fl_ops`` keys) replaces
    individual traced knobs with caller-supplied arrays — the mechanism
    layer's evaluate-learned-knobs path (``mechanism.to_fl_ops``); same
    executable, the override is just different operand values.
    """
    state, phys, ops, fops = _prep(state, fl, game, faults)
    ops = _merge_ops(ops, ops_override)
    return _training_scan_jit(phys, state, data, ops, fops, rounds=rounds,
                              **_static_kwargs(fl, game, logits_fn))


def run_training(state: FLState, data: FedData, fl: FLConfig,
                 game: GameConfig, logits_fn: Callable, rounds: int,
                 faults=None):
    """Compat shim over ``run_training_scan``: same signature and return
    shape as the legacy host loop — a list of per-round metric dicts with
    python scalars (``selected`` stays an ``[N]`` int array per round)."""
    state, stacked = run_training_scan(state, data, fl, game, logits_fn,
                                       rounds, faults)
    host = {k: jax.device_get(v) for k, v in stacked.items()}
    history = [{k: (v[r] if v.ndim > 1 else v[r].item())
                for k, v in host.items()} for r in range(rounds)]
    return state, history


def stack_states(states) -> FLState:
    """Stack S per-seed ``FLState``s into one with a leading seed axis on
    every leaf — the ``batched_training`` input layout."""
    states = [_canon_state(s) for s in states]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def stack_fl_ops(fls: Sequence[FLConfig], dtype=jnp.float32) -> Dict:
    """Stack C ``FLConfig`` points into one traced-ops dict with a leading
    [C] axis on every numeric knob ([C, 3] for the selection weights) — the
    config axis of ``sweep_training``, mirroring ``stack_physics``.

    All points must agree on the discrete algorithm choices (scheme,
    use_roni, n_selected, local/server steps): those are static compile
    keys, so a grid that varies them is several sweeps, not one."""
    fls = list(fls)
    statics = {(f.scheme, f.use_roni, f.n_selected, f.local_steps,
                f.server_steps) for f in fls}
    if len(statics) != 1:
        raise ValueError(
            "sweep config points mix static algorithm keys "
            f"{sorted(statics)}; scheme/use_roni/n_selected/steps are "
            "compile keys — sweep each combination separately")
    per_point = [_fl_ops(f, dtype) for f in fls]
    return {k: jnp.stack([ops[k] for ops in per_point])
            for k in per_point[0]}


def _shard_tree(tree, size: int):
    """``_shard_axis`` over every leaf of a pytree (leading batch/grid
    axis) — the legacy GSPMD placement recipe, kept for external callers;
    the training tiers now pad + ``shard_map`` via ``game_mesh``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(
        treedef, _shard_axis(tuple(leaves), axis=0, size=size))


def _unpad_result(final, metrics, *dims):
    """Slice a training result's leading axes back to the caller's
    logical sizes (no-op when the batch axes weren't padded)."""
    probe = jax.tree_util.tree_leaves(final)[0]
    if tuple(probe.shape[:len(dims)]) == dims:
        return final, metrics
    sl = tuple(slice(0, d) for d in dims)
    cut = lambda x: x[sl]
    return (jax.tree_util.tree_map(cut, final),
            jax.tree_util.tree_map(cut, metrics))


def batched_training(states: FLState, data: FedData, fl: FLConfig,
                     game: GameConfig, logits_fn: Callable, rounds: int,
                     faults=None):
    """S independent R-round trajectories in ONE XLA dispatch: ``vmap`` of
    the scanned round loop over a leading seed axis, device-sharded across
    the seed axis (single-device no-op).

    states : ``FLState`` with a leading S axis on every leaf (see
             ``stack_states``) — typically S seeds of the same experiment.
    data   : shared ``FedData``, or one with a leading S axis
             (``data.x.ndim == 4``) for per-seed datasets — e.g. an
             attacker-fraction axis where seed s was poisoned at ratio r_s.
    faults : optional ``FaultConfig`` (one scenario, broadcast across the
             seed axis) switching on the fault engine for every seed.

    Returns ``(final_states, metrics)`` with an extra leading S axis on
    every leaf/metric relative to ``run_training_scan``.  Seed s of the
    result equals ``run_training_scan`` on seed s alone (pure batching).
    """
    states, phys, ops, fops = _prep(states, fl, game, faults)
    data_batched = data.x.ndim == 4
    s = jax.tree_util.tree_leaves(states)[0].shape[0]
    shards = game_mesh.batch_shards(s)
    if shards > 1:
        sp = game_mesh.padded_size(s, shards)
        states = game_mesh.put_tree(game_mesh.pad_tree(states, 0, sp),
                                    0, shards)
        if data_batched:
            data = game_mesh.put_tree(game_mesh.pad_tree(data, 0, sp),
                                      0, shards)
    final, metrics = _batched_training_jit(
        phys, states, data, ops, fops, rounds=rounds,
        data_batched=data_batched, shards=shards,
        **_static_kwargs(fl, game, logits_fn))
    return _unpad_result(final, metrics, s)


@partial(jax.jit,
         static_argnames=_TRAINING_STATIC + ("data_mode", "grid_shards"))
def _sweep_training_jit(phys, states, data, ops, fops, *, rounds,
                        data_mode, grid_shards=(1, 1), **static):
    """Nested vmap of the scanned trajectory over the TRUE 2D C×S grid —
    config axis outer (physics/FL ops/fault ops mapped per point), seed
    axis inner — so one executable covers the whole config grid and the
    grid tiles directly onto the 2D (cfg, draw) device mesh.  ``fops=None``
    (an empty pytree under vmap) compiles the fault-free grid program.

    ``data_mode`` keys how the dataset rides the grid: ``"shared"`` (one
    dataset for every cell), ``"seed"`` (leading [S] axis, shared across
    configs) or ``"config"`` (leading [C] axis, shared across seeds)."""
    TRACE_COUNTS["sweep_training"] += 1

    def grid(ph_c, sts, dt, op_c, fo_c):
        def per_config(ph, st_s, d_c, op, fo):
            def scan_cell(st, d1):
                def body(carry, _):
                    TRACE_COUNTS["run_round"] += 1
                    return _round_body(carry, d1, ph, op, fops=fo, **static)

                return jax.lax.scan(body, st, None, length=rounds)

            if data_mode == "seed":
                return jax.vmap(scan_cell)(st_s, d_c)      # d_c is [S, ...]
            return jax.vmap(lambda st: scan_cell(st, d_c))(st_s)

        data_in = 0 if data_mode == "config" else None
        return jax.vmap(per_config, in_axes=(0, 0, data_in, 0, 0))(
            ph_c, sts, dt, op_c, fo_c)

    dc, dk = grid_shards
    if dc * dk > 1:
        # 2D (cfg, draw) mesh: each device owns a [C/dc, S/dk] grid tile;
        # seed-shared data splits along draw only, config-shared along cfg
        dspec = {"shared": P(), "seed": P(game_mesh.DRAW_AXIS),
                 "config": P(game_mesh.CFG_AXIS)}[data_mode]
        cfg_p = P(game_mesh.CFG_AXIS)
        grid = shard_map(grid, mesh=game_mesh.mesh_2d(dc, dk),
                         in_specs=(cfg_p,
                                   P(game_mesh.CFG_AXIS, game_mesh.DRAW_AXIS),
                                   dspec, cfg_p, cfg_p),
                         out_specs=P(game_mesh.CFG_AXIS, game_mesh.DRAW_AXIS),
                         check_rep=False)
    return grid(phys, states, data, ops, fops)


def _sweep_fault_ops(faults, c: int, dtype) -> FaultOps | None:
    """Normalize ``sweep_training``'s ``faults`` argument to [C]-leaved
    ``FaultOps`` (or None): a single ``FaultConfig`` broadcasts across the
    config axis, a sequence must have C entries (one scenario per config
    point), a pre-stacked ``FaultOps`` is validated and used as-is."""
    if faults is None:
        return None
    if isinstance(faults, FaultOps):
        got = faults.rep_gate.shape
        if got != (c,):
            raise ValueError(f"stacked FaultOps leaves must be [{c}]-shaped "
                             f"(one per config point); got {got}")
        return faults
    if isinstance(faults, FaultConfig):
        faults = [faults] * c
    faults = list(faults)
    if len(faults) == 1:
        faults = faults * c
    if len(faults) != c:
        raise ValueError(f"fault axis mismatch: {len(faults)} FaultConfig "
                         f"points vs {c} config points")
    return stack_fault_ops(faults, dtype)


def sweep_training(states: FLState, data: FedData, fls, games,
                   logits_fn: Callable, rounds: int, faults=None,
                   data_axis: str = "seed", ops_override=None):
    """A whole config-grid of training runs — C (``FLConfig``,
    ``GameConfig``) points × S seeds × R rounds — as ONE XLA dispatch of
    one executable (the Fig. 5/6/7/8 workload).

    fls    : C ``FLConfig`` points (or a single one, broadcast to match
             ``games``).  Every numeric knob (lr, ε, RONI threshold,
             selection weights, samples_per_unit) rides the config axis as
             a traced operand; the discrete keys (scheme, use_roni,
             n_selected, steps) must agree across points — they are the
             only compile keys.
    games  : C ``GameConfig`` points (or a single one); their eleven
             physics floats are stacked into a [C]-leaved ``GamePhysics``.
    states : ``FLState`` with a leading S seed axis (``stack_states``),
             shared across the config axis.
    data   : shared ``FedData`` (``x.ndim == 3``), or one with a leading
             batch axis (``x.ndim == 4``) whose meaning ``data_axis``
             selects — ``"seed"`` (default): S per-seed datasets shared
             across configs (fig5's attacker-fraction axis); ``"config"``:
             C per-config datasets shared across seeds (the attack-grid
             axis, where each scenario plants different poisoned/sybil
             clients).
    faults : optional fault-engine axis — a single ``FaultConfig``
             (broadcast), a C-sequence of them (one scenario per config
             point), or a pre-stacked [C]-leaved ``FaultOps``.  Its
             presence is the only structural compile flag; every knob is
             traced, so the whole attack grid shares one executable.

    The C×S grid is a true 2D layout tiled over the (cfg, draw) device
    mesh of ``sharding/game_mesh.py`` — the same machinery as the C×K
    grid of the equilibrium sweeps; non-divisible grids pad with
    edge-replicated cells that are sliced off the result (single-device
    no-op).  Returns
    ``(final_states, metrics)`` with a leading ``(C, S)`` prefix on every
    leaf — cell (c, s) equals ``run_training_scan`` with configs c on seed
    s alone (pure batching).
    """
    if data_axis not in ("seed", "config"):
        raise ValueError(f"data_axis must be 'seed' or 'config', "
                         f"got {data_axis!r}")
    fls = [fls] if isinstance(fls, FLConfig) else list(fls)
    games = [games] if isinstance(games, GameConfig) else list(games)
    # the config-axis length is set by whichever axis is non-singleton —
    # fls/games first, then the fault axis (an attack grid may sweep
    # scenarios over ONE (FLConfig, GameConfig) point); singletons
    # broadcast, non-singleton axes must agree
    if isinstance(faults, FaultOps):
        n_faults = faults.rep_gate.shape[0]
    elif faults is None or isinstance(faults, FaultConfig):
        n_faults = 1
    else:
        faults = list(faults)
        n_faults = len(faults)
    c = max(len(fls), len(games))
    if len(fls) == 1:
        fls = fls * c
    if len(games) == 1:
        games = games * c
    if len(fls) != len(games):
        raise ValueError(f"config axis mismatch: {len(fls)} FLConfig vs "
                         f"{len(games)} GameConfig points")
    if c == 1 and n_faults > 1:
        fls = fls * n_faults
        games = games * n_faults
        c = n_faults
    states = _canon_state(states)
    dtype = jnp.result_type(jnp.asarray(states.distances))
    phys = stack_physics(games, dtype)            # [C] leaves
    ops = stack_fl_ops(fls, dtype)                # [C] / [C, 3] leaves
    # knob override (see run_training_scan): leaves must carry the [C] axis
    ops = _merge_ops(ops, ops_override)
    fops = _sweep_fault_ops(faults, c, dtype)     # [C] leaves (or None)
    s = jax.tree_util.tree_leaves(states)[0].shape[0]

    # the states grid is TRUE 2D — [C, S, ...] leaves, configs outer,
    # seeds inner — so it tiles directly onto the (cfg, draw) device mesh
    bcast_cfg = lambda x: jnp.broadcast_to(x[None], (c,) + x.shape)
    states = jax.tree_util.tree_map(bcast_cfg, states)
    if data.x.ndim == 4:
        data_mode = data_axis
        if data_axis == "config" and data.x.shape[0] != c:
            raise ValueError(
                f"data_axis='config' needs a leading [{c}] axis on the "
                f"data (one dataset per config point); got "
                f"{data.x.shape[0]}")
    else:
        data_mode = "shared"

    # multi-device: pad the grid to the (dc, dk) mesh factorization with
    # edge-replicated cells (sliced back off below) and place the shards
    grid = game_mesh.grid_layout(c, s)
    dc, dk = grid
    if dc * dk > 1:
        cp = game_mesh.padded_size(c, dc)
        sp = game_mesh.padded_size(s, dk)
        pad_cfg = lambda t: game_mesh.pad_tree(t, 0, cp)
        phys = game_mesh.put_grid_tree(pad_cfg(phys), grid, cfg_only=True)
        ops = game_mesh.put_grid_tree(pad_cfg(ops), grid, cfg_only=True)
        if fops is not None:
            fops = game_mesh.put_grid_tree(pad_cfg(fops), grid,
                                           cfg_only=True)
        states = game_mesh.put_grid_tree(
            game_mesh.pad_tree(pad_cfg(states), 1, sp), grid)
        if data_mode == "seed":
            data = game_mesh.pad_tree(data, 0, sp)
        elif data_mode == "config":
            data = pad_cfg(data)

    final, metrics = _sweep_training_jit(
        phys, states, data, ops, fops, rounds=rounds,
        data_mode=data_mode, grid_shards=grid,
        **_static_kwargs(fls[0], games[0], logits_fn))
    return _unpad_result(final, metrics, c, s)
