"""One round of DT-assisted federated learning over NOMA (paper Fig. 1).

Round pipeline (§II–§V):
  1. reputation-based selection of N of M clients            (§III)
  2. fresh block-fading channel realization, SIC ordering    (§II-C)
  3. Stackelberg allocation (v*, f*, p*, α*) or baseline     (§IV–V)
  4. DT data split: Bernoulli(v_n) per sample → server-mapped (with ε
     feature deviation) vs local                             (§II)
  5. local SGD on clients (poisoners train on flipped labels) (Eq. 2)
     + server/DT SGD on the union of mapped data
  6. deadline check: clients with t_cmp + t_com > T_max straggle and
     drop out (the mechanism DT/NOMA alleviate)
  7. RONI validation → PI/NI bookkeeping, exclusion          (§III-3)
  8. DT-aware aggregation, Eq. (3)
  9. staleness update, Eq. (13)

Schemes: "proposed" (DT+NOMA), "wo_dt" (v≡0), "oma", "ideal" (no resource
constraints), matching §VI-C benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..data.federated import FedData
from . import reputation as rep
from .aggregation import dt_aggregate, fedavg
from .digital_twin import dt_feature_noise, split_mapping_mask
from .roni import roni_filter
from .stackelberg import (Allocation, GameConfig, batched_equilibrium,
                          batched_oma_allocation, batched_oma_tdma_allocation,
                          batched_random_allocation, batched_wo_dt_allocation,
                          equilibrium, oma_allocation, oma_tdma_allocation,
                          random_allocation, sweep_equilibrium,
                          sweep_oma_allocation, sweep_oma_tdma_allocation,
                          sweep_random_allocation, sweep_wo_dt_allocation,
                          wo_dt_allocation)
from .channel import sample_round_channels


@dataclass(frozen=True)
class FLConfig:
    n_selected: int = 5
    local_steps: int = 20
    server_steps: int = 20
    lr: float = 0.05
    epsilon: float = 0.0            # DT mapping deviation
    roni_threshold: float = 0.02
    weights: Tuple[float, float, float] = rep.PROPOSED_WEIGHTS
    scheme: str = "proposed"   # proposed | wo_dt | oma | oma_tdma | ideal | random
    use_roni: bool = True
    samples_per_unit: float = 1.0   # D_n (samples) → data units for latency


@dataclass
class FLState:
    params: dict
    rep: rep.ReputationState
    v_max: jax.Array        # [M]
    distances: jax.Array    # [M]
    key: jax.Array
    round: int = 0


# ---------------------------------------------------------------------------
# local / server SGD
# ---------------------------------------------------------------------------
def masked_loss(logits_fn, p, x, y, w):
    logits = logits_fn(p, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


@partial(jax.jit, static_argnames=("logits_fn", "steps"))
def sgd_train(logits_fn, params, x, y, w, steps: int, lr: float):
    """Full-batch SGD (Eq. 2) for ``steps`` steps with per-sample weights.

    jit-cached on (logits_fn, steps) — an eager ``lax.scan`` here would
    retrace (and recompile the conv backward) every FL round."""
    def step(p, _):
        g = jax.grad(partial(masked_loss, logits_fn))(p, x, y, w)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

    params, _ = jax.lax.scan(step, params, None, length=steps)
    return params


@partial(jax.jit, static_argnames=("logits_fn", "steps"))
def local_train_all(logits_fn, params, x, y, w, steps, lr):
    """vmap local SGD over the selected clients. x: [N, cap, dim]."""
    return jax.vmap(lambda xi, yi, wi: sgd_train(logits_fn, params, xi, yi,
                                                 wi, steps, lr))(x, y, w)


@partial(jax.jit, static_argnames=("logits_fn",))
def _val_acc(logits_fn, x_val, y_val, params):
    logits = logits_fn(params, x_val)
    return jnp.mean((jnp.argmax(logits, -1) == y_val).astype(jnp.float32))


# ---------------------------------------------------------------------------
# round
# ---------------------------------------------------------------------------
def allocate(scheme: str, game_cfg: GameConfig, key, h2_sorted, d_units,
             v_max_sel) -> Allocation:
    """Per-round resource allocation.  Every scheme routes through a fully
    jitted body whose physics floats are traced operands — one compile per
    (scheme, shape), shared across GameConfig parameterizations, no host
    syncs inside the solve."""
    if scheme in ("proposed", "ideal"):
        return equilibrium(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "wo_dt":
        return wo_dt_allocation(game_cfg, h2_sorted, d_units)
    if scheme == "oma":
        return oma_allocation(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "oma_tdma":
        return oma_tdma_allocation(game_cfg, h2_sorted, d_units, v_max_sel)
    if scheme == "random":
        return random_allocation(game_cfg, key, h2_sorted, d_units, v_max_sel)
    raise ValueError(scheme)


def allocate_batched(scheme: str, game_cfg: GameConfig, h2_batch, d_batch,
                     v_max_batch, epsilon: float = 0.0,
                     key=None) -> Allocation:
    """Monte-Carlo allocation: solve K network realizations in one XLA
    call (used by the Fig. 6–9 benchmark sweeps and throughput bench).
    EVERY scheme batches — proposed/ideal/wo_dt through the Stackelberg
    engine, OMA-FDMA/OMA-TDMA/random through their vmapped baseline
    bodies — and the K axis is device-sharded (single-device no-op).
    ``epsilon`` (DT mapping deviation) reaches the engine for the DT
    schemes; "wo_dt" has no twin and ignores it (matching
    ``wo_dt_allocation``).  ``key`` seeds the "random" scheme's per-draw
    randomness (defaults to PRNGKey(0))."""
    if scheme in ("proposed", "ideal"):
        return batched_equilibrium(game_cfg, h2_batch, d_batch, v_max_batch,
                                   epsilon=epsilon)
    if scheme == "wo_dt":
        return batched_wo_dt_allocation(game_cfg, h2_batch, d_batch)
    if scheme == "oma":
        return batched_oma_allocation(game_cfg, h2_batch, d_batch,
                                      v_max_batch, epsilon=epsilon)
    if scheme == "oma_tdma":
        return batched_oma_tdma_allocation(game_cfg, h2_batch, d_batch,
                                           v_max_batch, epsilon=epsilon)
    if scheme == "random":
        key = jax.random.PRNGKey(0) if key is None else key
        return batched_random_allocation(game_cfg, key, h2_batch, d_batch,
                                         v_max_batch, epsilon=epsilon)
    raise ValueError(f"no batched path for scheme {scheme!r}")


def sweep_allocation(scheme: str, configs, h2_batch, d_batch, v_max_batch,
                     epsilon=0.0, key=None) -> Allocation:
    """Benchmark-grid allocation: C config points × K realizations of one
    scheme in ONE XLA dispatch of one compiled executable (the fig9 sweep
    workload).  ``configs`` is a sequence of GameConfig whose physics are
    stacked into a traced [C] axis; ``epsilon`` may be scalar or [C].
    Returns an ``Allocation`` with a [C, K] prefix on every field."""
    if scheme in ("proposed", "ideal"):
        return sweep_equilibrium(configs, h2_batch, d_batch, v_max_batch,
                                 epsilon=epsilon)
    if scheme == "wo_dt":
        return sweep_wo_dt_allocation(configs, h2_batch, d_batch)
    if scheme == "oma":
        return sweep_oma_allocation(configs, h2_batch, d_batch, v_max_batch,
                                    epsilon=epsilon)
    if scheme == "oma_tdma":
        return sweep_oma_tdma_allocation(configs, h2_batch, d_batch,
                                         v_max_batch, epsilon=epsilon)
    if scheme == "random":
        key = jax.random.PRNGKey(0) if key is None else key
        return sweep_random_allocation(configs, key, h2_batch, d_batch,
                                       v_max_batch, epsilon=epsilon)
    raise ValueError(f"no sweep path for scheme {scheme!r}")


def run_round(state: FLState, data: FedData, fl: FLConfig, game: GameConfig,
              logits_fn: Callable) -> Tuple[FLState, Dict]:
    m = data.num_clients
    key, k_ch, k_map, k_dt, k_alloc = jax.random.split(state.key, 5)

    # 1. selection
    sel, z = rep.select_clients(state.rep, data.sizes, fl.n_selected,
                                fl.epsilon, fl.weights)
    sel_mask = jnp.zeros((m,), bool).at[sel].set(True)

    # 2. channel + SIC order (descending gain among the selected)
    h2 = sample_round_channels(k_ch, state.distances)[sel]
    order = jnp.argsort(-h2)
    sel_sorted = sel[order]
    h2_sorted = h2[order]

    # 3. allocation
    d_units = data.sizes[sel_sorted] * fl.samples_per_unit
    v_max_sel = state.v_max[sel_sorted]
    alloc = allocate(fl.scheme, game, k_alloc, h2_sorted, d_units, v_max_sel)
    v = alloc.v if fl.scheme != "ideal" else jnp.zeros_like(alloc.v)

    # 4. DT split of the selected clients' data
    xs, ys_true = data.x[sel_sorted], data.y[sel_sorted]
    ys_train = data.y_train[sel_sorted]
    msk = data.mask[sel_sorted]
    map_mask = split_mapping_mask(k_map, msk, v)      # True = mapped to DT
    if fl.scheme == "ideal":
        map_mask = jnp.zeros_like(map_mask)
    local_w = (msk & ~map_mask).astype(jnp.float32)

    # 5a. local SGD (poisoners flip labels locally)
    client_params = local_train_all(logits_fn, state.params, xs, ys_train,
                                    local_w, fl.local_steps, fl.lr)
    # 5b. server/DT SGD on mapped data (ε feature deviation).  The twin
    # mirrors the client's data AS-IS — a poisoner's mapped samples carry
    # the flipped labels too (DT offers no anti-poison oracle; DESIGN.md §8)
    n, cap, dim = xs.shape
    x_dt = dt_feature_noise(k_dt, xs, fl.epsilon).reshape(n * cap, dim)
    server_params = sgd_train(logits_fn, state.params, x_dt,
                              ys_train.reshape(-1),
                              map_mask.reshape(-1).astype(jnp.float32),
                              fl.server_steps, fl.lr)

    # 6. straggler deadline check (tolerance: the leader schedules
    # deadline-EXACT finishes, so `<=` would coin-flip on float error)
    if fl.scheme == "ideal":
        meets = jnp.ones((fl.n_selected,), bool)
    else:
        meets = (alloc.t_cmp + alloc.t_com) <= game.t_max * 1.001

    # 7. RONI
    val_acc = partial(_val_acc, logits_fn, data.x_val, data.y_val)
    if fl.use_roni:
        # per-update RONI against the pre-round global model (Biscotti [31]);
        # the DT/server update is validated the same way — the twin mirrors
        # poisoned mapped data too
        positive, _, _ = roni_filter(client_params, state.params,
                                     d_units, v, fl.epsilon, logits_fn,
                                     data.x_val, data.y_val,
                                     fl.roni_threshold)
        server_ok = _val_acc(logits_fn, data.x_val, data.y_val,
                             state.params) - val_acc(server_params) \
            <= fl.roni_threshold
    else:
        positive = jnp.ones((fl.n_selected,), bool)
        server_ok = jnp.asarray(True)
    include = positive & meets

    # 8. aggregation (Eq. 3); ideal uses plain FedAvg on full local data.
    # If RONI rejected EVERYTHING this round, keep the previous global model
    # (an empty aggregate would zero the parameters).
    any_included = bool(jnp.any(include)) or (fl.scheme != "ideal"
                                              and bool(server_ok))
    if not any_included:
        new_params = state.params
    elif fl.scheme == "ideal":
        new_params = fedavg(client_params, d_units, include_mask=include)
    else:
        new_params = dt_aggregate(client_params, server_params, d_units, v,
                                  fl.epsilon, include_mask=include,
                                  server_include=server_ok)

    # 9. reputation bookkeeping
    new_rep = rep.update_interactions(state.rep, sel_sorted, positive)
    new_rep = rep.update_staleness(new_rep, sel_mask)

    metrics = {
        "round": state.round,
        "selected": sel_sorted,
        "val_acc": float(val_acc(new_params)),
        "latency": float(alloc.t_total),
        "energy": float(alloc.energy),
        "total_cost": float(alloc.t_total + alloc.energy),
        "n_excluded_roni": int(jnp.sum(~positive)),
        "n_stragglers": int(jnp.sum(~meets)),
        "n_poisoned_selected": int(jnp.sum(data.poisoned[sel_sorted])),
        "mean_v": float(jnp.mean(v)),
    }
    new_state = FLState(params=new_params, rep=new_rep, v_max=state.v_max,
                        distances=state.distances, key=key,
                        round=state.round + 1)
    return new_state, metrics


def run_training(state: FLState, data: FedData, fl: FLConfig,
                 game: GameConfig, logits_fn: Callable, rounds: int):
    history = []
    for _ in range(rounds):
        state, metrics = run_round(state, data, fl, game, logits_fn)
        history.append(metrics)
    return state, history
