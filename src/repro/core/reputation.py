"""Reputation-based client selection (paper §III).

Z_n = ξ1·AC_n + ξ2·MS̄_n + ξ3·PI_n   (Eq. 16), top-N selected each round.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

# paper §VI weights
PROPOSED_WEIGHTS = (0.3, 0.5, 0.2)    # AC, MS, PI
BENCHMARK_WEIGHTS = (0.5, 0.5, 0.0)   # AC+MS only (PI-blind baseline)


@dataclass
class ReputationState:
    """Per-client reputation bookkeeping (all [M] arrays)."""
    ms: jax.Array         # model staleness counters (Eq. 13)
    pi_count: jax.Array   # I_n^PI
    ni_count: jax.Array   # I_n^NI


# pytree registration: the reputation bookkeeping rides inside the FLState
# carry of the scanned training trajectory (fl_round.run_training_scan).
jax.tree_util.register_dataclass(
    ReputationState,
    data_fields=tuple(f.name for f in dataclasses.fields(ReputationState)),
    meta_fields=())


def init_reputation(m: int) -> ReputationState:
    return ReputationState(ms=jnp.ones((m,)),
                           pi_count=jnp.ones((m,)),   # optimistic prior: 1 PI
                           ni_count=jnp.zeros((m,)))


def accuracy_contribution(d_sizes, epsilon: float = 0.0,
                          w1: float = 1.0, w2: float = 1.0,
                          w3: float = 1.0 / 2000.0):
    """Weibull AC model, Eq. (12): increasing & concave in data size."""
    return w1 - w2 * jnp.exp(-w3 * (d_sizes + epsilon))


def normalized_staleness(ms):
    """Eq. (14)."""
    return ms / jnp.maximum(jnp.sum(ms), 1e-12)


def positive_interaction(state: ReputationState):
    """Eq. (15)."""
    tot = state.pi_count + state.ni_count
    return state.pi_count / jnp.maximum(tot, 1e-12)


def reputation(state: ReputationState, d_sizes, epsilon: float = 0.0,
               weights: Tuple[float, float, float] = PROPOSED_WEIGHTS):
    """Eq. (16): Z over all M clients."""
    xi1, xi2, xi3 = weights
    return (xi1 * accuracy_contribution(d_sizes, epsilon)
            + xi2 * normalized_staleness(state.ms)
            + xi3 * positive_interaction(state))


def select_clients(state: ReputationState, d_sizes, n: int,
                   epsilon: float = 0.0,
                   weights: Tuple[float, float, float] = PROPOSED_WEIGHTS):
    """Top-N by reputation (descending). Returns indices [n].

    Ties break toward the lower client index (``stable=True``): equal
    reputations are common at init (identical priors), and an unpinned
    tie-break would make the selected set depend on backend sort
    internals — mechanism-learning gradients need the selection to be a
    deterministic function of Z."""
    z = reputation(state, d_sizes, epsilon, weights)
    return jnp.argsort(-z, stable=True)[:n], z


def update_staleness(state: ReputationState, selected_mask) -> ReputationState:
    """Eq. (13): reset selected clients to 1, increment the rest."""
    ms = jnp.where(selected_mask, 1.0, state.ms + 1.0)
    return ReputationState(ms=ms, pi_count=state.pi_count,
                           ni_count=state.ni_count)


def update_interactions(state: ReputationState, selected_idx,
                        positive_mask, count_mask=None) -> ReputationState:
    """Record RONI verdicts for the selected clients.

    ``count_mask`` ([n] bool operand, default None = all True) limits whose
    verdict is recorded at all: a dropped client (fault-engine channel
    outage) never delivered an update, so the server has nothing to judge —
    neither its PI nor its NI counter moves."""
    pos = positive_mask
    neg = ~positive_mask
    if count_mask is not None:
        pos = pos & count_mask
        neg = neg & count_mask
    pi = state.pi_count.at[selected_idx].add(pos.astype(state.pi_count.dtype))
    ni = state.ni_count.at[selected_idx].add(neg.astype(state.ni_count.dtype))
    return ReputationState(ms=state.ms, pi_count=pi, ni_count=ni)
