"""Trace-safe fault-injection scenario engine (ISSUE-7 tentpole).

The paper's whole premise is robustness — stragglers from limited compute
and unreliable wireless links, plus poisoning attacks on model updates —
but a static always-on label flipper is the weakest adversary the
fixed-weight reputation scheme ever meets.  This module grows the threat
model into a scenario *library* whose every knob is a TRACED operand, so
an attack-vs-defense grid rides ``sweep_training`` as one sharded XLA
dispatch per (scheme, shape) with zero mid-grid retraces.

Attack / fault taxonomy
=======================

===============  =========================  ================================
axis             knobs (all traced)         mechanism in the round body
===============  =========================  ================================
static poison    (data.poisoned only)       label-flip every round — the
                                            legacy attacker; ``FaultConfig()``
                                            defaults reproduce it exactly.
adaptive poison  ``rep_gate``               attacker reads its OWN current
                                            reputation Z_n (the selection
                                            score) and poisons only while
                                            Z_n ≥ gate · median(Z) — the
                                            gate is RELATIVE to the
                                            population median, so it is
                                            invariant to the deployed
                                            scheme's score scale; after
                                            RONI detections sink its PI
                                            term below the crowd it lies
                                            low, then resumes once
                                            reputation recovers
                                            (FLARE-style, arXiv 2511.14715).
duty cycle       ``duty_period, duty_on``   poison iff
                                            round % period < on — on–off
                                            bursts keyed on the round index
                                            carried in the scan.
sybil pool       ``data.federated.          one attacker dataset split
                 make_sybil_data``          across P colluding client IDs:
                                            each identity is small (low AC)
                                            and NI verdicts land on one
                                            identity at a time, diluting
                                            the PI bookkeeping.
channel outage   ``p_outage``               per-round Bernoulli deep fade:
                                            the client's h2 is zeroed and
                                            its lane is MASKED through the
                                            traced ``mask`` path of
                                            ``stackelberg._solve`` /
                                            ``_oma_body`` / ``_random_body``
                                            — the equilibrium re-solves
                                            with the n_eff survivors
                                            (graceful mid-round
                                            degradation, not a crash).
compute slowdown ``p_slow,                  a slowed client's achieved
                 compute_slowdown``         compute time is t_cmp·slowdown
                                            (its CPU underdelivers the
                                            allocated f_n), so it misses
                                            the deadline it was scheduled
                                            to exactly meet → straggler.
channel fade     ``channel_fade``           slowed clients also transmit
                                            through a degraded channel
                                            h2·fade (the solver SEES the
                                            fade and re-allocates — unlike
                                            the outage, which it must
                                            survive).
===============  =========================  ================================

Graceful mid-round degradation
------------------------------
A dropped client becomes a masked lane (PR 6's serving path): its h2 = 0
tail slot is invisible to every SIC suffix sum, ``jnp.where`` masking
erases it from d_hat / latency / energy / feasibility, and OMA divides
bandwidth/slots by the survivor count.  The masked solve zeroes the
lane's mapping ratio v, so none of its samples DT-map this round, its
local update never arrives (``meets &= alive``), and its reputation
bookkeeping is skipped (no PI/NI — the server never saw an update to
judge): the dropout erases the client from the round END-TO-END, and a
round with dropped clients matches the same round solved with those
lanes masked (the parity tests budget ≤1e-5).  The *system-level*
resilience is that the surviving n_eff clients still get a coherent
re-solved equilibrium — the round degrades instead of crashing.

Execution contract
------------------
``FaultConfig`` is a frozen hashable record of plain floats/ints;
``fault_ops`` lowers it to a ``FaultOps`` pytree of array operands
(mirroring ``GameConfig.physics()`` / ``fl_round._fl_ops``), and
``stack_fault_ops`` stacks C points into [C]-leaved pytrees for the
config axis of ``sweep_training``.  The ONLY structural compile flag is
``faults=None`` vs present (the None-vs-pytree treedef); every knob is an
operand, so a whole attack grid shares one executable per
(scheme, use_roni, shape).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FaultConfig:
    """One scenario's fault/attack knobs (plain numbers, hashable).

    The defaults are the NULL scenario: attackers (clients flagged in
    ``data.poisoned``) poison every round (``rep_gate=0`` — reputation is
    non-negative — and a 1/1 duty cycle), and no straggler/outage process
    runs.  ``FaultConfig()`` therefore reproduces the legacy static
    attacker bit-for-bit up to the extra PRNG splits drawn for the fault
    processes (documented in ``fl_round._round_body``)."""
    # -- adaptive attacker ------------------------------------------------
    rep_gate: float = 0.0        # poison while own Z ≥ gate · median(Z)
    duty_period: int = 1         # on–off cycle length in rounds
    duty_on: int = 1             # attacking rounds per period (≥ period ⇒ always)
    # -- straggler / dropout processes ------------------------------------
    p_outage: float = 0.0        # P(per-round channel outage → dropped lane)
    p_slow: float = 0.0          # P(per-round compute straggler)
    compute_slowdown: float = 1.0   # achieved t_cmp multiplier when slowed
    channel_fade: float = 1.0    # h2 multiplier when slowed (solver-visible)

    def ops(self, dtype=jnp.float32) -> "FaultOps":
        return fault_ops(self, dtype)


@dataclass(frozen=True)
class FaultOps:
    """The traced view of ``FaultConfig``: every field a JAX array operand
    (scalar per scenario; [C] under the config axis of ``sweep_training``).
    Registered as a pytree so it flows through jit/vmap/scan; ``None`` in
    its place compiles the exact pre-fault round program."""
    rep_gate: jax.Array
    duty_period: jax.Array       # int32
    duty_on: jax.Array           # int32
    p_outage: jax.Array
    p_slow: jax.Array
    compute_slowdown: jax.Array
    channel_fade: jax.Array


_FAULT_FIELDS = tuple(f.name for f in dataclasses.fields(FaultOps))
_INT_FIELDS = ("duty_period", "duty_on")
jax.tree_util.register_dataclass(FaultOps, data_fields=_FAULT_FIELDS,
                                 meta_fields=())


def fault_ops(fc: FaultConfig, dtype=jnp.float32) -> FaultOps:
    """Lower one ``FaultConfig`` to device-scalar operands."""
    return FaultOps(**{
        name: jnp.asarray(getattr(fc, name),
                          jnp.int32 if name in _INT_FIELDS else dtype)
        for name in _FAULT_FIELDS})


def stack_fault_ops(fcs: Sequence[FaultConfig],
                    dtype=jnp.float32) -> FaultOps:
    """Stack C scenarios into a ``FaultOps`` with [C]-shaped leaves — the
    config axis of ``sweep_training``, mirroring ``stack_physics`` /
    ``stack_fl_ops``.  There is nothing to reject: every fault knob is an
    operand, so arbitrary scenario mixes share one executable."""
    return FaultOps(**{
        name: jnp.asarray([getattr(fc, name) for fc in fcs],
                          jnp.int32 if name in _INT_FIELDS else dtype)
        for name in _FAULT_FIELDS})


def sample_round_faults(key, fops: FaultOps,
                        n: int) -> Tuple[jax.Array, jax.Array]:
    """Draw one round's per-client fault realization.

    Returns ``(outage, slow)``, both [n] bool: ``outage`` marks clients
    whose channel died this round (→ masked lane), ``slow`` marks compute
    stragglers (→ t_cmp·slowdown, h2·fade).  Probabilities are traced
    operands, so a scenario sweep reuses the executable."""
    k_out, k_slow = jax.random.split(key)
    outage = jax.random.uniform(k_out, (n,)) < fops.p_outage
    slow = jax.random.uniform(k_slow, (n,)) < fops.p_slow
    return outage, slow


def attack_active(fops: FaultOps, poisoned, z_own, z_ref,
                  round_idx) -> jax.Array:
    """Per-client poison gate for this round ([N] bool).

    A flagged attacker poisons iff BOTH adaptive gates pass:
      * reputation gate — its own current selection score ``z_own``
        (Eq. 16, computed pre-round) is at or above ``rep_gate · z_ref``,
        where ``z_ref`` is the population median score.  The RELATIVE
        gate makes the attacker scale-invariant to the deployed scheme's
        weights: it measures its standing against the crowd, not against
        an absolute number it cannot calibrate;
      * duty cycle     — ``round_idx % duty_period < duty_on`` (the round
        index rides the scan carry, so the schedule is trace-safe).
    """
    period = jnp.maximum(fops.duty_period, 1)
    duty = jnp.mod(round_idx, period) < fops.duty_on
    return poisoned & (z_own >= fops.rep_gate * z_ref) & duty


def slowdown_multiplier(fops: FaultOps, slow) -> jax.Array:
    """Achieved-compute-time multiplier per client (1 where not slowed)."""
    one = jnp.ones((), fops.compute_slowdown.dtype)
    return jnp.where(slow, fops.compute_slowdown, one)


def faded_channel(fops: FaultOps, h2, outage, slow) -> jax.Array:
    """Apply the channel fault processes to this round's gains: slowed
    clients fade by ``channel_fade`` (solver-visible), outage lanes drop
    to EXACTLY zero so they sink to the SIC tail under the descending
    sort and stay invisible to every suffix interference sum."""
    dtype = h2.dtype
    h2 = jnp.where(slow, h2 * fops.channel_fade.astype(dtype), h2)
    return jnp.where(outage, jnp.zeros((), dtype), h2)


# ---------------------------------------------------------------------------
# scenario profiles (the attack-vs-defense grid vocabulary)
# ---------------------------------------------------------------------------
def static_attacker(**kw) -> FaultConfig:
    """The legacy always-on label flipper (gates wide open)."""
    return FaultConfig(**kw)


def adaptive_attacker(rep_gate: float = 0.85, **kw) -> FaultConfig:
    """Reputation-aware attacker: poisons only while its own selection
    score stays at/above ``rep_gate ×`` the population median — it turns
    honest after detections sink its PI term below the crowd, waits out
    the reputation recovery, then resumes."""
    return FaultConfig(rep_gate=rep_gate, **kw)


def duty_cycle_attacker(period: int = 4, on: int = 2, **kw) -> FaultConfig:
    """On–off burst attacker: poisons ``on`` rounds out of every
    ``period`` (evades defenses that key on persistent degradation)."""
    return FaultConfig(duty_period=period, duty_on=on, **kw)


def straggler_storm(p_outage: float = 0.25, p_slow: float = 0.5,
                    compute_slowdown: float = 3.0,
                    channel_fade: float = 0.3, **kw) -> FaultConfig:
    """Heavy straggler/dropout weather: frequent outages (masked-lane
    re-solves) plus compute slowdowns and channel fades — the graceful-
    degradation stress scenario."""
    return FaultConfig(p_outage=p_outage, p_slow=p_slow,
                       compute_slowdown=compute_slowdown,
                       channel_fade=channel_fade, **kw)


#: Named attack profiles used by ``benchmarks/robustness_grid.py`` and the
#: dev smoke — poisoned-client placement comes from the DATA (see
#: ``data.federated``); these set the behavioral gates.
ATTACK_PROFILES: Dict[str, FaultConfig] = {
    "static": static_attacker(),
    "adaptive": adaptive_attacker(),
    "duty": duty_cycle_attacker(),
    "storm": straggler_storm(),
}
