"""Stackelberg game between clients (leader, minimize energy E) and the
server (follower, minimize latency T) — paper §IV–V.

Closed-form structure used by ``equilibrium`` (Algorithm 2):

  follower (Theorem 1):  equal DT finish times t_1^S = … = t_N^S = t^S.
      case 1 (server slack):   α_n* = c_n·D̂_n / (t_total·f_S)      (Eq. 26)
      case 2 (server saturated): α_n* = c_n·D̂_n / Σ_m c_m·D̂_m      (Eq. 29)

  leader, decomposed (§V-B):
      v_n* = v_n_max                                               (§V-B-1)
      f_n* = max(f̃_n, f_min),  f̃_n = (1−v_n)·c_n·D_n / A_n        (§V-B-2)
      p_n* via successive Dinkelbach                               (§V-B-3)

Engine layout — ONE compiled program per (scheme, shape), shared by every
parameterization:

  * ``GameConfig``   — the user-facing Table-I record (plain floats,
    hashable).  Only ``dinkelbach_inner`` is a static jit argument; all
    physics floats are lowered to a ``GamePhysics`` pytree of traced
    array operands via ``GameConfig.physics()``, so sweeping bandwidth /
    t_max / model_bits / … re-uses the same XLA executable instead of
    recompiling per point.
  * ``equilibrium``         — single instance, fully jitted ``lax.while_loop``
    Alg.-2 alternation with the best-iterate safeguard carried as arrays.
  * ``batched_equilibrium`` — ``vmap`` over K independent realizations
    ``h2_batch[K, N]``; the K axis is sharded across available devices
    (single-device fallback is a no-op).
  * ``sweep_equilibrium``   — ``vmap`` over a leading config axis ON TOP of
    the K axis: the whole benchmark grid (C config points × K channel
    draws) is one dispatch of one executable.  ``epsilon`` may also vary
    along the config axis (fig6's deviation sweep).
  * OMA-FDMA / OMA-TDMA / random baselines get the same three tiers
    (``oma_allocation`` / ``batched_oma_allocation`` / ``sweep_oma_allocation``
    etc.), so ``fl_round.allocate_batched`` works for every scheme.
  * ``equilibrium_eager``   — the legacy host-side Python loop, kept as the
    numerical reference for tests and the throughput microbench.

``TRACE_COUNTS`` counts actual traces of each jitted entry point (the
Python body only runs when XLA compiles a new specialization), which is
how the recompile-count tests and the benchmark's ``recompiles`` field
prove the zero-mid-sweep-recompile property.

``Allocation`` is registered as a pytree so whole solves can cross
``jit``/``vmap`` boundaries; under ``batched_equilibrium`` every field
gains a leading K axis, under ``sweep_equilibrium`` a [C, K] prefix.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from . import noma
from ..sharding import game_mesh
from .channel import BANDWIDTH_HZ, noise_power
from .dinkelbach import dinkelbach_power
from .sic import SIC_MODES, successive_power_any
# re-exported from .tracking (the historical import site for both)
from .tracking import TRACE_COUNTS, reset_trace_counts

TAU = 2e-28  # effective capacitance coefficient (Table I / [22])


@dataclass(frozen=True)
class GameConfig:
    """Table I simulation parameters (plain floats, hashable).

    The physics fields are NOT static jit arguments: the solvers receive
    them as a traced ``GamePhysics`` pytree (see ``physics()``), so any
    number of distinct parameterizations share one compiled engine.  Only
    ``dinkelbach_inner`` and ``sic_mode`` (algorithm choices, not
    operands) stay static.

    ``sic_mode`` selects the successive-power engine (``repro.core.sic``):
    ``sequential`` (the paper's reverse-scan SIC chain, default) or
    ``blocked`` / ``blocked_interpret`` / ``blocked_pallas`` (Jacobi
    fixed-point sweeps for large N, suffix interference via jnp or the
    Pallas kernel) — every tier (single/batched/sweep, and the FL round)
    reads it off the config.
    """
    bandwidth: float = BANDWIDTH_HZ
    sigma2: float = field(default_factory=noise_power)
    p_min: float = 0.01
    p_max: float = 0.10
    f_min: float = 1.0e9
    f_max: float = 10.0e9
    f_server: float = 100.0e9
    t_max: float = 10.0
    cycles_per_sample: float = 1.0e7          # c_n
    model_bits: float = 1.0e6                 # d_n = 1 Mbit
    tau: float = TAU
    dinkelbach_inner: str = "projected"
    sic_mode: str = "sequential"

    def physics(self, dtype=jnp.float32) -> "GamePhysics":
        """Traced-operand view of the physics fields (scalar leaves)."""
        return GamePhysics(**{name: jnp.asarray(getattr(self, name), dtype)
                              for name in _PHYSICS_FIELDS})


@dataclass(frozen=True)
class GamePhysics:
    """The traced remainder of ``GameConfig``: every field is a JAX array
    operand (scalar per instance; [C] under a config-axis ``vmap``).

    Registered as a pytree so it flows through jit/vmap; attribute names
    mirror ``GameConfig`` so the solver bodies are polymorphic over both
    (the eager reference path passes a ``GameConfig`` directly).
    """
    bandwidth: jax.Array
    sigma2: jax.Array
    p_min: jax.Array
    p_max: jax.Array
    f_min: jax.Array
    f_max: jax.Array
    f_server: jax.Array
    t_max: jax.Array
    cycles_per_sample: jax.Array
    model_bits: jax.Array
    tau: jax.Array


_PHYSICS_FIELDS = tuple(f.name for f in dataclasses.fields(GamePhysics))
jax.tree_util.register_dataclass(GamePhysics, data_fields=_PHYSICS_FIELDS,
                                 meta_fields=())


def stack_physics(configs: Sequence[GameConfig],
                  dtype=jnp.float32) -> GamePhysics:
    """Stack C configs into a GamePhysics with [C]-shaped leaves — the
    leading config axis of ``sweep_equilibrium``.  All configs must agree
    on the static keys ``dinkelbach_inner`` and ``sic_mode``."""
    inners = {c.dinkelbach_inner for c in configs}
    if len(inners) != 1:
        raise ValueError(f"sweep configs mix dinkelbach_inner={inners}; "
                         "the inner solver is static — sweep each separately")
    modes = {c.sic_mode for c in configs}
    if len(modes) != 1:
        raise ValueError(f"sweep configs mix sic_mode={modes}; the SIC "
                         "engine choice is static — sweep each separately")
    return GamePhysics(**{name: jnp.asarray([getattr(c, name)
                                             for c in configs], dtype)
                          for name in _PHYSICS_FIELDS})


# ---------------------------------------------------------------------------
# device sharding — unified mesh layer (see sharding/game_mesh.py)
# ---------------------------------------------------------------------------
# Batched/sweep tiers pad their batch axes to a device multiple
# (edge-replicated lanes, sliced off the outputs by ``_unpad``) and run
# under ``shard_map`` — one independent while_loop per device — instead
# of GSPMD hints, whose global convergence predicate serializes devices.
# ``sharding_layout``/``_shard_axis`` remain as the legacy placement API
# (bench reporting, external callers).
sharding_layout = game_mesh.layout_1d
_shard_axis = game_mesh.put_axis
_CFG, _DRAW = game_mesh.CFG_AXIS, game_mesh.DRAW_AXIS


def _unpad(alloc: "Allocation", *dims: int) -> "Allocation":
    """Slice a batched/sweep ``Allocation``'s leading axes back to the
    caller's logical sizes (no-op when nothing was padded)."""
    if tuple(alloc.v.shape[:len(dims)]) == dims:
        return alloc
    sl = tuple(slice(0, d) for d in dims)
    return jax.tree_util.tree_map(lambda x: x[sl], alloc)


# ---------------------------------------------------------------------------
# per-term physics (paper Eqs. 5–7, 10–11)
# ---------------------------------------------------------------------------
def local_compute_latency(c, v, D, f):
    return c * (1.0 - v) * D / f                                    # Eq. (5)


def local_compute_energy(c, v, D, f, tau=TAU):
    return 0.5 * tau * c * (1.0 - v) * D * f ** 2                   # Eq. (6)


def dt_compute_latency(c, d_hat, alpha, f_server):
    """Eq. (7), grad-safe: the α = 0 lane (masked client, zero DT load)
    must not divide by the 1e-12 clamp inside the live branch — reverse
    mode would scale its cotangent by 1e12 and, composed with an inf
    upstream, NaN.  Double-``where`` keeps the forward value bit-identical
    to ``load / (max(α, 1e-12)·f_server)`` in both regimes."""
    load = c * d_hat
    ok = alpha > 1e-12
    return jnp.where(ok, load / (jnp.where(ok, alpha, 1.0) * f_server),
                     load * 1e12 / f_server)


# ---------------------------------------------------------------------------
# follower: Theorem 1
# ---------------------------------------------------------------------------
def follower_alpha(c, d_hat, t_total, f_server) -> Tuple[jax.Array, jax.Array]:
    """Optimal DT frequency shares.  Returns (alpha [N], t_S scalar).

    The Eq.-26 denominator is guarded: a degenerate cell with zero DT load
    AND zero round latency (every client masked out in a padded serving
    bucket) is 0/0 without the floor, and the NaN would leak into
    ``t_dt``/latency of that lane.

    Both guards are double-``where`` rather than ``max(·, 1e-12)``: the
    clamp is forward-equivalent (``load·1e12`` IS ``load / 1e-12``) but
    reverse-mode through the clamped branch multiplies cotangents by 1e12
    and — through the branch a ``where`` upstream discards — turns any
    inf into NaN.  With the safe denominator in the untaken branch every
    cotangent stays finite (tests/test_grad_edges.py)."""
    load = c * d_hat                                # CPU cycles per client
    den1 = t_total * f_server
    den1_ok = den1 > 1e-12
    alpha_case1 = jnp.where(                                      # Eq. (26)
        den1_ok, load / jnp.where(den1_ok, den1, 1.0), load * 1e12)
    saturated = jnp.sum(alpha_case1) > 1.0
    den2 = jnp.sum(load)
    den2_ok = den2 > 1e-12
    alpha_case2 = jnp.where(                                      # Eq. (29)
        den2_ok, load / jnp.where(den2_ok, den2, 1.0), load * 1e12)
    alpha = jnp.where(saturated, alpha_case2, alpha_case1)
    t_s = jnp.where(saturated, jnp.sum(load) / f_server, t_total)
    return alpha, t_s


# ---------------------------------------------------------------------------
# leader closed forms
# ---------------------------------------------------------------------------
def leader_v(v_max):
    """§V-B-1: map the maximum insensitive fraction."""
    return v_max


def leader_f(c, v, D, a_n, f_min, f_max):
    """§V-B-2: run exactly at the deadline, floor at f_min."""
    f_tilde = c * (1.0 - v) * D / jnp.maximum(a_n, 1e-9)
    return jnp.clip(jnp.maximum(f_tilde, f_min), f_min, f_max)


# ---------------------------------------------------------------------------
# Algorithm 2: joint equilibrium
# ---------------------------------------------------------------------------
@dataclass
class Allocation:
    v: jax.Array
    f: jax.Array
    p: jax.Array
    alpha: jax.Array
    rates: jax.Array
    q: jax.Array           # per-client Dinkelbach optima (rate per energy)
    t_cmp: jax.Array
    t_com: jax.Array
    t_dt: jax.Array
    t_total: jax.Array     # scalar round latency T (Eq. 17)
    energy: jax.Array      # scalar total energy E (Eq. 18)
    e_cmp: jax.Array
    e_com: jax.Array
    iterations: jax.Array | int = 0
    feasible: jax.Array | bool = True   # best iterate met the deadline


_ALLOC_FIELDS = tuple(f.name for f in dataclasses.fields(Allocation))
# pytree registration: every field is a data leaf, so Allocation flows
# through jit/vmap/scan; batched solves stack each field on a leading axis.
jax.tree_util.register_dataclass(Allocation, data_fields=_ALLOC_FIELDS,
                                 meta_fields=())


def round_metrics(cfg, D, v, f, p, h2_sorted, mask=None):
    """Per-client latency/energy terms.  ``cfg`` may be a ``GameConfig``
    (floats — eager paths, tests) or a ``GamePhysics`` (traced).

    ``mask`` (optional [N] bool, a traced operand) marks the REAL clients
    of a padded serving bucket.  Padded lanes carry h2 = 0 so they are
    invisible to the SIC interference chain (p·|h|² = 0 contributes
    nothing to any real client's suffix sum), but their zero rate would
    otherwise surface as a huge ``t_com`` (= d / rate-floor) that poisons
    the round maxima and energy sums — so every per-client term is zeroed
    on masked-out lanes with ``where`` (NOT multiplication: 0·inf = NaN).
    ``mask=None`` compiles the exact pre-existing unmasked program."""
    rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    if mask is not None:
        zero = jnp.zeros((), rates.dtype)
        rates = jnp.where(mask, rates, zero)
        t_com = jnp.where(mask, t_com, zero)
        t_cmp = jnp.where(mask, t_cmp, zero)
        e_cmp = jnp.where(mask, e_cmp, zero)
    e_com = noma.tx_energy(p, t_com)
    return rates, t_cmp, t_com, e_cmp, e_com


def _leader_iteration(cfg, h2_sorted, D, v, f, inner: str,
                      sic_mode: str = "sequential", mask=None):
    """One Alg.-2 leader sweep: p via successive Dinkelbach given the current
    compute times, then f runs to the deadline given the new airtimes.

    Shared verbatim by the eager reference loop and the traced engine so the
    two paths are numerically identical per iteration.  ``inner`` /
    ``sic_mode`` are the static Dinkelbach / SIC-engine choices (the
    non-physics remainder of GameConfig).  ``mask`` (see ``round_metrics``)
    keeps padded-bucket lanes out of the energy sum and the feasibility
    max; the masked lanes' p (pinned at p_max against h2 = 0) never
    perturbs real clients because p·|h|² = 0 in every suffix sum."""
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)        # rate-floor slack
    p, q = successive_power_any(h2_sorted, cfg.model_bits, g_n,
                                cfg.bandwidth, cfg.sigma2, cfg.p_min,
                                cfg.p_max, inner=inner, sic_mode=sic_mode)
    rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    _, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p, h2_sorted,
                                                  mask)
    e_total = jnp.sum(e_cmp + e_com)
    feasible = jnp.max(t_cmp + t_com) <= cfg.t_max + 1e-6
    return f, p, q, e_total, feasible


def _finish(cfg, h2_sorted, D, v, f, p, q, d_hat, iterations,
            feasible, mask=None) -> Allocation:
    """Follower best response to the leader's final strategy (Eq. 17)."""
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p,
                                                      h2_sorted, mask)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _t_s = follower_alpha(cfg.cycles_per_sample, d_hat, t_total,
                                 cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha,
                              cfg.f_server)
    latency = jnp.maximum(t_total, jnp.max(t_dt))          # Eq. (17)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=latency, energy=jnp.sum(e_cmp + e_com),
                      e_cmp=e_cmp, e_com=e_com, iterations=iterations,
                      feasible=feasible)


def _solve(cfg, h2_sorted, D, v_max, epsilon, max_iter: int, tol,
           inner: str = "projected", sic_mode: str = "sequential",
           mask=None) -> Allocation:
    """Traced Alg.-2 alternation: a ``lax.while_loop`` whose carry holds the
    best-iterate safeguard and the convergence flag as arrays.

    The safeguard key is lexicographic (infeasible, energy): Alg-2
    alternation is not guaranteed monotone near infeasible channel draws,
    so we return the lowest-energy deadline-feasible-first iterate —
    same policy as the legacy loop, minus the host syncs.

    ``mask`` ([N] bool operand, default None = all real) is the padded
    serving buckets' ragged-N story: masked lanes must carry h2 = 0 (tail
    of the SIC order) and are erased from d_hat, every latency/energy
    reduction and the feasibility test, so a request solved in a bucket
    with padding is bit-identical to its exact-N solve (asserted in
    tests/test_alloc_serve.py).  ``mask=None`` traces the historical
    unmasked program unchanged.
    """
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    v = leader_v(jnp.broadcast_to(v_max, (n,)).astype(dtype))
    D = jnp.broadcast_to(D, (n,)).astype(dtype)
    d_hat = v * D + epsilon                       # DT-mapped data size
    if mask is not None:
        # padded lanes: no DT load (ε would otherwise leak into the
        # follower's α shares), no insensitive fraction
        zero = jnp.zeros((), dtype)
        v = jnp.where(mask, v, zero)
        d_hat = jnp.where(mask, d_hat, zero)
    f0 = jnp.full((n,), cfg.f_max, dtype)
    p0 = jnp.full((n,), cfg.p_max, dtype)
    q0 = jnp.zeros((n,), dtype)
    inf = jnp.asarray(jnp.inf, dtype)

    def cond(carry):
        *_rest, it, done = carry
        return (~done) & (it < max_iter)

    def body(carry):
        f, p, q, prev_e, bb, be, bf, bp, bq, it, _done = carry
        f, p, q, e, feas = _leader_iteration(cfg, h2_sorted, D, v, f, inner,
                                             sic_mode, mask)
        bad = jnp.where(feas, jnp.asarray(0.0, dtype),
                        jnp.asarray(1.0, dtype))
        # strict lexicographic improvement, matching the legacy tuple compare
        better = (bad < bb) | ((bad == bb) & (e < be))
        bb = jnp.where(better, bad, bb)
        be = jnp.where(better, e, be)
        bf = jnp.where(better, f, bf)
        bp = jnp.where(better, p, bp)
        bq = jnp.where(better, q, bq)
        done = jnp.abs(prev_e - e) < tol * jnp.maximum(e, 1e-12)
        return (f, p, q, e, bb, be, bf, bp, bq, it + 1, done)

    init = (f0, p0, q0, inf,
            jnp.asarray(2.0, dtype), inf, f0, p0, q0,   # best: bad, e, f, p, q
            jnp.asarray(0, jnp.int32), jnp.asarray(False))
    carry = jax.lax.while_loop(cond, body, init)
    _f, _p, _q, _e, bb, _be, bf, bp, bq, it, _done = carry
    return _finish(cfg, h2_sorted, D, v, bf, bp, bq, d_hat, it, bb == 0.0,
                   mask)


@partial(jax.jit, static_argnames=("max_iter", "inner", "sic_mode"))
def _equilibrium_jit(phys, h2_sorted, D, v_max, epsilon, tol, max_iter,
                     inner, sic_mode):
    TRACE_COUNTS["equilibrium"] += 1
    return _solve(phys, h2_sorted, D, v_max, epsilon, max_iter, tol, inner,
                  sic_mode)


@partial(jax.jit, static_argnames=("max_iter", "inner", "sic_mode", "shards"))
def _batched_equilibrium_jit(phys, h2_batch, D_batch, v_max_batch, epsilon,
                             tol, max_iter, inner, sic_mode, shards=1):
    TRACE_COUNTS["batched_equilibrium"] += 1

    def vsolve(ph, h2, d, vm, eps, tl):
        solve1 = lambda hh, dd, vv: _solve(ph, hh, dd, vv, eps, max_iter,
                                           tl, inner, sic_mode)
        return jax.vmap(solve1)(h2, d, vm)

    if shards > 1:
        # one independent while_loop per device over its local K block
        vsolve = shard_map(vsolve, mesh=game_mesh.mesh_1d(shards),
                           in_specs=(P(), P(_DRAW), P(_DRAW), P(_DRAW),
                                     P(), P()),
                           out_specs=P(_DRAW), check_rep=False)
    return vsolve(phys, h2_batch, D_batch, v_max_batch, epsilon, tol)


@partial(jax.jit,
         static_argnames=("max_iter", "inner", "sic_mode", "grid_shards"))
def _sweep_equilibrium_jit(phys, h2_cbn, D_cbn, v_max_cbn, epsilon_c, tol,
                           max_iter, inner, sic_mode, grid_shards=(1, 1)):
    TRACE_COUNTS["sweep_equilibrium"] += 1

    def sweep(ph_c, h2_c, d_c, vm_c, eps_c, tl):
        def solve_config(ph, h2_kn, d_kn, vm_kn, eps):
            solve1 = lambda h2, d, vm: _solve(ph, h2, d, vm, eps, max_iter,
                                              tl, inner, sic_mode)
            return jax.vmap(solve1)(h2_kn, d_kn, vm_kn)

        return jax.vmap(solve_config)(ph_c, h2_c, d_c, vm_c, eps_c)

    dc, dk = grid_shards
    if dc * dk > 1:
        # 2D (cfg, draw) mesh: each device owns a [C/dc, K/dk] grid tile
        sweep = shard_map(sweep, mesh=game_mesh.mesh_2d(dc, dk),
                          in_specs=(P(_CFG), P(_CFG, _DRAW), P(_CFG, _DRAW),
                                    P(_CFG, _DRAW), P(_CFG), P()),
                          out_specs=P(_CFG, _DRAW), check_rep=False)
    return sweep(phys, h2_cbn, D_cbn, v_max_cbn, epsilon_c, tol)


@lru_cache(maxsize=512)
def _physics_cached(cfg: GameConfig, dtype) -> GamePhysics:
    """Per-(config, dtype) device scalars, built once — keeps the
    per-dispatch host overhead of the traced-physics design off the
    per-instance hot path (GameConfig is frozen + hashable)."""
    return cfg.physics(dtype)


@lru_cache(maxsize=4096)
def _scalar_cached(value: float, dtype):
    return jnp.asarray(value, dtype)


def _as_operand(x, dtype):
    """Scalar operand with a cached device buffer for python numbers."""
    if isinstance(x, (int, float)):
        return _scalar_cached(float(x), dtype)
    return jnp.asarray(x, dtype)


def _canon_single(cfg: GameConfig, h2_sorted, D, v_max, epsilon, tol):
    """Normalize one instance's operands to a fixed-dtype signature so
    repeated calls (floats vs arrays, different configs) hit one jit cache
    entry."""
    h2_sorted = jnp.asarray(h2_sorted)
    dtype = jnp.result_type(h2_sorted)
    return (_physics_cached(cfg, dtype), h2_sorted,
            jnp.asarray(D, dtype), jnp.asarray(v_max, dtype),
            _as_operand(epsilon, dtype), _as_operand(tol, dtype))


def _canon_batch(cfg: GameConfig, h2_batch, D_batch, v_max_batch, epsilon,
                 tol, shard: bool = True):
    """Normalize batched operands to [K, N] and, on multi-device
    processes, pad K to a device multiple + place the shards.  Returns
    the operands plus ``(shards, k)`` so the entry point can pick the
    shard_map specialization and ``_unpad`` the result."""
    h2_batch = jnp.asarray(h2_batch)
    dtype = jnp.result_type(h2_batch)
    k, n = h2_batch.shape
    D_batch = jnp.broadcast_to(jnp.asarray(D_batch, dtype), (k, n))
    v_max_batch = jnp.broadcast_to(jnp.asarray(v_max_batch, dtype), (k, n))
    shards = game_mesh.batch_shards(k) if shard else 1
    if shards > 1:
        kp = game_mesh.padded_size(k, shards)
        h2_batch, D_batch, v_max_batch = game_mesh.put_batch(
            tuple(game_mesh.pad_axis(a, 0, kp)
                  for a in (h2_batch, D_batch, v_max_batch)),
            axis=0, shards=shards)
    return (_physics_cached(cfg, dtype), h2_batch, D_batch, v_max_batch,
            _as_operand(epsilon, dtype), _as_operand(tol, dtype), shards, k)


def _canon_sweep(configs: Sequence[GameConfig], h2_batch, D, v_max, epsilon,
                 tol, shard: bool = True):
    """[C]-stack the configs and broadcast operands to [C, K, N]; epsilon
    may be scalar or [C] (it rides the config axis — fig6's ε sweep).
    On multi-device processes the C×K grid is padded to the 2D mesh
    factorization and placed; returns extra ``(grid_shards, c, k)`` for
    the shard_map specialization + output ``_unpad``."""
    configs = list(configs)
    c = len(configs)
    h2_batch = jnp.asarray(h2_batch)
    dtype = jnp.result_type(h2_batch)
    if h2_batch.ndim == 2:
        h2_batch = jnp.broadcast_to(h2_batch, (c,) + h2_batch.shape)
    _, k, n = h2_batch.shape
    D = jnp.broadcast_to(jnp.asarray(D, dtype), (c, k, n))
    v_max = jnp.broadcast_to(jnp.asarray(v_max, dtype), (c, k, n))
    eps = jnp.broadcast_to(jnp.asarray(epsilon, dtype), (c,))
    phys = stack_physics(configs, dtype)
    grid = game_mesh.grid_layout(c, k) if shard else (1, 1)
    dc, dk = grid
    if dc * dk > 1:
        cp = game_mesh.padded_size(c, dc)
        kp = game_mesh.padded_size(k, dk)
        h2_batch, D, v_max = game_mesh.put_grid(
            tuple(game_mesh.pad_axis(game_mesh.pad_axis(a, 0, cp), 1, kp)
                  for a in (h2_batch, D, v_max)), grid)
        eps = game_mesh.put_grid_tree(game_mesh.pad_axis(eps, 0, cp), grid,
                                      cfg_only=True)
        phys = game_mesh.put_grid_tree(game_mesh.pad_tree(phys, 0, cp), grid,
                                       cfg_only=True)
    return (phys, h2_batch, D, v_max, eps, jnp.asarray(tol, dtype),
            configs[0].dinkelbach_inner, grid, c, k)


def equilibrium(cfg: GameConfig, h2_sorted, D, v_max, epsilon: float = 0.0,
                max_iter: int = 20, tol: float = 1e-6) -> Allocation:
    """Algorithm 2 — alternate leader/follower best responses to the
    Stackelberg equilibrium, compiled to a single XLA program shared by
    every physics parameterization (only ``dinkelbach_inner`` and the
    shapes specialize the compile).  Inputs sorted by descending channel
    gain.

    h2_sorted : [N] channel power gains (SIC order)
    D         : [N] client data sizes (samples)
    v_max     : [N] max insensitive-data fractions
    """
    phys, h2, D, v_max, eps, tol = _canon_single(cfg, h2_sorted, D, v_max,
                                                 epsilon, tol)
    return _equilibrium_jit(phys, h2, D, v_max, eps, tol, max_iter=max_iter,
                            inner=cfg.dinkelbach_inner,
                            sic_mode=cfg.sic_mode)


# NOTE: the batched/sweep tiers below all run their batch axes through
# ``_canon_batch``/``_canon_sweep``, which pad to a device multiple on
# multi-device processes — every entry point therefore ``_unpad``s its
# result back to the caller's logical shape.


def batched_equilibrium(cfg: GameConfig, h2_batch, D_batch, v_max_batch,
                        epsilon: float = 0.0, max_iter: int = 20,
                        tol: float = 1e-6) -> Allocation:
    """Solve K independent network realizations in ONE XLA call.

    h2_batch    : [K, N] channel power gains, each row in SIC order
    D_batch     : [K, N] or [N] client data sizes (broadcast across K)
    v_max_batch : [K, N] or [N] max insensitive-data fractions

    Returns an ``Allocation`` whose every field carries a leading K axis
    (scalars such as ``energy`` become [K]).  This is the Monte-Carlo
    entry point: thousands of channel draws per benchmark point amortize
    to one compile + one device dispatch, and the K axis is sharded
    across available devices (no-op on one device).
    """
    phys, h2, D, vm, eps, tol, shards, k = _canon_batch(
        cfg, h2_batch, D_batch, v_max_batch, epsilon, tol)
    out = _batched_equilibrium_jit(phys, h2, D, vm, eps, tol,
                                   max_iter=max_iter,
                                   inner=cfg.dinkelbach_inner,
                                   sic_mode=cfg.sic_mode, shards=shards)
    return _unpad(out, k)


def sweep_equilibrium(configs: Sequence[GameConfig], h2_batch, D, v_max,
                      epsilon=0.0, max_iter: int = 20,
                      tol: float = 1e-6) -> Allocation:
    """Solve a whole benchmark grid — C config points × K channel draws —
    in ONE XLA call of ONE executable (zero mid-sweep recompiles).

    configs  : C ``GameConfig`` points (same ``dinkelbach_inner``); their
               physics floats are stacked into a [C]-leaved ``GamePhysics``
               and vmapped over, so distinct t_max / model_bits / bandwidth
               values are array rows, not compile keys.
    h2_batch : [K, N] (shared across configs) or [C, K, N]
    D, v_max : broadcastable to [C, K, N]
    epsilon  : scalar, or [C] to sweep the DT deviation along the config axis

    Returns an ``Allocation`` with a [C, K] leading prefix on every field.
    """
    configs = list(configs)
    phys, h2, D, vm, eps, tol, inner, grid, c, k = _canon_sweep(
        configs, h2_batch, D, v_max, epsilon, tol)
    out = _sweep_equilibrium_jit(phys, h2, D, vm, eps, tol,
                                 max_iter=max_iter, inner=inner,
                                 sic_mode=configs[0].sic_mode,
                                 grid_shards=grid)
    return _unpad(out, c, k)


def equilibrium_eager(cfg: GameConfig, h2_sorted, D, v_max,
                      epsilon: float = 0.0, max_iter: int = 20,
                      tol: float = 1e-6) -> Allocation:
    """Legacy Algorithm 2: host-side Python loop with per-iteration
    ``float()``/``bool()`` device syncs.  Kept as the numerical reference
    for the jitted engine (tests) and as the baseline of
    ``benchmarks/equilibrium_throughput.py``.  Not jit/vmap-able.
    """
    h2_sorted = jnp.asarray(h2_sorted)
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    v = leader_v(jnp.broadcast_to(v_max, (n,)).astype(dtype))
    f = jnp.full((n,), cfg.f_max, dtype)
    p = jnp.full((n,), cfg.p_max, dtype)
    q = jnp.zeros((n,), dtype)
    d_hat = v * jnp.asarray(D, dtype) + epsilon   # DT-mapped data size

    prev_e = jnp.inf
    it = 0
    best = None   # best-iterate safeguard (see _solve)
    for it in range(1, max_iter + 1):
        f, p, q, e_total, feas = _leader_iteration(cfg, h2_sorted, D, v, f,
                                                   cfg.dinkelbach_inner,
                                                   cfg.sic_mode)
        cand = (not bool(feas), float(e_total), (f, p, q))
        if best is None or cand[:2] < best[:2]:
            best = cand
        if jnp.abs(prev_e - e_total) < tol * jnp.maximum(e_total, 1e-12):
            break
        prev_e = e_total
    f, p, q = best[2]
    return _finish(cfg, h2_sorted, D, v, f, p, q, d_hat, it,
                   jnp.asarray(not best[0]))


# ---------------------------------------------------------------------------
# baselines for Fig. 9 — same three-tier layout (single / batched / sweep)
# ---------------------------------------------------------------------------
def _random_body(cfg, key, h2_sorted, D, v_max, epsilon,
                 mask=None) -> Allocation:
    """Random resource allocation baseline (same selection, random p/f/v).
    Traced body shared by the single/batched/sweep entry points and (with
    ``mask``) the padded serving buckets — note the random draws are
    bucket-shaped, so unlike the deterministic schemes a padded solve is
    distributionally, not bitwise, equivalent to the exact-N one."""
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    k1, k2, k3 = jax.random.split(key, 3)
    v = jax.random.uniform(k1, (n,), dtype) * jnp.broadcast_to(
        v_max, (n,)).astype(dtype)
    f = cfg.f_min + jax.random.uniform(k2, (n,), dtype) * (cfg.f_max -
                                                           cfg.f_min)
    p = cfg.p_min + jax.random.uniform(k3, (n,), dtype) * (cfg.p_max -
                                                           cfg.p_min)
    D = jnp.broadcast_to(D, (n,)).astype(dtype)
    d_hat = v * D + epsilon
    if mask is not None:
        zero = jnp.zeros((), dtype)
        v = jnp.where(mask, v, zero)
        d_hat = jnp.where(mask, d_hat, zero)
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p,
                                                      h2_sorted, mask)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total,
                              cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha,
                              cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates,
                      q=jnp.zeros((n,), dtype), t_cmp=t_cmp, t_com=t_com,
                      t_dt=t_dt, t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com,
                      iterations=jnp.asarray(0, jnp.int32),
                      feasible=t_total <= cfg.t_max + 1e-6)


def _oma_body(cfg, h2_sorted, D, v_max, epsilon, inner: str,
              tdma: bool, mask=None) -> Allocation:
    """OMA baseline body — FDMA (B/N sub-bands) or TDMA (sequential
    full-band slots), fully traced: the per-client Dinkelbach solves are a
    client-axis ``vmap`` instead of a host loop, so the whole baseline
    jits/vmaps like the proposed engine.

    With ``mask`` the orthogonal split is over the REAL client count
    Σmask, not the padded bucket width — unlike NOMA (where zero-gain
    padding is invisible by construction), OMA's per-client bandwidth /
    slot share depends on N directly, so a padded solve would otherwise
    hand every real client a thinner sub-band than its exact-N solve."""
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    v = leader_v(jnp.broadcast_to(v_max, (n,)).astype(dtype))
    D = jnp.broadcast_to(D, (n,)).astype(dtype)
    f = jnp.full((n,), cfg.f_max, dtype)
    d_hat = v * D + epsilon
    if mask is not None:
        zero = jnp.zeros((), dtype)
        v = jnp.where(mask, v, zero)
        d_hat = jnp.where(mask, d_hat, zero)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    # real-client count: the orthogonal resource divisor (== n unmasked)
    n_eff = n if mask is None else jnp.maximum(
        jnp.sum(mask.astype(dtype)), jnp.ones((), dtype))
    if tdma:
        # per-client slot budget: (Tmax − t_cmp)/N, full band per slot
        g_n = jnp.maximum((cfg.t_max - t_cmp) / n_eff, 1e-3)
        bw, s2 = cfg.bandwidth, cfg.sigma2
    else:
        g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)
        bw, s2 = cfg.bandwidth / n_eff, cfg.sigma2 / n_eff

    def solve(h2_n, g_nn):
        p_n, q_n, _ = dinkelbach_power(cfg.model_bits, g_nn, h2_n / s2, bw,
                                       cfg.p_min, cfg.p_max, inner=inner)
        return p_n, q_n

    p, q = jax.vmap(solve)(h2_sorted, g_n)
    if tdma:
        rates = cfg.bandwidth * jnp.log2(1.0 + p * h2_sorted / cfg.sigma2)
        t_own = noma.tx_latency(cfg.model_bits, rates)  # own-slot airtime
        if mask is not None:
            t_own = jnp.where(mask, t_own, jnp.zeros((), dtype))
        t_com = jnp.sum(t_own) * jnp.ones_like(t_own)   # sequential round
    else:
        rates = bw * jnp.log2(1.0 + p * h2_sorted / s2)  # == oma_rates @ n_eff
        t_own = t_com = noma.tx_latency(cfg.model_bits, rates)
        if mask is not None:
            t_own = t_com = jnp.where(mask, t_own, jnp.zeros((), dtype))
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_own)                    # energy over own slot
    if mask is not None:
        zero = jnp.zeros((), dtype)
        rates = jnp.where(mask, rates, zero)
        t_cmp = jnp.where(mask, t_cmp, zero)
        e_cmp = jnp.where(mask, e_cmp, zero)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total,
                              cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha,
                              cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com,
                      iterations=jnp.asarray(0, jnp.int32),
                      feasible=t_total <= cfg.t_max + 1e-6)


@partial(jax.jit, static_argnames=("inner",))
def _random_jit(phys, key, h2, D, v_max, epsilon, inner):
    del inner  # random draws never run Dinkelbach; kept for signature parity
    TRACE_COUNTS["random_allocation"] += 1
    return _random_body(phys, key, h2, D, v_max, epsilon)


@partial(jax.jit, static_argnames=("inner", "shards"))
def _batched_random_jit(phys, keys, h2, D, v_max, epsilon, inner, shards=1):
    del inner
    TRACE_COUNTS["batched_random_allocation"] += 1

    def vbody(ph, kk, h2_b, d_b, vm_b, eps):
        body = lambda k1, h, d, vm: _random_body(ph, k1, h, d, vm, eps)
        return jax.vmap(body)(kk, h2_b, d_b, vm_b)

    if shards > 1:
        vbody = shard_map(vbody, mesh=game_mesh.mesh_1d(shards),
                          in_specs=(P(), P(_DRAW), P(_DRAW), P(_DRAW),
                                    P(_DRAW), P()),
                          out_specs=P(_DRAW), check_rep=False)
    return vbody(phys, keys, h2, D, v_max, epsilon)


@partial(jax.jit, static_argnames=("inner", "grid_shards"))
def _sweep_random_jit(phys, keys, h2, D, v_max, epsilon_c, inner,
                      grid_shards=(1, 1)):
    del inner
    TRACE_COUNTS["sweep_random_allocation"] += 1

    def sweep(ph_c, kk, h2_c, d_c, vm_c, eps_c):
        def per_config(ph, h_kn, d_kn, vm_kn, eps):
            body = lambda k1, h, d, vm: _random_body(ph, k1, h, d, vm, eps)
            return jax.vmap(body)(kk, h_kn, d_kn, vm_kn)

        # keys are shared across the config axis (in_axes=None): every
        # config point sees the same K channel/key draws, isolating the
        # config effect (a draw-axis device tile still sees the same key
        # block for each of its config rows)
        return jax.vmap(per_config)(ph_c, h2_c, d_c, vm_c, eps_c)

    dc, dk = grid_shards
    if dc * dk > 1:
        sweep = shard_map(sweep, mesh=game_mesh.mesh_2d(dc, dk),
                          in_specs=(P(_CFG), P(_DRAW), P(_CFG, _DRAW),
                                    P(_CFG, _DRAW), P(_CFG, _DRAW), P(_CFG)),
                          out_specs=P(_CFG, _DRAW), check_rep=False)
    return sweep(phys, keys, h2, D, v_max, epsilon_c)


def _oma_variant(tdma: bool) -> str:
    """TRACE_COUNTS key suffix: FDMA and TDMA are distinct static
    specializations, so they must not share a recompile counter."""
    return "oma_tdma_allocation" if tdma else "oma_allocation"


@partial(jax.jit, static_argnames=("inner", "tdma"))
def _oma_jit(phys, h2, D, v_max, epsilon, inner, tdma):
    TRACE_COUNTS[_oma_variant(tdma)] += 1
    return _oma_body(phys, h2, D, v_max, epsilon, inner, tdma)


@partial(jax.jit, static_argnames=("inner", "tdma", "shards"))
def _batched_oma_jit(phys, h2, D, v_max, epsilon, inner, tdma, shards=1):
    TRACE_COUNTS["batched_" + _oma_variant(tdma)] += 1

    def vbody(ph, h2_b, d_b, vm_b, eps):
        body = lambda h, d, vm: _oma_body(ph, h, d, vm, eps, inner, tdma)
        return jax.vmap(body)(h2_b, d_b, vm_b)

    if shards > 1:
        vbody = shard_map(vbody, mesh=game_mesh.mesh_1d(shards),
                          in_specs=(P(), P(_DRAW), P(_DRAW), P(_DRAW), P()),
                          out_specs=P(_DRAW), check_rep=False)
    return vbody(phys, h2, D, v_max, epsilon)


@partial(jax.jit, static_argnames=("inner", "tdma", "grid_shards"))
def _sweep_oma_jit(phys, h2, D, v_max, epsilon_c, inner, tdma,
                   grid_shards=(1, 1)):
    TRACE_COUNTS["sweep_" + _oma_variant(tdma)] += 1

    def sweep(ph_c, h2_c, d_c, vm_c, eps_c):
        def per_config(ph, h_kn, d_kn, vm_kn, eps):
            body = lambda h, d, vm: _oma_body(ph, h, d, vm, eps, inner, tdma)
            return jax.vmap(body)(h_kn, d_kn, vm_kn)

        return jax.vmap(per_config)(ph_c, h2_c, d_c, vm_c, eps_c)

    dc, dk = grid_shards
    if dc * dk > 1:
        sweep = shard_map(sweep, mesh=game_mesh.mesh_2d(dc, dk),
                          in_specs=(P(_CFG), P(_CFG, _DRAW), P(_CFG, _DRAW),
                                    P(_CFG, _DRAW), P(_CFG)),
                          out_specs=P(_CFG, _DRAW), check_rep=False)
    return sweep(phys, h2, D, v_max, epsilon_c)


def random_allocation(cfg: GameConfig, key, h2_sorted, D, v_max,
                      epsilon: float = 0.0) -> Allocation:
    """Random resource allocation baseline (same selection, random p/f/v)."""
    phys, h2, D, vm, eps, _ = _canon_single(cfg, h2_sorted, D, v_max,
                                            epsilon, 0.0)
    return _random_jit(phys, key, h2, D, vm, eps, inner=cfg.dinkelbach_inner)


def batched_random_allocation(cfg: GameConfig, key, h2_batch, D_batch,
                              v_max_batch, epsilon: float = 0.0) -> Allocation:
    """K random allocations in one XLA call; per-draw keys are
    ``jax.random.split(key, K)``, so row i reproduces
    ``random_allocation(cfg, jax.random.split(key, K)[i], …)`` exactly."""
    phys, h2, D, vm, eps, _, shards, k = _canon_batch(
        cfg, h2_batch, D_batch, v_max_batch, epsilon, 0.0)
    # split with the LOGICAL k (row i must reproduce the documented
    # per-instance key exactly), then pad keys to the device multiple
    keys = game_mesh.pad_axis(jax.random.split(key, k), 0, h2.shape[0])
    out = _batched_random_jit(phys, keys, h2, D, vm, eps,
                              inner=cfg.dinkelbach_inner, shards=shards)
    return _unpad(out, k)


def sweep_random_allocation(configs: Sequence[GameConfig], key, h2_batch, D,
                            v_max, epsilon=0.0) -> Allocation:
    """C configs × K draws of the random baseline in one call.  The K
    per-draw keys are shared across the config axis (each config point sees
    identical randomness, isolating the config effect)."""
    phys, h2, D, vm, eps, _, inner, grid, c, k = _canon_sweep(
        configs, h2_batch, D, v_max, epsilon, 0.0)
    keys = game_mesh.pad_axis(jax.random.split(key, k), 0, h2.shape[1])
    out = _sweep_random_jit(phys, keys, h2, D, vm, eps, inner=inner,
                            grid_shards=grid)
    return _unpad(out, c, k)


def oma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                   epsilon: float = 0.0) -> Allocation:
    """OMA baseline (default): FDMA — each client gets a B/N sub-band.

    Bandwidth-limited: at the paper's operating load (d_n ≥ 1 Mbit) the B/N
    sub-bands force long transmissions / higher power, reproducing the
    Fig. 9 OMA penalty.  (At very light load OMA is within ~2% of NOMA —
    regime note in EXPERIMENTS.md §Paper-validation.)"""
    phys, h2, D, vm, eps, _ = _canon_single(cfg, h2_sorted, D, v_max,
                                            epsilon, 0.0)
    return _oma_jit(phys, h2, D, vm, eps, inner=cfg.dinkelbach_inner,
                    tdma=False)


def batched_oma_allocation(cfg: GameConfig, h2_batch, D_batch, v_max_batch,
                           epsilon: float = 0.0) -> Allocation:
    """K OMA-FDMA allocations in one XLA call (K axis device-sharded)."""
    phys, h2, D, vm, eps, _, shards, k = _canon_batch(
        cfg, h2_batch, D_batch, v_max_batch, epsilon, 0.0)
    out = _batched_oma_jit(phys, h2, D, vm, eps, inner=cfg.dinkelbach_inner,
                           tdma=False, shards=shards)
    return _unpad(out, k)


def sweep_oma_allocation(configs: Sequence[GameConfig], h2_batch, D, v_max,
                         epsilon=0.0) -> Allocation:
    """C configs × K draws of the OMA-FDMA baseline in one call."""
    phys, h2, D, vm, eps, _, inner, grid, c, k = _canon_sweep(
        configs, h2_batch, D, v_max, epsilon, 0.0)
    out = _sweep_oma_jit(phys, h2, D, vm, eps, inner=inner, tdma=False,
                         grid_shards=grid)
    return _unpad(out, c, k)


def oma_tdma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                        epsilon: float = 0.0) -> Allocation:
    """OMA variant: TDMA — sequential full-band slots (round latency Σ t_n,
    the paper's "insufficient clients per round" mechanism)."""
    phys, h2, D, vm, eps, _ = _canon_single(cfg, h2_sorted, D, v_max,
                                            epsilon, 0.0)
    return _oma_jit(phys, h2, D, vm, eps, inner=cfg.dinkelbach_inner,
                    tdma=True)


def batched_oma_tdma_allocation(cfg: GameConfig, h2_batch, D_batch,
                                v_max_batch,
                                epsilon: float = 0.0) -> Allocation:
    """K OMA-TDMA allocations in one XLA call (K axis device-sharded)."""
    phys, h2, D, vm, eps, _, shards, k = _canon_batch(
        cfg, h2_batch, D_batch, v_max_batch, epsilon, 0.0)
    out = _batched_oma_jit(phys, h2, D, vm, eps, inner=cfg.dinkelbach_inner,
                           tdma=True, shards=shards)
    return _unpad(out, k)


def sweep_oma_tdma_allocation(configs: Sequence[GameConfig], h2_batch, D,
                              v_max, epsilon=0.0) -> Allocation:
    """C configs × K draws of the OMA-TDMA baseline in one call."""
    phys, h2, D, vm, eps, _, inner, grid, c, k = _canon_sweep(
        configs, h2_batch, D, v_max, epsilon, 0.0)
    out = _sweep_oma_jit(phys, h2, D, vm, eps, inner=inner, tdma=True,
                         grid_shards=grid)
    return _unpad(out, c, k)


def wo_dt_allocation(cfg: GameConfig, h2_sorted, D) -> Allocation:
    """W/O-DT baseline: v ≡ 0, all training on-client (straggler-exposed).

    Routed through the jitted engine (zero v_max shares the same XLA
    program as the proposed scheme — no extra compile)."""
    h2_sorted = jnp.asarray(h2_sorted)
    zero_vmax = jnp.zeros(h2_sorted.shape, jnp.result_type(h2_sorted))
    return equilibrium(cfg, h2_sorted, D, zero_vmax, epsilon=0.0)


def batched_wo_dt_allocation(cfg: GameConfig, h2_batch, D_batch) -> Allocation:
    """Batched W/O-DT: K realizations with v ≡ 0 in one XLA call."""
    h2_batch = jnp.asarray(h2_batch)
    return batched_equilibrium(cfg, h2_batch, D_batch,
                               jnp.zeros_like(h2_batch), epsilon=0.0)


def sweep_wo_dt_allocation(configs: Sequence[GameConfig], h2_batch,
                           D) -> Allocation:
    """C configs × K draws of the W/O-DT scheme (shares the sweep engine)."""
    h2_batch = jnp.asarray(h2_batch)
    zeros = jnp.zeros(h2_batch.shape[-2:], jnp.result_type(h2_batch))
    return sweep_equilibrium(configs, h2_batch, D, zeros, epsilon=0.0)
