"""Stackelberg game between clients (leader, minimize energy E) and the
server (follower, minimize latency T) — paper §IV–V.

Closed-form structure used by ``equilibrium`` (Algorithm 2):

  follower (Theorem 1):  equal DT finish times t_1^S = … = t_N^S = t^S.
      case 1 (server slack):   α_n* = c_n·D̂_n / (t_total·f_S)      (Eq. 26)
      case 2 (server saturated): α_n* = c_n·D̂_n / Σ_m c_m·D̂_m      (Eq. 29)

  leader, decomposed (§V-B):
      v_n* = v_n_max                                               (§V-B-1)
      f_n* = max(f̃_n, f_min),  f̃_n = (1−v_n)·c_n·D_n / A_n        (§V-B-2)
      p_n* via successive Dinkelbach                               (§V-B-3)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp

from . import noma
from .channel import BANDWIDTH_HZ, noise_power
from .dinkelbach import successive_power

TAU = 2e-28  # effective capacitance coefficient (Table I / [22])


@dataclass(frozen=True)
class GameConfig:
    """Table I simulation parameters."""
    bandwidth: float = BANDWIDTH_HZ
    sigma2: float = field(default_factory=noise_power)
    p_min: float = 0.01
    p_max: float = 0.10
    f_min: float = 1.0e9
    f_max: float = 10.0e9
    f_server: float = 100.0e9
    t_max: float = 10.0
    cycles_per_sample: float = 1.0e7          # c_n
    model_bits: float = 1.0e6                 # d_n = 1 Mbit
    tau: float = TAU
    dinkelbach_inner: str = "projected"


# ---------------------------------------------------------------------------
# per-term physics (paper Eqs. 5–7, 10–11)
# ---------------------------------------------------------------------------
def local_compute_latency(c, v, D, f):
    return c * (1.0 - v) * D / f                                    # Eq. (5)


def local_compute_energy(c, v, D, f, tau: float = TAU):
    return 0.5 * tau * c * (1.0 - v) * D * f ** 2                   # Eq. (6)


def dt_compute_latency(c, d_hat, alpha, f_server):
    return c * d_hat / (jnp.maximum(alpha, 1e-12) * f_server)       # Eq. (7)


# ---------------------------------------------------------------------------
# follower: Theorem 1
# ---------------------------------------------------------------------------
def follower_alpha(c, d_hat, t_total, f_server) -> Tuple[jax.Array, jax.Array]:
    """Optimal DT frequency shares.  Returns (alpha [N], t_S scalar)."""
    load = c * d_hat                                # CPU cycles per client
    alpha_case1 = load / (t_total * f_server)       # Eq. (26)
    saturated = jnp.sum(alpha_case1) > 1.0
    alpha_case2 = load / jnp.maximum(jnp.sum(load), 1e-12)   # Eq. (29)
    alpha = jnp.where(saturated, alpha_case2, alpha_case1)
    t_s = jnp.where(saturated, jnp.sum(load) / f_server, t_total)
    return alpha, t_s


# ---------------------------------------------------------------------------
# leader closed forms
# ---------------------------------------------------------------------------
def leader_v(v_max):
    """§V-B-1: map the maximum insensitive fraction."""
    return v_max


def leader_f(c, v, D, a_n, f_min, f_max):
    """§V-B-2: run exactly at the deadline, floor at f_min."""
    f_tilde = c * (1.0 - v) * D / jnp.maximum(a_n, 1e-9)
    return jnp.clip(jnp.maximum(f_tilde, f_min), f_min, f_max)


# ---------------------------------------------------------------------------
# Algorithm 2: joint equilibrium
# ---------------------------------------------------------------------------
@dataclass
class Allocation:
    v: jax.Array
    f: jax.Array
    p: jax.Array
    alpha: jax.Array
    rates: jax.Array
    q: jax.Array           # per-client Dinkelbach optima (rate per energy)
    t_cmp: jax.Array
    t_com: jax.Array
    t_dt: jax.Array
    t_total: jax.Array     # scalar round latency T (Eq. 17)
    energy: jax.Array      # scalar total energy E (Eq. 18)
    e_cmp: jax.Array
    e_com: jax.Array
    iterations: int = 0


def round_metrics(cfg: GameConfig, D, v, f, p, h2_sorted):
    rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_com)
    return rates, t_cmp, t_com, e_cmp, e_com


def equilibrium(cfg: GameConfig, h2_sorted, D, v_max, epsilon: float = 0.0,
                max_iter: int = 20, tol: float = 1e-6) -> Allocation:
    """Algorithm 2 — alternate leader/follower best responses to the
    Stackelberg equilibrium.  Inputs sorted by descending channel gain.

    h2_sorted : [N] channel power gains (SIC order)
    D         : [N] client data sizes (samples)
    v_max     : [N] max insensitive-data fractions
    """
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    p = jnp.full((n,), cfg.p_max)
    d_hat = v * D + epsilon                       # DT-mapped data size

    prev_e = jnp.inf
    it = 0
    q = jnp.zeros((n,))
    best = None   # best-iterate safeguard: Alg-2 alternation is not
    #               guaranteed monotone near infeasible channel draws, so we
    #               return the lowest-energy (deadline-feasible-first) iterate
    for it in range(1, max_iter + 1):
        # leader: power via successive Dinkelbach given current compute times
        t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
        g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)        # rate-floor slack
        p, q = successive_power(h2_sorted, cfg.model_bits, g_n, cfg.bandwidth,
                                cfg.sigma2, cfg.p_min, cfg.p_max,
                                inner=cfg.dinkelbach_inner)
        rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
        t_com = noma.tx_latency(cfg.model_bits, rates)
        # leader: frequency runs exactly to the deadline
        a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
        f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
        rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p,
                                                          h2_sorted)
        e_total = jnp.sum(e_cmp + e_com)
        feasible = bool(jnp.max(t_cmp + t_com) <= cfg.t_max + 1e-6)
        cand = (not feasible, float(e_total), (v, f, p, q))
        if best is None or cand[:2] < best[:2]:
            best = cand
        if jnp.abs(prev_e - e_total) < tol * jnp.maximum(e_total, 1e-12):
            break
        prev_e = e_total
    v, f, p, q = best[2]
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p,
                                                      h2_sorted)

    # follower best response to the leader's final strategy
    t_total_n = t_cmp + t_com
    t_total = jnp.max(t_total_n)
    alpha, t_s = follower_alpha(cfg.cycles_per_sample, d_hat, t_total,
                                cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha,
                              cfg.f_server)
    latency = jnp.maximum(t_total, jnp.max(t_dt))          # Eq. (17)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=latency, energy=jnp.sum(e_cmp + e_com),
                      e_cmp=e_cmp, e_com=e_com, iterations=it)


# ---------------------------------------------------------------------------
# baselines for Fig. 9
# ---------------------------------------------------------------------------
def random_allocation(cfg: GameConfig, key, h2_sorted, D, v_max,
                      epsilon: float = 0.0) -> Allocation:
    """Random resource allocation baseline (same selection, random p/f/v)."""
    n = h2_sorted.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    v = jax.random.uniform(k1, (n,)) * v_max
    f = cfg.f_min + jax.random.uniform(k2, (n,)) * (cfg.f_max - cfg.f_min)
    p = cfg.p_min + jax.random.uniform(k3, (n,)) * (cfg.p_max - cfg.p_min)
    d_hat = v * D + epsilon
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p, h2_sorted)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates,
                      q=jnp.zeros((n,)), t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com)


def oma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                   epsilon: float = 0.0) -> Allocation:
    """OMA baseline (default): FDMA — each client gets a B/N sub-band.

    Bandwidth-limited: at the paper's operating load (d_n ≥ 1 Mbit) the B/N
    sub-bands force long transmissions / higher power, reproducing the
    Fig. 9 OMA penalty.  (At very light load OMA is within ~2% of NOMA —
    regime note in EXPERIMENTS.md §Paper-validation.)"""
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    d_hat = v * D + epsilon
    bw, s2 = cfg.bandwidth / n, cfg.sigma2 / n
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)
    from .dinkelbach import dinkelbach_power
    def solve(h2_n, g_nn):
        p_n, q_n, _ = dinkelbach_power(cfg.model_bits, g_nn, h2_n / s2, bw,
                                       cfg.p_min, cfg.p_max,
                                       inner=cfg.dinkelbach_inner)
        return p_n, q_n
    p, q = jax.vmap(solve)(h2_sorted, g_n)
    rates = noma.oma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_com)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com)


def oma_tdma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                        epsilon: float = 0.0) -> Allocation:
    """OMA variant: TDMA — sequential full-band slots (round latency Σ t_n,
    the paper's "insufficient clients per round" mechanism)."""
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    d_hat = v * D + epsilon
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    # per-client slot budget: (Tmax − t_cmp)/N
    g_n = jnp.maximum((cfg.t_max - t_cmp) / n, 1e-3)
    from .dinkelbach import dinkelbach_power
    def solve(h2_n, g_nn):
        p_n, q_n, _ = dinkelbach_power(cfg.model_bits, g_nn,
                                       h2_n / cfg.sigma2, cfg.bandwidth,
                                       cfg.p_min, cfg.p_max,
                                       inner=cfg.dinkelbach_inner)
        return p_n, q_n
    p, q = jax.vmap(solve)(h2_sorted, g_n)
    rates = cfg.bandwidth * jnp.log2(1.0 + p * h2_sorted / cfg.sigma2)
    t_own = noma.tx_latency(cfg.model_bits, rates)     # own-slot airtime
    t_com = jnp.sum(t_own) * jnp.ones_like(t_own)      # sequential round time
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_own)                   # energy over own slot
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com)


def wo_dt_allocation(cfg: GameConfig, h2_sorted, D) -> Allocation:
    """W/O-DT baseline: v ≡ 0, all training on-client (straggler-exposed)."""
    n = h2_sorted.shape[0]
    zero_vmax = jnp.zeros((n,))
    return equilibrium(cfg, h2_sorted, D, zero_vmax, epsilon=0.0)
