"""Stackelberg game between clients (leader, minimize energy E) and the
server (follower, minimize latency T) — paper §IV–V.

Closed-form structure used by ``equilibrium`` (Algorithm 2):

  follower (Theorem 1):  equal DT finish times t_1^S = … = t_N^S = t^S.
      case 1 (server slack):   α_n* = c_n·D̂_n / (t_total·f_S)      (Eq. 26)
      case 2 (server saturated): α_n* = c_n·D̂_n / Σ_m c_m·D̂_m      (Eq. 29)

  leader, decomposed (§V-B):
      v_n* = v_n_max                                               (§V-B-1)
      f_n* = max(f̃_n, f_min),  f̃_n = (1−v_n)·c_n·D_n / A_n        (§V-B-2)
      p_n* via successive Dinkelbach                               (§V-B-3)

Engine layout (one XLA program per solve):

  * ``equilibrium``         — single instance, fully jitted: the Alg.-2
    alternation runs as a ``lax.while_loop`` whose carry holds the
    best-iterate safeguard (lexicographic (infeasible, energy) key) and
    the convergence flag as JAX arrays — no host syncs on the hot path.
  * ``batched_equilibrium`` — ``vmap`` of the same body over K independent
    network realizations ``h2_batch[K, N]``; one XLA call solves all K
    (the Monte-Carlo workload of Figs. 4–9 and related incentive-game
    reproductions).
  * ``equilibrium_eager``   — the legacy host-side Python loop with
    per-iteration ``float()``/``bool()`` syncs, kept as the numerical
    reference for tests and the throughput microbench.

``Allocation`` is registered as a pytree so whole solves can cross
``jit``/``vmap`` boundaries; under ``batched_equilibrium`` every field
gains a leading K axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from . import noma
from .channel import BANDWIDTH_HZ, noise_power
from .dinkelbach import successive_power

TAU = 2e-28  # effective capacitance coefficient (Table I / [22])


@dataclass(frozen=True)
class GameConfig:
    """Table I simulation parameters.

    Frozen + hashable: passed as a static argument to the jitted solvers,
    so each distinct parameterization compiles exactly once.
    """
    bandwidth: float = BANDWIDTH_HZ
    sigma2: float = field(default_factory=noise_power)
    p_min: float = 0.01
    p_max: float = 0.10
    f_min: float = 1.0e9
    f_max: float = 10.0e9
    f_server: float = 100.0e9
    t_max: float = 10.0
    cycles_per_sample: float = 1.0e7          # c_n
    model_bits: float = 1.0e6                 # d_n = 1 Mbit
    tau: float = TAU
    dinkelbach_inner: str = "projected"


# ---------------------------------------------------------------------------
# per-term physics (paper Eqs. 5–7, 10–11)
# ---------------------------------------------------------------------------
def local_compute_latency(c, v, D, f):
    return c * (1.0 - v) * D / f                                    # Eq. (5)


def local_compute_energy(c, v, D, f, tau: float = TAU):
    return 0.5 * tau * c * (1.0 - v) * D * f ** 2                   # Eq. (6)


def dt_compute_latency(c, d_hat, alpha, f_server):
    return c * d_hat / (jnp.maximum(alpha, 1e-12) * f_server)       # Eq. (7)


# ---------------------------------------------------------------------------
# follower: Theorem 1
# ---------------------------------------------------------------------------
def follower_alpha(c, d_hat, t_total, f_server) -> Tuple[jax.Array, jax.Array]:
    """Optimal DT frequency shares.  Returns (alpha [N], t_S scalar)."""
    load = c * d_hat                                # CPU cycles per client
    alpha_case1 = load / (t_total * f_server)       # Eq. (26)
    saturated = jnp.sum(alpha_case1) > 1.0
    alpha_case2 = load / jnp.maximum(jnp.sum(load), 1e-12)   # Eq. (29)
    alpha = jnp.where(saturated, alpha_case2, alpha_case1)
    t_s = jnp.where(saturated, jnp.sum(load) / f_server, t_total)
    return alpha, t_s


# ---------------------------------------------------------------------------
# leader closed forms
# ---------------------------------------------------------------------------
def leader_v(v_max):
    """§V-B-1: map the maximum insensitive fraction."""
    return v_max


def leader_f(c, v, D, a_n, f_min, f_max):
    """§V-B-2: run exactly at the deadline, floor at f_min."""
    f_tilde = c * (1.0 - v) * D / jnp.maximum(a_n, 1e-9)
    return jnp.clip(jnp.maximum(f_tilde, f_min), f_min, f_max)


# ---------------------------------------------------------------------------
# Algorithm 2: joint equilibrium
# ---------------------------------------------------------------------------
@dataclass
class Allocation:
    v: jax.Array
    f: jax.Array
    p: jax.Array
    alpha: jax.Array
    rates: jax.Array
    q: jax.Array           # per-client Dinkelbach optima (rate per energy)
    t_cmp: jax.Array
    t_com: jax.Array
    t_dt: jax.Array
    t_total: jax.Array     # scalar round latency T (Eq. 17)
    energy: jax.Array      # scalar total energy E (Eq. 18)
    e_cmp: jax.Array
    e_com: jax.Array
    iterations: jax.Array | int = 0
    feasible: jax.Array | bool = True   # best iterate met the deadline


_ALLOC_FIELDS = tuple(f.name for f in dataclasses.fields(Allocation))
# pytree registration: every field is a data leaf, so Allocation flows
# through jit/vmap/scan; batched solves stack each field on a leading axis.
jax.tree_util.register_dataclass(Allocation, data_fields=_ALLOC_FIELDS,
                                 meta_fields=())


def round_metrics(cfg: GameConfig, D, v, f, p, h2_sorted):
    rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_com)
    return rates, t_cmp, t_com, e_cmp, e_com


def _leader_iteration(cfg: GameConfig, h2_sorted, D, v, f):
    """One Alg.-2 leader sweep: p via successive Dinkelbach given the current
    compute times, then f runs to the deadline given the new airtimes.

    Shared verbatim by the eager reference loop and the traced engine so the
    two paths are numerically identical per iteration.
    """
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)        # rate-floor slack
    p, q = successive_power(h2_sorted, cfg.model_bits, g_n, cfg.bandwidth,
                            cfg.sigma2, cfg.p_min, cfg.p_max,
                            inner=cfg.dinkelbach_inner)
    rates = noma.noma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    _, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p, h2_sorted)
    e_total = jnp.sum(e_cmp + e_com)
    feasible = jnp.max(t_cmp + t_com) <= cfg.t_max + 1e-6
    return f, p, q, e_total, feasible


def _finish(cfg: GameConfig, h2_sorted, D, v, f, p, q, d_hat, iterations,
            feasible) -> Allocation:
    """Follower best response to the leader's final strategy (Eq. 17)."""
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p,
                                                      h2_sorted)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _t_s = follower_alpha(cfg.cycles_per_sample, d_hat, t_total,
                                 cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha,
                              cfg.f_server)
    latency = jnp.maximum(t_total, jnp.max(t_dt))          # Eq. (17)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=latency, energy=jnp.sum(e_cmp + e_com),
                      e_cmp=e_cmp, e_com=e_com, iterations=iterations,
                      feasible=feasible)


def _solve(cfg: GameConfig, h2_sorted, D, v_max, epsilon, max_iter: int,
           tol) -> Allocation:
    """Traced Alg.-2 alternation: a ``lax.while_loop`` whose carry holds the
    best-iterate safeguard and the convergence flag as arrays.

    The safeguard key is lexicographic (infeasible, energy): Alg-2
    alternation is not guaranteed monotone near infeasible channel draws,
    so we return the lowest-energy deadline-feasible-first iterate —
    same policy as the legacy loop, minus the host syncs.
    """
    n = h2_sorted.shape[0]
    dtype = jnp.result_type(h2_sorted)
    v = leader_v(jnp.broadcast_to(v_max, (n,)).astype(dtype))
    D = jnp.broadcast_to(D, (n,)).astype(dtype)
    d_hat = v * D + epsilon                       # DT-mapped data size
    f0 = jnp.full((n,), cfg.f_max, dtype)
    p0 = jnp.full((n,), cfg.p_max, dtype)
    q0 = jnp.zeros((n,), dtype)
    inf = jnp.asarray(jnp.inf, dtype)

    def cond(carry):
        *_rest, it, done = carry
        return (~done) & (it < max_iter)

    def body(carry):
        f, p, q, prev_e, bb, be, bf, bp, bq, it, _done = carry
        f, p, q, e, feas = _leader_iteration(cfg, h2_sorted, D, v, f)
        bad = jnp.where(feas, jnp.asarray(0.0, dtype),
                        jnp.asarray(1.0, dtype))
        # strict lexicographic improvement, matching the legacy tuple compare
        better = (bad < bb) | ((bad == bb) & (e < be))
        bb = jnp.where(better, bad, bb)
        be = jnp.where(better, e, be)
        bf = jnp.where(better, f, bf)
        bp = jnp.where(better, p, bp)
        bq = jnp.where(better, q, bq)
        done = jnp.abs(prev_e - e) < tol * jnp.maximum(e, 1e-12)
        return (f, p, q, e, bb, be, bf, bp, bq, it + 1, done)

    init = (f0, p0, q0, inf,
            jnp.asarray(2.0, dtype), inf, f0, p0, q0,   # best: bad, e, f, p, q
            jnp.asarray(0, jnp.int32), jnp.asarray(False))
    carry = jax.lax.while_loop(cond, body, init)
    _f, _p, _q, _e, bb, _be, bf, bp, bq, it, _done = carry
    return _finish(cfg, h2_sorted, D, v, bf, bp, bq, d_hat, it, bb == 0.0)


@partial(jax.jit, static_argnames=("cfg", "max_iter"))
def _equilibrium_jit(cfg, h2_sorted, D, v_max, epsilon, tol, max_iter):
    return _solve(cfg, h2_sorted, D, v_max, epsilon, max_iter, tol)


@partial(jax.jit, static_argnames=("cfg", "max_iter"))
def _batched_equilibrium_jit(cfg, h2_batch, D_batch, v_max_batch, epsilon,
                             tol, max_iter):
    solve1 = lambda h2, d, vm: _solve(cfg, h2, d, vm, epsilon, max_iter, tol)
    return jax.vmap(solve1)(h2_batch, D_batch, v_max_batch)


def equilibrium(cfg: GameConfig, h2_sorted, D, v_max, epsilon: float = 0.0,
                max_iter: int = 20, tol: float = 1e-6) -> Allocation:
    """Algorithm 2 — alternate leader/follower best responses to the
    Stackelberg equilibrium, compiled to a single XLA program.
    Inputs sorted by descending channel gain.

    h2_sorted : [N] channel power gains (SIC order)
    D         : [N] client data sizes (samples)
    v_max     : [N] max insensitive-data fractions
    """
    return _equilibrium_jit(cfg, h2_sorted, D, v_max, epsilon, tol,
                            max_iter=max_iter)


def batched_equilibrium(cfg: GameConfig, h2_batch, D_batch, v_max_batch,
                        epsilon: float = 0.0, max_iter: int = 20,
                        tol: float = 1e-6) -> Allocation:
    """Solve K independent network realizations in ONE XLA call.

    h2_batch    : [K, N] channel power gains, each row in SIC order
    D_batch     : [K, N] or [N] client data sizes (broadcast across K)
    v_max_batch : [K, N] or [N] max insensitive-data fractions

    Returns an ``Allocation`` whose every field carries a leading K axis
    (scalars such as ``energy`` become [K]).  This is the Monte-Carlo
    entry point: thousands of channel draws per benchmark point amortize
    to one compile + one device dispatch.
    """
    h2_batch = jnp.asarray(h2_batch)
    k, n = h2_batch.shape
    D_batch = jnp.broadcast_to(D_batch, (k, n))
    v_max_batch = jnp.broadcast_to(v_max_batch, (k, n))
    return _batched_equilibrium_jit(cfg, h2_batch, D_batch, v_max_batch,
                                    epsilon, tol, max_iter=max_iter)


def equilibrium_eager(cfg: GameConfig, h2_sorted, D, v_max,
                      epsilon: float = 0.0, max_iter: int = 20,
                      tol: float = 1e-6) -> Allocation:
    """Legacy Algorithm 2: host-side Python loop with per-iteration
    ``float()``/``bool()`` device syncs.  Kept as the numerical reference
    for the jitted engine (tests) and as the baseline of
    ``benchmarks/equilibrium_throughput.py``.  Not jit/vmap-able.
    """
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    p = jnp.full((n,), cfg.p_max)
    q = jnp.zeros((n,))
    d_hat = v * D + epsilon                       # DT-mapped data size

    prev_e = jnp.inf
    it = 0
    best = None   # best-iterate safeguard (see _solve)
    for it in range(1, max_iter + 1):
        f, p, q, e_total, feas = _leader_iteration(cfg, h2_sorted, D, v, f)
        cand = (not bool(feas), float(e_total), (f, p, q))
        if best is None or cand[:2] < best[:2]:
            best = cand
        if jnp.abs(prev_e - e_total) < tol * jnp.maximum(e_total, 1e-12):
            break
        prev_e = e_total
    f, p, q = best[2]
    return _finish(cfg, h2_sorted, D, v, f, p, q, d_hat, it,
                   jnp.asarray(not best[0]))


# ---------------------------------------------------------------------------
# baselines for Fig. 9
# ---------------------------------------------------------------------------
def random_allocation(cfg: GameConfig, key, h2_sorted, D, v_max,
                      epsilon: float = 0.0) -> Allocation:
    """Random resource allocation baseline (same selection, random p/f/v)."""
    n = h2_sorted.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    v = jax.random.uniform(k1, (n,)) * v_max
    f = cfg.f_min + jax.random.uniform(k2, (n,)) * (cfg.f_max - cfg.f_min)
    p = cfg.p_min + jax.random.uniform(k3, (n,)) * (cfg.p_max - cfg.p_min)
    d_hat = v * D + epsilon
    rates, t_cmp, t_com, e_cmp, e_com = round_metrics(cfg, D, v, f, p, h2_sorted)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates,
                      q=jnp.zeros((n,)), t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com,
                      feasible=t_total <= cfg.t_max + 1e-6)


def oma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                   epsilon: float = 0.0) -> Allocation:
    """OMA baseline (default): FDMA — each client gets a B/N sub-band.

    Bandwidth-limited: at the paper's operating load (d_n ≥ 1 Mbit) the B/N
    sub-bands force long transmissions / higher power, reproducing the
    Fig. 9 OMA penalty.  (At very light load OMA is within ~2% of NOMA —
    regime note in EXPERIMENTS.md §Paper-validation.)"""
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    d_hat = v * D + epsilon
    bw, s2 = cfg.bandwidth / n, cfg.sigma2 / n
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    g_n = jnp.maximum(cfg.t_max - t_cmp, 1e-3)
    from .dinkelbach import dinkelbach_power
    def solve(h2_n, g_nn):
        p_n, q_n, _ = dinkelbach_power(cfg.model_bits, g_nn, h2_n / s2, bw,
                                       cfg.p_min, cfg.p_max,
                                       inner=cfg.dinkelbach_inner)
        return p_n, q_n
    p, q = jax.vmap(solve)(h2_sorted, g_n)
    rates = noma.oma_rates(p, h2_sorted, cfg.bandwidth, cfg.sigma2)
    t_com = noma.tx_latency(cfg.model_bits, rates)
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_com)
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com,
                      feasible=t_total <= cfg.t_max + 1e-6)


def oma_tdma_allocation(cfg: GameConfig, h2_sorted, D, v_max,
                        epsilon: float = 0.0) -> Allocation:
    """OMA variant: TDMA — sequential full-band slots (round latency Σ t_n,
    the paper's "insufficient clients per round" mechanism)."""
    n = h2_sorted.shape[0]
    v = leader_v(jnp.broadcast_to(v_max, (n,)))
    f = jnp.full((n,), cfg.f_max)
    d_hat = v * D + epsilon
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    # per-client slot budget: (Tmax − t_cmp)/N
    g_n = jnp.maximum((cfg.t_max - t_cmp) / n, 1e-3)
    from .dinkelbach import dinkelbach_power
    def solve(h2_n, g_nn):
        p_n, q_n, _ = dinkelbach_power(cfg.model_bits, g_nn,
                                       h2_n / cfg.sigma2, cfg.bandwidth,
                                       cfg.p_min, cfg.p_max,
                                       inner=cfg.dinkelbach_inner)
        return p_n, q_n
    p, q = jax.vmap(solve)(h2_sorted, g_n)
    rates = cfg.bandwidth * jnp.log2(1.0 + p * h2_sorted / cfg.sigma2)
    t_own = noma.tx_latency(cfg.model_bits, rates)     # own-slot airtime
    t_com = jnp.sum(t_own) * jnp.ones_like(t_own)      # sequential round time
    a_n = jnp.maximum(cfg.t_max - t_com, 1e-3)
    f = leader_f(cfg.cycles_per_sample, v, D, a_n, cfg.f_min, cfg.f_max)
    t_cmp = local_compute_latency(cfg.cycles_per_sample, v, D, f)
    e_cmp = local_compute_energy(cfg.cycles_per_sample, v, D, f, cfg.tau)
    e_com = noma.tx_energy(p, t_own)                   # energy over own slot
    t_total = jnp.max(t_cmp + t_com)
    alpha, _ = follower_alpha(cfg.cycles_per_sample, d_hat, t_total, cfg.f_server)
    t_dt = dt_compute_latency(cfg.cycles_per_sample, d_hat, alpha, cfg.f_server)
    return Allocation(v=v, f=f, p=p, alpha=alpha, rates=rates, q=q,
                      t_cmp=t_cmp, t_com=t_com, t_dt=t_dt,
                      t_total=jnp.maximum(t_total, jnp.max(t_dt)),
                      energy=jnp.sum(e_cmp + e_com), e_cmp=e_cmp, e_com=e_com,
                      feasible=t_total <= cfg.t_max + 1e-6)


def wo_dt_allocation(cfg: GameConfig, h2_sorted, D) -> Allocation:
    """W/O-DT baseline: v ≡ 0, all training on-client (straggler-exposed).

    Routed through the jitted engine (zero v_max shares the same XLA
    program as the proposed scheme — no extra compile)."""
    n = h2_sorted.shape[0]
    zero_vmax = jnp.zeros((n,))
    return equilibrium(cfg, h2_sorted, D, zero_vmax, epsilon=0.0)


def batched_wo_dt_allocation(cfg: GameConfig, h2_batch, D_batch) -> Allocation:
    """Batched W/O-DT: K realizations with v ≡ 0 in one XLA call."""
    h2_batch = jnp.asarray(h2_batch)
    return batched_equilibrium(cfg, h2_batch, D_batch,
                               jnp.zeros_like(h2_batch), epsilon=0.0)
