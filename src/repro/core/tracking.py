"""Shared recompile accounting for every jitted engine entry point.

``TRACE_COUNTS`` counts actual traces (the Python body of a jitted function
only runs when XLA compiles a new specialization) — the proof object behind
the zero-mid-sweep-recompile tests and the benchmarks' ``recompiles``
fields.  It lives in its own module so both ``stackelberg`` (which re-exports
it — the historical import site) and ``sic`` can increment it without an
import cycle (``stackelberg`` imports ``sic``).
"""
from __future__ import annotations

import collections

TRACE_COUNTS: collections.Counter = collections.Counter()


def reset_trace_counts() -> None:
    """Zero every trace counter (the jit caches themselves are untouched).

    Test isolation: ``TRACE_COUNTS`` deltas asserted in one test must not
    depend on which other tests ran first — an autouse fixture calls this
    before each test, so every assertion starts from a clean counter and
    snapshots its own ``before`` value."""
    TRACE_COUNTS.clear()
