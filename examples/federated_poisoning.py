"""The paper's main experiment (Fig. 5): reputation-based selection with
RONI defends FL accuracy against label-flip poisoners.

    PYTHONPATH=src python examples/federated_poisoning.py [--rounds 20]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import curve, fl_experiment
from repro.core.reputation import BENCHMARK_WEIGHTS, PROPOSED_WEIGHTS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--poison", type=float, default=0.3)
    args = ap.parse_args()

    print(f"=== {int(args.poison*100)}% poisoners, {args.rounds} rounds ===")
    runs = {}
    for name, w, roni in (("proposed (AC+MS+PI, RONI)", PROPOSED_WEIGHTS, True),
                          ("benchmark (AC+MS only)", BENCHMARK_WEIGHTS, False)):
        hist = fl_experiment(seed=7, dataset="mnist",
                             poison_ratio=args.poison, weights=w,
                             use_roni=roni, rounds=args.rounds)
        acc = curve(hist)
        runs[name] = acc
        excl = sum(h["n_excluded_roni"] for h in hist)
        psel = sum(h["n_poisoned_selected"] for h in hist)
        print(f"\n{name}")
        print("  acc: " + " ".join(f"{a:.3f}" for a in acc[:: max(1, args.rounds // 10)]))
        print(f"  final {max(acc[-5:]):.3f} | poisoned-selected {psel} | "
              f"RONI-excluded {excl}")
    p = max(runs["proposed (AC+MS+PI, RONI)"][-5:])
    b = max(runs["benchmark (AC+MS only)"][-5:])
    print(f"\nproposed {p:.3f} vs benchmark {b:.3f} → "
          f"{'REPRODUCED' if p >= b - 0.02 else 'NOT reproduced'} "
          "(paper Fig. 5 claim)")


if __name__ == "__main__":
    main()
