"""End-to-end LM training driver: train a ~100M-parameter granite-family
model for a few hundred steps on the synthetic token stream, with
checkpointing — exercising the real train_step (grad accumulation, AdamW,
remat, scan-over-layers).

Default config is ~25M params / 120 steps so it completes on the CPU
container in minutes; pass --full-100m --steps 300 for the full run
(identical code path, just bigger).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod
from repro.models.config import ATTN, BlockSpec, ModelConfig


def lm_config(full: bool) -> ModelConfig:
    if full:  # ~100M
        return ModelConfig(
            name="repro-lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, pattern=(BlockSpec(kind=ATTN),),
            dtype="float32", param_dtype="float32", remat=False)
    return ModelConfig(  # ~25M
        name="repro-lm-25m", family="dense", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=1408,
        vocab_size=16384, pattern=(BlockSpec(kind=ATTN),),
        dtype="float32", param_dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    from repro.checkpoint.io import restore_checkpoint, save_checkpoint
    from repro.data.pipeline import PipelineConfig, lm_batches
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim import AdamWConfig, init_opt_state

    cfg = lm_config(args.full_100m)
    pipe = PipelineConfig(global_batch=args.global_batch,
                          seq_len=args.seq_len, vocab_size=cfg.vocab_size)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps of {args.global_batch}x{args.seq_len}")
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, num_microbatches=1),
                      donate_argnums=(0, 1))
    import time
    it, t0, first = lm_batches(pipe), time.time(), None
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, next(it))
        loss = float(m["loss"])
        first = first if first is not None else loss
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = (step + 1) * args.global_batch * args.seq_len / (time.time() - t0)
            print(f"step {step:4d} loss {loss:.4f} tok/s {tok_s:.0f}", flush=True)
    save_checkpoint("runs/ckpt_lm", {"params": params}, args.steps)
    restored = restore_checkpoint("runs/ckpt_lm", {"params": params})
    print(f"checkpoint round-trip OK; loss {first:.3f} → {loss:.3f} "
          f"({'improved' if loss < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
