"""Batched serving example: run prefix-primed batched decoding with a KV
cache on a small gemma2-family model (sliding-window + global layers,
softcaps — the real serving code path).

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.launch.serve import generate
from repro.models import forward_logits, init_params

cfg = smoke_variant(get_config("gemma2-9b"))
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)

B, P, G = 4, 12, 24
prompt = jax.random.randint(jax.random.fold_in(key, 1), (B, P), 0,
                            cfg.vocab_size)
t0 = time.time()
toks = generate(cfg, params, prompt, max_seq=P + G + 1, gen=G)
dt = time.time() - t0
print(f"batch={B} prompt={P} generated={G} in {dt:.1f}s "
      f"({B*G/dt:.1f} tok/s on CPU)")

# consistency check: decode path must agree with the full forward pass
logits_full, _ = forward_logits(params, {"tokens": toks[:, :-1]}, cfg)
greedy_full = jnp.argmax(logits_full[:, P - 1:, :], axis=-1)
match = bool(jnp.all(greedy_full[:, 0] == toks[:, P]))
print(f"first generated token matches full-forward greedy: {match}")
assert match, "decode/forward divergence"
print("sample tokens:", toks[0].tolist())
