"""Streaming allocation example: a mixed-N request stream through the
ragged-N bucket scheduler (``repro.launch.alloc_serve``), then the same
service under pressure with the ISSUE-9 SLA/resilience contract.

Part 1 — the baseline stream: ten cells with 2–30 clients each, their
own channel draws and deadlines, padded into warm 8/16/32-wide bucket
executables (zero retraces), same-bucket requests batched per dispatch,
each cell's Stackelberg allocation returned in its own client order.

Part 2 — the SLA contract.  Every submitted rid yields EXACTLY ONE
result whose ``status`` comes from the five-word vocabulary:

  ok          solved, feasible, inside any deadline
  infeasible  solved, but the equilibrium violates the deadline/resource
              box even after the degraded-retry ladder (the ladder first
              re-solves with t_max x relax_factor — same executable,
              zero retrace — then falls back to the cheaper oma scheme;
              the trail is recorded in ``result.degradation``)
  rejected    no valid allocation: oversized N, non-finite channel
              gains, admission control (the EWMA queue-wait prediction
              already busts ``deadline_s``), an OPEN circuit breaker, or
              a dispatch that failed after backoff retries
  shed        dropped by priority-ordered load shedding when the bounded
              queue (``max_queue``) overflowed — lowest priority sheds
              first, high priority keeps completing
  timeout     solved (or expired in queue) after ``deadline_s``

Per-(bucket, scheme) circuit breakers contain a sick executable:
``breaker_threshold`` consecutive bad batches (non-finite outputs,
watchdog trips, dispatch failures) trip it OPEN → submissions fast-fail
→ after ``breaker_cooldown_s`` a HALF_OPEN probe either closes it or
re-opens.  ``service.health()`` snapshots queues, breakers, counters
and per-priority latency percentiles.

    PYTHONPATH=src python examples/serve_allocation.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest

rng = np.random.default_rng(0)
svc = AllocationService(buckets=(8, 16, 32), max_batch=4)

print("warming bucket executables (one-time compile)...")
# warm the oma fallback too: the degraded-retry ladder may land on it,
# and a warmed pair keeps even degraded streams retrace-free
print(f"  warmup: {svc.warmup(schemes=('proposed', 'oma')):.1f}s")
warm = TRACE_COUNTS["serve_allocation"]

cells = [int(n) for n in rng.integers(2, 31, size=10)]
t0 = time.time()
for i, n in enumerate(cells):
    svc.submit(AllocRequest(
        h2=rng.uniform(0.2, 2.0, n).astype(np.float32),
        d=200.0, v_max=0.5, epsilon=0.05,
        cfg=GameConfig(t_max=float(rng.uniform(0.9, 1.4)))))
results = svc.drain()                      # rid-sorted by contract
dt = time.time() - t0

print(f"\n{len(results)} cells allocated in {dt*1e3:.0f} ms "
      f"({svc.stats['dispatches']} dispatches, "
      f"{TRACE_COUNTS['serve_allocation'] - warm} retraces)")
print(f"{'cell':>4} {'N':>3} {'bucket':>6} {'status':>10} {'energy(J)':>10} "
      f"{'t_tot(s)':>9} {'degradation':>22}")
for r in results:
    print(f"{r.rid:>4} {r.n:>3} {r.bucket:>6} {r.status:>10} "
          f"{r.energy:>10.4f} {r.t_total:>9.4f} "
          f"{','.join(r.degradation) or '-':>22}")

# --- part 2: the same service under pressure -------------------------------
print("\nSLA mode: bounded queue, priorities, deadlines --")
sla = AllocationService(buckets=(8,), max_batch=4, max_queue=6)
sla.warmup(schemes=("proposed",))
for i in range(12):                        # a burst over the queue bound:
    hi = i % 3 == 0                        # every 3rd request is priority 2
    sla.submit(AllocRequest(
        h2=rng.uniform(0.2, 2.0, int(rng.integers(2, 9))),
        priority=2 if hi else 0,
        deadline_s=2.0 if hi else None))
sla.submit(AllocRequest(h2=np.ones(99)))             # oversized  → rejected
sla.submit(AllocRequest(h2=np.array([1.0, np.nan])))  # poisoned  → rejected
burst = sla.drain()

by_status = {}
for r in burst:
    by_status.setdefault(r.status, []).append(r.rid)
print(f"  {len(burst)} results for {len(burst)} submits (exactly once):")
for status, rids in sorted(by_status.items()):
    print(f"    {status:>10}: rids {rids}")
health = sla.health()
print(f"  health: counters={health['counters']}")
print(f"          breakers={health['breakers']}")
print(f"          latency by priority (ms) = "
      f"{health['latency_by_priority_ms']}")
