"""Streaming allocation example: a mixed-N request stream through the
ragged-N bucket scheduler (``repro.launch.alloc_serve``).

Ten cells with 2–30 clients each — their own channel draws and deadlines —
are submitted as a stream; the service pads them into warm 8/16/32-wide
bucket executables (zero retraces), batches same-bucket requests into one
dispatch, and returns each cell's Stackelberg allocation in its own client
order.

    PYTHONPATH=src python examples/serve_allocation.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.stackelberg import GameConfig
from repro.core.tracking import TRACE_COUNTS
from repro.launch.alloc_serve import AllocationService, AllocRequest

rng = np.random.default_rng(0)
svc = AllocationService(buckets=(8, 16, 32), max_batch=4)

print("warming bucket executables (one-time compile)...")
print(f"  warmup: {svc.warmup(schemes=('proposed',)):.1f}s")
warm = TRACE_COUNTS["serve_allocation"]

cells = [int(n) for n in rng.integers(2, 31, size=10)]
t0 = time.time()
for i, n in enumerate(cells):
    svc.submit(AllocRequest(
        h2=rng.uniform(0.2, 2.0, n).astype(np.float32),
        d=200.0, v_max=0.5, epsilon=0.05,
        cfg=GameConfig(t_max=float(rng.uniform(0.9, 1.4)))))
results = sorted(svc.drain(), key=lambda r: r.rid)
dt = time.time() - t0

print(f"\n{len(results)} cells allocated in {dt*1e3:.0f} ms "
      f"({svc.stats['dispatches']} dispatches, "
      f"{TRACE_COUNTS['serve_allocation'] - warm} retraces)")
print(f"{'cell':>4} {'N':>3} {'bucket':>6} {'feas':>5} {'energy(J)':>10} "
      f"{'latency(s)':>10} {'p[0](W)':>8}")
for r in results:
    print(f"{r.rid:>4} {r.n:>3} {r.bucket:>6} {str(r.feasible):>5} "
          f"{r.energy:>10.4f} {r.t_total:>10.4f} {r.p[0]:>8.4f}")
