"""Quickstart: one DT-assisted FL round, end to end, narrated.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (FLConfig, FLState, GameConfig, equilibrium,
                        init_reputation, run_round, select_clients)
from repro.core.channel import sample_positions, sample_round_channels
from repro.core.digital_twin import DTConfig, sample_v_max
from repro.data.federated import make_federated_data
from repro.data.synthetic import SYNTHETIC_MNIST
from repro.models.classifier import make_classifier

key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 6)
M, N = 20, 5

print("=== DT-assisted FL over NOMA: one round ===")
data = make_federated_data(ks[0], SYNTHETIC_MNIST, m=M, cap=128,
                           poison_ratio=0.3)
print(f"{M} clients, data sizes {data.sizes.astype(int).tolist()}")
print(f"poisoned clients: {jnp.where(data.poisoned)[0].tolist()}")

# 1. reputation-based selection (paper §III)
rep = init_reputation(M)
sel, z = select_clients(rep, data.sizes, N)
print(f"\n[1] selected by reputation: {sel.tolist()}")
print(f"    reputation scores: {[round(float(z[i]), 3) for i in sel]}")

# 2. channel realization + SIC order (paper §II-C)
dist = sample_positions(ks[1], M)
h2 = sample_round_channels(ks[2], dist)[sel]
order = jnp.argsort(-h2)
print(f"\n[2] SIC decode order (desc |h|²): {sel[order].tolist()}")

# 3. Stackelberg equilibrium (paper §IV–V)
game = GameConfig()
vmax = sample_v_max(ks[3], M, DTConfig())
alloc = equilibrium(game, h2[order], data.sizes[sel[order]], vmax[sel[order]])
print(f"\n[3] Stackelberg allocation (leader=clients, follower=server):")
print(f"    v* (DT mapping ratios) = {[round(float(x),2) for x in alloc.v]}")
print(f"    f* (GHz)               = {[round(float(x)/1e9,2) for x in alloc.f]}")
print(f"    p* (W)                 = {[round(float(x),3) for x in alloc.p]}")
print(f"    alpha* (server shares) = {[round(float(x),4) for x in alloc.alpha]}")
print(f"    round latency T = {float(alloc.t_total):.2f}s  "
      f"energy E = {float(alloc.energy):.3f}J")

# 4. full round through the orchestrator (train, RONI, aggregate)
params, logits_fn = make_classifier("mlp", ks[4], in_dim=784, hidden=64)
state = FLState(params=params, rep=rep, v_max=vmax, distances=dist, key=ks[5])
state, metrics = run_round(state, data, FLConfig(), game, logits_fn)
print(f"\n[4] round metrics: " + ", ".join(
    f"{k}={v}" for k, v in metrics.items() if not hasattr(v, 'shape')))
print("\nOK — see examples/federated_poisoning.py for multi-round training.")
